from .sharding import (ParallelCtx, choose_spec, local_ctx, make_ctx,
                       param_pspec, param_shardings, zero1_pspec)

__all__ = ["ParallelCtx", "choose_spec", "local_ctx", "make_ctx",
           "param_pspec", "param_shardings", "zero1_pspec"]
