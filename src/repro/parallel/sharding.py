"""Mesh context + logical→physical sharding rules.

Meshes (DESIGN.md §6): single-pod ``(16, 16) ("data", "model")``,
multi-pod ``(2, 16, 16) ("pod", "data", "model")``.

* dense layers: tensor parallel over ``model``, batch over
  (``pod``,)+``data`` — expressed as parameter shardings + activation
  constraints, XLA SPMD inserts the collectives.
* MoE experts: EP over ``model``; each expert FSDP-sharded over ``data``
  (+``pod``) and gathered at use (see repro.models.moe).
* optimizer states: ZeRO-1 — additionally sharded over ``data`` on the
  largest still-unsharded divisible dim.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Everything model code needs to know about the mesh."""

    mesh: Optional[Mesh]
    pod_axis: Optional[str] = None
    data_axis: Optional[str] = None
    model_axis: Optional[str] = None

    # -- derived ---------------------------------------------------------
    @property
    def ep_axis(self) -> Optional[str]:
        return self.model_axis

    @property
    def fsdp_axis(self) -> Optional[str]:
        return self.data_axis

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in (self.pod_axis, self.data_axis,
                                 self.model_axis) if a is not None)

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in (self.pod_axis, self.data_axis)
                     if a is not None)

    def axis_size(self, axis: Optional[str]) -> int:
        if axis is None or self.mesh is None:
            return 1
        return self.mesh.shape[axis]

    @property
    def num_devices(self) -> int:
        return 1 if self.mesh is None else int(np.prod(list(self.mesh.shape.values())))

    @property
    def ep_size(self) -> int:
        return self.axis_size(self.model_axis)

    def constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def batch_spec(self, ndim: int, batch: Optional[int] = None) -> P:
        """Shard dim0 over (pod, data); rest replicated.  If ``batch`` is
        given, fall back to the largest prefix of the batch axes that
        divides it (B=1 long-context decode ⇒ replicated)."""
        ba = list(self.batch_axes)
        if batch is not None:
            while ba and batch % int(np.prod([self.axis_size(a)
                                              for a in ba])) != 0:
                ba.pop(0)
        if not ba:
            return P(*([None] * ndim))
        lead = tuple(ba) if len(ba) != 1 else ba[0]
        return P(lead, *([None] * (ndim - 1)))


def make_ctx(mesh: Mesh) -> ParallelCtx:
    names = mesh.axis_names
    return ParallelCtx(
        mesh=mesh,
        pod_axis="pod" if "pod" in names else None,
        data_axis="data" if "data" in names else None,
        model_axis="model" if "model" in names else None,
    )


def local_ctx() -> ParallelCtx:
    """No-mesh single-device context (CPU smoke tests / quickstart)."""
    return ParallelCtx(mesh=None)


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

def choose_spec(shape: Sequence[int], candidates: Sequence[P],
                mesh_shape: dict) -> P:
    """First candidate whose every named axis divides its dim."""
    for spec in candidates:
        ok = True
        for dim, names in enumerate(spec):
            if names is None:
                continue
            ns = names if isinstance(names, tuple) else (names,)
            size = int(np.prod([mesh_shape[n] for n in ns]))
            if dim >= len(shape) or shape[dim] % size != 0:
                ok = False
                break
        if ok:
            return spec
    return P(*([None] * len(shape)))


def _expert_leaf(path: Tuple[str, ...]) -> bool:
    """Expert-stacked matrices live under a 'wi'/'wg'/'wo' key whose parent
    chain contains a MoE marker; we detect by rank-3 leaf under 'moe'."""
    return any(p in ("moe",) for p in path)


def param_pspec(path: Tuple[str, ...], shape: Sequence[int],
                ctx: ParallelCtx, *, stacked_dims: int = 0) -> P:
    """Sharding spec for one parameter.

    ``stacked_dims``: number of leading scan-stacking dims (replicated).
    Rules (after stripping stacked dims):
      embedding [V, d]           → P(model, None)  (vocab-sharded)
      expert wi/wg [E, d, f]     → P(model, pod, data)
      expert wo   [E, f, d]      → P(model, data, pod)
      matmul [in, out]           → shard larger of out/in over model
      1-D / norms                → replicated
    """
    if ctx.mesh is None:
        return P()
    mesh_shape = dict(ctx.mesh.shape)
    core = tuple(shape[stacked_dims:])
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""

    m, d_ax, p_ax = ctx.model_axis, ctx.data_axis, ctx.pod_axis

    if name == "table":  # embedding
        spec = choose_spec(core, [P(m, None), P(None, m), P(None, None)],
                           mesh_shape)
    elif len(core) == 3 and name in ("wi", "wg", "wo") and _expert_leaf(path):
        if name == "wo":
            cands = [P(m, d_ax, p_ax), P(m, d_ax, None), P(m, None, None),
                     P(None, None, None)]
        else:
            cands = [P(m, p_ax, d_ax), P(m, None, d_ax), P(m, None, None),
                     P(None, None, None)]
        spec = choose_spec(core, cands, mesh_shape)
    elif len(core) == 2:
        # Alternate model-sharding between producer (out-dim) and consumer
        # (in-dim) matrices to avoid resharding between them.
        if name in ("wo", "out_proj", "down_proj", "out", "dt_proj", "wuk",
                    "wuv"):
            cands = [P(m, None), P(None, m), P(None, None)]
        else:
            cands = [P(None, m), P(m, None), P(None, None)]
        spec = choose_spec(core, cands, mesh_shape)
    elif len(core) == 3:  # e.g. sLSTM block-diagonal recurrence [H, dh, 4dh]
        spec = choose_spec(core, [P(m, None, None), P(None, None, None)],
                           mesh_shape)
    else:
        spec = P(*([None] * len(core)))
    return P(*([None] * stacked_dims), *spec)


def zero1_pspec(spec: P, shape: Sequence[int], ctx: ParallelCtx) -> P:
    """Optimizer-state spec: param spec + ``data`` on the largest
    still-unsharded divisible dim (ZeRO-1)."""
    if ctx.mesh is None or ctx.data_axis is None:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    if any(e is not None and (e == ctx.data_axis or
                              (isinstance(e, tuple) and ctx.data_axis in e))
           for e in entries):
        return spec
    data_size = ctx.axis_size(ctx.data_axis)
    best, best_dim = -1, None
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % data_size == 0 and s > best:
            best, best_dim = s, i
    if best_dim is None:
        return spec
    entries[best_dim] = ctx.data_axis
    return P(*entries)


def param_shardings(params, ctx: ParallelCtx, *, stacked_dims_fn=None):
    """Tree of NamedShardings mirroring a param pytree.

    ``stacked_dims_fn(path) -> int`` reports scan-stacking depth (default:
    paths under a 'stages' subtree have 1 stacked dim)."""
    if ctx.mesh is None:
        return jax.tree.map(lambda _: None, params)

    def default_stacked(path):
        return 1 if any(str(p) == "stages" for p in path) else 0

    fn = stacked_dims_fn or default_stacked

    def leaf_spec(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "idx", str(p)))
                     for p in path)
        keys = tuple(str(k) for k in keys)
        spec = param_pspec(keys, leaf.shape, ctx, stacked_dims=fn(keys))
        return NamedSharding(ctx.mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)
