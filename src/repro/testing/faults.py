"""Deterministic seeded fault injection for the self-healing runtime.

The resilience layer (plan watchdog, transactional relocation, atomic
checkpoints) only earns its keep if the degradation paths are exercised
on every CI run, not just when hardware actually misbehaves.  This module
is the injection harness: a :class:`FaultInjector` holds a schedule of
:class:`Fault` records, each naming a *site* (what goes wrong) and an
occurrence index *at* (the n-th time that site is reached — for per-step
sites like planning, this equals the training step whose counts are being
processed).  Production code reaches the injector through module-level
hooks that cost one ``None`` check when no injector is installed:

===================  =====================================================
site (kind)          effect at the hook
===================  =====================================================
``planner_exception``  raises :class:`InjectedFault` inside the Plan
                       primitive, before ``engine.observe`` runs — the
                       watchdog must fall back to the last-good placements.
``slow_plan``          sleeps ``payload['delay_s']`` (default 0.05) inside
                       the Plan window — with ``REPRO_PLAN_DEADLINE_MS``
                       set, the watchdog must reject the overrun plan.
``corrupt_counts``     rewrites seeded entries of the fetched routing
                       counts to NaN / negative values
                       (``payload['mode']`` ∈ {``nan``, ``negative``,
                       ``inf``, ``mixed``}) — sanitization must repair
                       them from the last-good observation.
``fail_relocation``    makes the transactional weight/optimizer exchange
                       fail: ``payload['mode']='raise'`` raises mid-
                       exchange, ``'corrupt'`` (default) perturbs one
                       relocated leaf so the fingerprint round-trip check
                       catches it — either way the trainer must roll back.
``torn_checkpoint``    simulates a crash mid-save: ``payload['mode']``
                       ``'truncate'`` (default) truncates ``state.npz``
                       after the digest was stamped (a torn write the
                       digest check must catch), ``'abort'`` abandons the
                       temp directory before the atomic rename (a partial
                       ``restore_latest`` must skip).
``straggler``          multiplies device ``payload['device']`` (default 0)'s
                       reported step time by ``payload['factor']``
                       (default 2.0) for ``payload['steps']`` (default 5)
                       consecutive timing observations, then clears — the
                       health tracker must classify it degraded and the
                       planner must drain hot experts toward fast ranks,
                       and it must recover once the episode ends.
``degraded_throughput``  like ``straggler`` but *persistent*: device
                       ``payload['device']`` reports
                       ``payload['factor']``× (default 2.0) step times
                       from occurrence ``at`` onwards — steady-state
                       heterogeneity-aware planning.
``device_loss``        device ``payload['device']`` stops reporting (its
                       timing entry becomes NaN — a missed heartbeat)
                       from occurrence ``at`` onwards: the tracker must
                       classify it *lost* after its patience window and
                       the planner must evacuate every resident expert.
===================  =====================================================

The three timing sites share one hook (``device_timings``): the trainer
passes the measured per-device step-time vector through it every step,
and all three site counters advance together, so ``at`` is the training
step the episode starts at.

Everything is deterministic: the schedule is explicit, per-site counters
advance exactly once per hook reach, and the corruption positions come
from a seeded ``numpy`` generator — the same injector config always
produces the same faults, which is what lets ``tests/test_resilience.py``
assert *bit-identical* loss under planner faults.

Usage::

    inj = FaultInjector([Fault("planner_exception", at=3),
                         Fault("corrupt_counts", at=5)], seed=0)
    with faults.injected(inj):
        trainer.run(...)
    assert ("planner_exception", 3) in inj.fired
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Array = np.ndarray

KINDS = ("planner_exception", "slow_plan", "corrupt_counts",
         "fail_relocation", "torn_checkpoint",
         "straggler", "degraded_throughput", "device_loss")


class InjectedFault(RuntimeError):
    """Raised by injection sites that simulate a crash."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: ``kind`` names the site, ``at`` the 0-based
    occurrence index at that site (for per-step sites this is the
    training step whose counts/relocation/save is being processed), and
    ``payload`` carries site-specific knobs (see module docstring)."""

    kind: str
    at: int
    payload: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.at < 0:
            raise ValueError(f"fault occurrence index must be >= 0, "
                             f"got {self.at}")


class FaultInjector:
    """Deterministic schedule of faults keyed by (site, occurrence)."""

    def __init__(self, faults: Sequence[Fault], *, seed: int = 0):
        self.faults: List[Fault] = [f if isinstance(f, Fault) else Fault(*f)
                                    for f in faults]
        self.rng = np.random.default_rng(seed)
        self._counters: Dict[str, int] = defaultdict(int)
        self.fired: List[Tuple[str, int]] = []
        # Live timing episodes (straggler countdowns, persistent
        # degradation/loss) started by device_timings.
        self._timing_effects: List[Dict] = []

    def _take(self, kind: str) -> Optional[Fault]:
        """Advance the site counter; return the scheduled fault for this
        occurrence (and log it) or None."""
        i = self._counters[kind]
        self._counters[kind] += 1
        for f in self.faults:
            if f.kind == kind and f.at == i:
                self.fired.append((kind, i))
                return f
        return None

    # -- site hooks ------------------------------------------------------
    def planner_fault(self) -> None:
        f = self._take("planner_exception")
        if f is not None:
            raise InjectedFault(
                f"injected planner exception (plan #{f.at})")

    def plan_delay(self) -> float:
        """Seconds to stall the Plan primitive (0.0 when unscheduled)."""
        f = self._take("slow_plan")
        return float(f.payload.get("delay_s", 0.05)) if f is not None else 0.0

    def corrupt_counts(self, counts: Array) -> Array:
        """Maybe corrupt the fetched ``[L, D, E]`` routing counts.  The
        corrupted copy is float64 (ints can't hold NaN); positions come
        from the seeded generator."""
        f = self._take("corrupt_counts")
        if f is None:
            return counts
        mode = f.payload.get("mode", "mixed")
        out = np.array(counts, dtype=np.float64, copy=True)
        flat = out.reshape(-1)
        n_bad = max(1, flat.size // 16)
        idx = self.rng.choice(flat.size, size=n_bad, replace=False)
        if mode == "nan":
            flat[idx] = np.nan
        elif mode == "inf":
            flat[idx] = np.inf
        elif mode == "negative":
            flat[idx] = -1.0 - np.abs(flat[idx])
        else:  # mixed
            thirds = np.array_split(idx, 3)
            flat[thirds[0]] = np.nan
            flat[thirds[1]] = np.inf
            flat[thirds[2]] = -7.0
        return out

    def relocation_fault(self) -> Optional[Fault]:
        """The transactional relocation hook: the caller applies the
        returned fault's mode (``raise`` | ``corrupt``), or nothing."""
        return self._take("fail_relocation")

    def torn_checkpoint(self) -> Optional[Fault]:
        """The checkpoint-save hook: the caller simulates the returned
        fault's crash mode (``truncate`` | ``abort``), or nothing."""
        return self._take("torn_checkpoint")

    def device_timings(self, times: Array) -> Array:
        """The fleet-health hook: perturb the measured per-device step
        times before the health tracker sees them.  All three timing
        sites advance together once per call, so a fault's ``at`` is the
        timing observation (≈ training step) its episode starts at.
        Effects persist across calls: a ``straggler`` inflates its
        device's time for ``steps`` observations then clears,
        ``degraded_throughput`` inflates forever, ``device_loss`` reports
        NaN (missed heartbeat) forever."""
        out = np.array(times, dtype=np.float64, copy=True)
        for kind in ("straggler", "degraded_throughput", "device_loss"):
            f = self._take(kind)
            if f is None:
                continue
            self._timing_effects.append({
                "kind": kind,
                "device": int(f.payload.get("device", 0)),
                "factor": float(f.payload.get("factor", 2.0)),
                "left": (int(f.payload.get("steps", 5))
                         if kind == "straggler" else -1),
            })
        keep = []
        for eff in self._timing_effects:
            d = eff["device"]
            if eff["kind"] == "device_loss":
                out[d] = np.nan
            else:
                out[d] *= eff["factor"]
                if eff["left"] > 0:
                    eff["left"] -= 1
                    if eff["left"] == 0:
                        continue          # straggler episode over
            keep.append(eff)
        self._timing_effects = keep
        return out


# ---------------------------------------------------------------------------
# Process-wide installation (hooks are no-ops when nothing is installed)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    """The installed injector, or None (the common, zero-cost case)."""
    return _ACTIVE


def install(inj: FaultInjector) -> Optional[FaultInjector]:
    """Install ``inj`` process-wide; returns the previously installed
    injector (if any) so callers can restore it."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, inj
    return prev


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


@contextlib.contextmanager
def injected(inj: FaultInjector):
    """Scoped installation: ``with faults.injected(inj): trainer.run(...)``."""
    prev = install(inj)
    try:
        yield inj
    finally:
        global _ACTIVE
        _ACTIVE = prev
