"""Testing utilities: deterministic fault injection for the resilience
layer (see :mod:`repro.testing.faults`)."""
from .faults import (Fault, FaultInjector, InjectedFault, active, injected,
                     install, uninstall)

__all__ = ["Fault", "FaultInjector", "InjectedFault", "active", "injected",
           "install", "uninstall"]
