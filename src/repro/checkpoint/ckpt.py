"""Pytree checkpointing: flattened key-path → .npz, sharding-aware restore.

No orbax in this environment; this is a self-contained implementation with
the same contract: save(state) → directory; restore(state_like) → state
with each leaf device_put to the target sharding (so a checkpoint written
on one mesh restores onto another).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

_SEP = "::"


_BF16 = "__bf16__"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz can't store ml_dtypes
            flat[key + _BF16] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def save_pytree(tree, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)


def load_pytree(tree_like, path: str, shardings: Optional[Any] = None):
    """Restore into the structure of ``tree_like``; device_put each leaf to
    the matching sharding if given."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(flat_paths[0]))
    for (pth, like), shard in zip(flat_paths[0], shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pth)
        if key + _BF16 in data:
            import ml_dtypes
            arr = data[key + _BF16].view(ml_dtypes.bfloat16)
        else:
            arr = data[key]
        assert arr.shape == tuple(like.shape), (key, arr.shape, like.shape)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.numpy.asarray(arr, like.dtype))
    return jax.tree_util.tree_unflatten(flat_paths[1], leaves)


def save_train_state(state, path: str, *, step: int, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    save_pytree(state, os.path.join(path, "state.npz"))
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, **(extra or {})}, f)


def restore_train_state(state_like, path: str, shardings=None):
    state = load_pytree(state_like, os.path.join(path, "state.npz"),
                        shardings)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return state, meta
