"""Pytree checkpointing: flattened key-path → .npz, sharding-aware restore.

No orbax in this environment; this is a self-contained implementation with
the same contract: save(state) → directory; restore(state_like) → state
with each leaf device_put to the target sharding (so a checkpoint written
on one mesh restores onto another).

Durability contract (the self-healing runtime's recovery anchor):

* **Atomic writes** — :func:`save_train_state` stages the checkpoint in a
  ``.tmp-``-prefixed sibling directory, fsyncs file contents and the
  parent directory, and publishes with a single ``rename``.  A crash at
  any point leaves either the previous checkpoint or an invisible temp
  directory — never a half-written published one.

* **Verifiable content** — ``meta.json`` records a SHA-256 digest of
  ``state.npz``; :func:`verify_checkpoint` re-hashes on read, so torn or
  bit-rotted state files are detected instead of silently restored.

* **Retention + recovery** — :func:`save_checkpoint` writes
  ``root/step-<n>`` and prunes to the last ``keep``;
  :func:`restore_latest` scans newest-first and skips anything corrupt or
  partial, recovering the last intact checkpoint.

Structural mismatches on load raise :class:`CheckpointError` naming the
offending key path; dtypes must match the restore target exactly (the
old silent-cast path hid real mismatches — a bf16-saved leaf restores
only into a bf16 slot, via the uint16 view round-trip).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

_SEP = "::"


_BF16 = "__bf16__"

_TMP_PREFIX = ".tmp-"


class CheckpointError(RuntimeError):
    """A checkpoint could not be written, verified, or restored."""


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz can't store ml_dtypes
            flat[key + _BF16] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def save_pytree(tree, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)


def load_pytree(tree_like, path: str, shardings: Optional[Any] = None):
    """Restore into the structure of ``tree_like``; device_put each leaf to
    the matching sharding if given.

    Structural problems raise :class:`CheckpointError` naming the leaf's
    key path: a missing array, a shape mismatch, or a dtype mismatch
    (leaves restore only into slots of the dtype they were saved with —
    bf16 leaves travel as a uint16 view and require a bf16 target)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    try:
        data = np.load(path)
    except (OSError, ValueError) as e:
        raise CheckpointError(f"cannot read checkpoint array file "
                              f"{path}: {e}") from e
    flat_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(flat_paths[0]))
    for (pth, like), shard in zip(flat_paths[0], shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pth)
        like_dtype = np.dtype(like.dtype)
        if key + _BF16 in data:
            if like_dtype.name != "bfloat16":
                raise CheckpointError(
                    f"leaf '{key}': checkpoint holds bfloat16 but the "
                    f"restore target expects {like_dtype.name}")
            import ml_dtypes
            arr = data[key + _BF16].view(ml_dtypes.bfloat16)
        elif key in data:
            arr = data[key]
            if arr.dtype != like_dtype:
                raise CheckpointError(
                    f"leaf '{key}': checkpoint dtype {arr.dtype} does not "
                    f"match restore target dtype {like_dtype}")
        else:
            raise CheckpointError(
                f"leaf '{key}' is missing from checkpoint {path}")
        if arr.shape != tuple(like.shape):
            raise CheckpointError(
                f"leaf '{key}': checkpoint shape {arr.shape} does not "
                f"match restore target shape {tuple(like.shape)}")
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.numpy.asarray(arr, like.dtype))
    return jax.tree_util.tree_unflatten(flat_paths[1], leaves)


# ---------------------------------------------------------------------------
# Atomic directory checkpoints
# ---------------------------------------------------------------------------

def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_train_state(state, path: str, *, step: int, extra: dict = None):
    """Atomically write ``path/{state.npz, meta.json}``.

    The files are staged in a ``.tmp-``-prefixed sibling directory,
    fsynced, and published with one ``rename`` — readers see either the
    complete new checkpoint or whatever was there before, never a torn
    one.  ``meta.json`` carries a SHA-256 digest of ``state.npz``
    (checked by :func:`verify_checkpoint` / :func:`restore_latest`)."""
    from repro.testing import faults as _faults
    path = os.path.abspath(path)
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(parent,
                       f"{_TMP_PREFIX}{os.path.basename(path)}-{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    state_file = os.path.join(tmp, "state.npz")
    save_pytree(state, state_file)
    meta = {"step": int(step), "digest": _sha256_file(state_file),
            **(extra or {})}
    meta_file = os.path.join(tmp, "meta.json")
    with open(meta_file, "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(state_file)
    _fsync_path(tmp)

    inj = _faults.active()
    if inj is not None:
        fault = inj.torn_checkpoint()
        if fault is not None:
            mode = fault.payload.get("mode", "truncate")
            if mode == "abort":
                # Simulated crash before the publish rename: the temp
                # directory stays behind (invisible to step-* scans).
                return
            # Simulated torn write that still got published: truncate the
            # array file after its digest was stamped.
            size = os.path.getsize(state_file)
            with open(state_file, "rb+") as f:
                f.truncate(max(1, size // 2))

    if os.path.exists(path):
        old = f"{path}.old-{os.getpid()}"
        os.rename(path, old)
        os.rename(tmp, path)
        shutil.rmtree(old, ignore_errors=True)
    else:
        os.rename(tmp, path)
    _fsync_path(parent)


def verify_checkpoint(path: str) -> Tuple[bool, str]:
    """Integrity check of one checkpoint directory: readable metadata,
    present array file, matching content digest.  Returns
    ``(ok, reason)`` — reason is ``""`` when intact."""
    meta_file = os.path.join(path, "meta.json")
    state_file = os.path.join(path, "state.npz")
    try:
        with open(meta_file) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return False, f"unreadable meta.json: {e}"
    if "step" not in meta:
        return False, "meta.json missing 'step'"
    if not os.path.exists(state_file):
        return False, "state.npz missing"
    digest = meta.get("digest")
    if digest is None:
        return True, ""        # pre-digest checkpoint: structurally intact
    actual = _sha256_file(state_file)
    if actual != digest:
        return False, (f"state.npz digest mismatch: meta records "
                       f"{digest[:12]}…, file hashes {actual[:12]}…")
    return True, ""


def restore_train_state(state_like, path: str, shardings=None):
    state = load_pytree(state_like, os.path.join(path, "state.npz"),
                        shardings)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return state, meta


# ---------------------------------------------------------------------------
# Retained checkpoint roots (step-<n> directories)
# ---------------------------------------------------------------------------

def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step-{step:08d}")


def list_checkpoints(root: str) -> List[Tuple[int, str]]:
    """``[(step, path), ...]`` ascending for every published ``step-*``
    directory under ``root`` (temp/aside directories never match)."""
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if not name.startswith("step-"):
            continue
        full = os.path.join(root, name)
        if not os.path.isdir(full):
            continue
        try:
            out.append((int(name[len("step-"):]), full))
        except ValueError:
            continue
    return sorted(out)


def save_checkpoint(state, root: str, *, step: int, keep: int = 3,
                    extra: dict = None) -> str:
    """Atomic retained checkpoint: write ``root/step-<n>`` via
    :func:`save_train_state`, then prune to the newest ``keep``
    directories.  Returns the checkpoint path."""
    path = _step_dir(root, step)
    save_train_state(state, path, step=step, extra=extra)
    if keep and keep > 0:
        for _, old in list_checkpoints(root)[:-keep]:
            shutil.rmtree(old, ignore_errors=True)
    return path


def restore_latest(state_like, root: str, shardings=None):
    """Restore the newest *intact* checkpoint under ``root`` →
    ``(state, meta, path)``.  Corrupt or partial directories (failed
    digest, unreadable metadata, structural mismatch) are skipped with
    the next-newest tried; raises :class:`CheckpointError` when no
    restorable checkpoint remains."""
    tried = []
    for step, path in reversed(list_checkpoints(root)):
        ok, reason = verify_checkpoint(path)
        if not ok:
            tried.append(f"{path}: {reason}")
            continue
        try:
            state, meta = restore_train_state(state_like, path, shardings)
            return state, meta, path
        except (CheckpointError, OSError) as e:
            tried.append(f"{path}: {e}")
    detail = ("; ".join(tried)) if tried else "no step-* directories"
    raise CheckpointError(
        f"no intact checkpoint under {root} ({detail})")
