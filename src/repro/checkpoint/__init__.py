from .ckpt import (CheckpointError, list_checkpoints, load_pytree,
                   restore_latest, restore_train_state, save_checkpoint,
                   save_pytree, save_train_state, verify_checkpoint)

__all__ = ["CheckpointError", "list_checkpoints", "load_pytree",
           "restore_latest", "restore_train_state", "save_checkpoint",
           "save_pytree", "save_train_state", "verify_checkpoint"]
