from .ckpt import load_pytree, restore_train_state, save_pytree, save_train_state

__all__ = ["load_pytree", "restore_train_state", "save_pytree",
           "save_train_state"]
