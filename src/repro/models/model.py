"""Top-level models: decoder LM, encoder (audio), VLM backbone.

The modality frontends for [vlm]/[audio] are stubs per the assignment:
``input_specs`` supplies precomputed patch/frame embeddings of the right
shape; this module implements the transformer that consumes them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import blocks
from .common import (cross_entropy_loss, dense_init, embed, embedding_init,
                     rmsnorm, rmsnorm_init, unembed)


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, len(cfg.stages) + 3)
    p: Dict[str, Any] = {
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "stages": [blocks.stage_init(ks[i], st, cfg, dtype)
                   for i, st in enumerate(cfg.stages)],
    }
    if cfg.modality == "audio":
        # Frontend stub: frames arrive as embeddings; learn an input proj.
        p["in_proj"] = dense_init(ks[-3], (cfg.d_model, cfg.d_model), dtype)
        p["out_proj"] = dense_init(ks[-2], (cfg.d_model, cfg.vocab_size),
                                   dtype)
    else:
        p["embed"] = embedding_init(ks[-3], cfg.vocab_size, cfg.d_model,
                                    dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[-2], (cfg.d_model, cfg.vocab_size),
                                      dtype)
    if cfg.modality == "vlm":
        # Projector from the (stub) vision embedding space into d_model.
        p["vision_proj"] = dense_init(ks[-1], (cfg.d_model, cfg.d_model),
                                      dtype)
    return p


def _split_placements(cfg: ModelConfig, placements):
    """Split stacked [L_moe, ...] placement arrays into per-stage chunks
    shaped [repeats, m_moe, ...]."""
    if placements is None:
        return [None] * len(cfg.stages)
    out, off = [], 0
    for st in cfg.stages:
        m = len(blocks.moe_positions(st))
        n = m * st.repeats
        if m == 0:
            out.append(None)
        else:
            out.append({k: v[off:off + n].reshape((st.repeats, m)
                                                  + v.shape[1:])
                        for k, v in placements.items()})
        off += n
    return out


def forward(params, tokens, cfg: ModelConfig, ctx, *, placements=None,
            attn_impl: str = "auto", prefix_embeds=None,
            frame_embeds=None, remat: bool = True,
            return_hidden: bool = False, a2a_chunks: int = 1):
    """Returns (logits, aux).  aux['counts']: [L_moe, ep, E] or None.

    tokens [B, S] (ignored for audio); prefix_embeds [B, P, d] (vlm);
    frame_embeds [B, S, d] (audio).  ``a2a_chunks``: static MoE a2a↔FEC
    chunk count (repro.models.moe module docstring).
    """
    if cfg.modality == "audio":
        x = frame_embeds @ params["in_proj"]
    else:
        x = embed(params["embed"], tokens)
        if cfg.modality == "vlm":
            pre = prefix_embeds @ params["vision_proj"]
            x = jnp.concatenate([pre.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = ctx.constrain(x, ctx.batch_spec(3))

    per_stage = _split_placements(cfg, placements)
    counts: List[Any] = []
    for st_params, st, pl in zip(params["stages"], cfg.stages, per_stage):
        x, c = blocks.stage_apply(st_params, x, positions, st, cfg, ctx,
                                  placements=pl, attn_impl=attn_impl,
                                  remat=remat, a2a_chunks=a2a_chunks)
        if c is not None:
            counts.append(c)
    x = rmsnorm(params["final_norm"], x)
    if return_hidden:
        return x, {"counts": jnp.concatenate(counts) if counts else None}
    if cfg.modality == "audio":
        logits = (x @ params["out_proj"]).astype(jnp.float32)
    elif cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = (x @ params["lm_head"]).astype(jnp.float32)
    aux = {"counts": jnp.concatenate(counts) if counts else None}
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig, ctx, *, placements=None,
            attn_impl: str = "auto", remat: bool = True,
            a2a_chunks: int = 1):
    """batch: tokens/labels (+loss_mask) or frame_embeds/labels/loss_mask
    (audio) or tokens/prefix_embeds/labels (vlm)."""
    from repro import flags
    chunk = flags.xent_chunk()
    if chunk and cfg.tie_embeddings and cfg.modality != "audio":
        # §Perf memory lever: fused unembed + streaming xent, no [B,S,V].
        x, aux = forward(
            params, batch.get("tokens"), cfg, ctx, placements=placements,
            attn_impl=attn_impl, prefix_embeds=batch.get("prefix_embeds"),
            frame_embeds=batch.get("frame_embeds"), remat=remat,
            return_hidden=True, a2a_chunks=a2a_chunks)
        if cfg.modality == "vlm":
            x = x[:, cfg.num_prefix_tokens:]
        from .common import chunked_unembed_xent
        loss = chunked_unembed_xent(x, params["embed"]["table"],
                                    batch["labels"], chunk,
                                    batch.get("loss_mask"))
        return loss, aux
    logits, aux = forward(
        params, batch.get("tokens"), cfg, ctx, placements=placements,
        attn_impl=attn_impl, prefix_embeds=batch.get("prefix_embeds"),
        frame_embeds=batch.get("frame_embeds"), remat=remat,
        a2a_chunks=a2a_chunks)
    labels = batch["labels"]
    if cfg.modality == "vlm":
        # Loss only over the text region (labels align with text tokens).
        logits = logits[:, cfg.num_prefix_tokens:]
    loss = cross_entropy_loss(logits, labels, batch.get("loss_mask"))
    return loss, aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32):
    assert cfg.supports_decode, f"{cfg.name} has no decode path"
    return [blocks.stage_init_cache(st, cfg, batch, max_len, dtype)
            for st in cfg.stages]


def decode_step(params, caches, token, cache_index, cfg: ModelConfig, ctx,
                *, placements=None):
    """One-token decode. token [B, 1] int32; cache_index scalar int32.
    Returns (logits [B, 1, V], new caches)."""
    x = embed(params["embed"], token)
    per_stage = _split_placements(cfg, placements)
    new_caches = []
    for st_params, st, cache, pl in zip(params["stages"], cfg.stages, caches,
                                        per_stage):
        x, nc = blocks.stage_decode(st_params, x, cache, cache_index, st,
                                    cfg, ctx, placements=pl)
        new_caches.append(nc)
    x = rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits, new_caches
