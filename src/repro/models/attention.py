"""Attention: GQA/MQA/MHA with optional QKV bias and sliding windows.

Three interchangeable inner implementations (all numerically equivalent):

* ``naive``   — materializes [B, H, Sq, Skv] scores.  Tests / tiny shapes.
* ``chunked`` — flash-style online softmax over KV blocks in pure jnp
  (lax.scan); O(S·block) live memory.  Default for large shapes.
* ``banded``  — sliding-window variant of ``chunked`` that only visits the
  ceil(window/block)+1 KV blocks a query block can see: true sub-quadratic
  compute for local-attention layers (gemma3, jamba @ 500k).
* ``pallas``  — TPU Pallas flash kernel (repro.kernels.flash_attention),
  validated in interpret mode; selected via ``impl='pallas'``.

Grouped heads are handled without materializing repeated KV: queries are
reshaped to [B, S, K, G, dh] and contracted against KV per group.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init

NEG_INF = -1e30


def attention_init(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, num_heads * head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, num_kv_heads * head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, num_kv_heads * head_dim), dtype),
        "wo": dense_init(ks[3], (num_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def _qkv(params, x, num_heads, num_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, num_heads, head_dim)
    k = k.reshape(B, S, num_kv_heads, head_dim)
    v = v.reshape(B, S, num_kv_heads, head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Inner attention implementations.  q: [B,Sq,K,G,dh], k/v: [B,Skv,K,dh].
# ---------------------------------------------------------------------------

def _naive(q, k, v, *, causal: bool, window: Optional[int], scale: float,
           q_offset: int = 0):
    B, Sq, K, G, dh = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqkgd,bpkd->bkgqp", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqp,bpkd->bqkgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def _online_block(carry, qb, kb, vb, mask, scale):
    """One online-softmax update. carry=(m,l,o); qb [B,Bq,K,G,dh].

    With REPRO_ATTN_BF16_SCORES=1 (§Perf memory lever) the two big
    einsums read bf16 operands and accumulate in f32 via
    preferred_element_type — halves the score-traffic bytes with the same
    f32 softmax statistics."""
    from repro import flags
    bf16_ops = flags.attn_bf16_scores()
    m, l, o = carry
    if bf16_ops:
        # jnp.einsum upcasts operands even with preferred_element_type in
        # this pattern — explicit dot_general keeps them bf16.
        lhs = qb.transpose(0, 2, 3, 1, 4)          # [B,K,G,Bq,dh]
        rhs = kb.transpose(0, 2, 1, 3)             # [B,K,Bk,dh]
        s = jax.lax.dot_general(
            lhs, rhs, (((4,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32) * scale
    else:
        s = jnp.einsum("bqkgd,bpkd->bkgqp", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    # Guard fully-masked rows (m_new == NEG_INF) against inf-inf.
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - safe_m[..., None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - safe_m))
    l_new = l * alpha + p.sum(axis=-1)
    if bf16_ops:
        rhs_v = vb.transpose(0, 2, 1, 3)           # [B,K,Bk,dv]
        ob = jax.lax.dot_general(
            p.astype(vb.dtype), rhs_v, (((4,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)
    else:
        ob = jnp.einsum("bkgqp,bpkd->bkgqd", p, vb.astype(jnp.float32))
    o_new = o * alpha[..., None] + ob
    return m_new, l_new, o_new


def _pad_seq(x, block: int):
    pad = (-x.shape[1]) % block
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[1] = (0, pad)
        x = jnp.pad(x, widths)
    return x


def _chunked(q, k, v, *, causal: bool, window: Optional[int], scale: float,
             q_block: int, kv_block: int, q_offset: int = 0):
    """Online softmax over all KV blocks (masked). O(S·block) memory."""
    B, Sq0, K, G, dh = q.shape
    Skv0 = k.shape[1]
    dv = v.shape[-1]              # may differ from dh (MLA)
    q = _pad_seq(q, q_block)
    k = _pad_seq(k, kv_block)
    v = _pad_seq(v, kv_block)
    Sq, Skv = q.shape[1], k.shape[1]
    nq, nk = Sq // q_block, Skv // kv_block

    kb = k.reshape(B, nk, kv_block, K, dh)
    vb = v.reshape(B, nk, kv_block, K, dv)
    qb = q.reshape(B, nq, q_block, K, G, dh)

    def per_q(qi, qblk):
        qpos = qi * q_block + jnp.arange(q_block) + q_offset

        def step(carry, inp):
            ki, kblk, vblk = inp
            kpos = ki * kv_block + jnp.arange(kv_block)
            mask = jnp.broadcast_to(kpos[None, :] < Skv0,
                                    (q_block, kv_block))  # kv padding
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            mask = mask[None, None, None]  # [1,1,1,Bq,Bk]
            return _online_block(carry, qblk, kblk, vblk, mask, scale), None

        init = (jnp.full((B, K, G, q_block), NEG_INF, jnp.float32),
                jnp.zeros((B, K, G, q_block), jnp.float32),
                jnp.zeros((B, K, G, q_block, dv), jnp.float32))
        (m, l, o), _ = jax.lax.scan(
            step, init,
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return jnp.einsum("bkgqd->bqkgd", o).astype(q.dtype)

    out = jax.lax.map(lambda t: per_q(t[0], t[1]),
                      (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, K, G, dv)[:, :Sq0]


def _banded(q, k, v, *, window: int, scale: float, q_block: int,
            kv_block: int, q_offset: int = 0):
    """Causal sliding-window attention visiting only in-band KV blocks.

    Query block i (absolute start p0 = i·Bq + q_offset) can see keys in
    [p0 − window + 1, p0 + Bq − 1]; that's a static count of
    ceil((window + Bq)/Bk) + 1 KV blocks fetched by dynamic_slice.
    """
    B, Sq0, K, G, dh = q.shape
    Skv0 = k.shape[1]
    dv = v.shape[-1]
    q = _pad_seq(q, q_block)
    k = _pad_seq(k, kv_block)
    v = _pad_seq(v, kv_block)
    Sq, Skv = q.shape[1], k.shape[1]
    nq = Sq // q_block
    nband = (window + q_block - 1) // kv_block + 1

    qb = q.reshape(B, nq, q_block, K, G, dh)

    def per_q(qi, qblk):
        p0 = qi * q_block + q_offset
        qpos = p0 + jnp.arange(q_block)
        first_block = (p0 - window + 1) // kv_block  # may be negative

        def step(carry, r):
            bidx = first_block + r
            cl = jnp.clip(bidx, 0, Skv // kv_block - 1)
            kblk = jax.lax.dynamic_slice_in_dim(k, cl * kv_block, kv_block, 1)
            vblk = jax.lax.dynamic_slice_in_dim(v, cl * kv_block, kv_block, 1)
            kpos = cl * kv_block + jnp.arange(kv_block)
            mask = (qpos[:, None] >= kpos[None, :]) & \
                   (qpos[:, None] - kpos[None, :] < window) & \
                   (bidx >= 0) & (kpos[None, :] < Skv0)
            mask = mask[None, None, None]
            return _online_block(carry, qblk, kblk, vblk, mask, scale), None

        init = (jnp.full((B, K, G, q_block), NEG_INF, jnp.float32),
                jnp.zeros((B, K, G, q_block), jnp.float32),
                jnp.zeros((B, K, G, q_block, dv), jnp.float32))
        (m, l, o), _ = jax.lax.scan(step, init, jnp.arange(nband))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return jnp.einsum("bkgqd->bqkgd", o).astype(q.dtype)

    out = jax.lax.map(lambda t: per_q(t[0], t[1]),
                      (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, K, G, dv)[:, :Sq0]


def multihead_attention(params, x, positions, *, num_heads: int,
                        num_kv_heads: int, head_dim: int,
                        causal: bool = True, window: Optional[int] = None,
                        rope_theta: float = 10000.0, use_rope: bool = True,
                        impl: str = "auto", q_block: int = 512,
                        kv_block: int = 512):
    """Full attention sublayer (projections + rope + inner attention)."""
    B, S, _ = x.shape
    K, G = num_kv_heads, num_heads // num_kv_heads
    q, k, v = _qkv(params, x, num_heads, num_kv_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    qg = q.reshape(B, S, K, G, head_dim)
    scale = head_dim ** -0.5

    if impl == "auto":
        from repro import flags
        # §Perf lever (REPRO_ATTN_NAIVE_MAX): at moderate S, naive scores
        # with head-TP + remat beat the chunked lax.map path, whose
        # q-block loop forces SPMD "involuntary full rematerialization"
        # all-gathers.  Default threshold keeps the original behaviour.
        naive_max = flags.attn_naive_max()
        if window is not None and causal and S > 2 * q_block and window < S:
            impl = "banded"
        elif S > naive_max:
            impl = "chunked"
        else:
            impl = "naive"
    if impl == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(qg, k, v, causal=causal, window=window,
                                 scale=scale)
    elif impl == "naive":
        o = _naive(qg, k, v, causal=causal, window=window, scale=scale)
    elif impl == "chunked":
        qb = min(q_block, S)
        o = _chunked(qg, k, v, causal=causal, window=window, scale=scale,
                     q_block=qb, kv_block=min(kv_block, S))
    elif impl == "banded":
        assert window is not None and causal
        qb = min(q_block, S)
        o = _banded(qg, k, v, window=window, scale=scale,
                    q_block=qb, kv_block=min(kv_block, S))
    else:
        raise ValueError(f"unknown attention impl {impl}")

    o = o.reshape(B, S, num_heads * head_dim)
    return o @ params["wo"]


def decode_attention(params, x, cache_k, cache_v, cache_index, *,
                     num_heads: int, num_kv_heads: int, head_dim: int,
                     window: Optional[int] = None,
                     rope_theta: float = 10000.0, use_rope: bool = True):
    """Single-token decode: x [B,1,d]; cache [B,Smax,K,dh]; returns
    (y [B,1,d], new_cache_k, new_cache_v)."""
    B, one, _ = x.shape
    K, G = num_kv_heads, num_heads // num_kv_heads
    q, k, v = _qkv(params, x, num_heads, num_kv_heads, head_dim)
    pos = jnp.full((B, 1), cache_index, jnp.int32)
    if use_rope:
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), cache_index, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), cache_index, axis=1)
    Smax = cache_k.shape[1]
    qg = q.reshape(B, K, G, head_dim)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) * head_dim ** -0.5
    kpos = jnp.arange(Smax)
    mask = kpos <= cache_index
    if window is not None:
        mask &= kpos > cache_index - window
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, cache_v.astype(jnp.float32))
    o = o.astype(x.dtype).reshape(B, 1, num_heads * head_dim)
    return o @ params["wo"], cache_k, cache_v
