"""Layer + stage composition: heterogeneous macro-blocks under lax.scan.

A stage scans ``repeats`` copies of a macro-block (tuple of LayerSpecs
unrolled in the body).  Parameters are stacked along a leading dim by
vmapped init; caches likewise for decode.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, Stage

from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm, xlstm
from .common import rmsnorm, rmsnorm_init
from .ffn import ffn_apply, ffn_init


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def layer_init(key, spec: LayerSpec, cfg: ModelConfig, dtype=jnp.float32):
    kmix, kffn = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if spec.mixer == "gqa":
        p["attn"] = attn.attention_init(
            kmix, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias, dtype=dtype)
    elif spec.mixer == "mla":
        m = cfg.mla
        p["mla"] = mla_mod.mla_init(
            kmix, cfg.d_model, cfg.num_heads, q_rank=m.q_rank,
            kv_rank=m.kv_rank, nope_dim=m.nope_dim, rope_dim=m.rope_dim,
            v_dim=m.v_dim, dtype=dtype)
    elif spec.mixer == "mamba":
        mb = cfg.mamba
        p["mamba"] = ssm.mamba_init(kmix, cfg.d_model, expand=mb.expand,
                                    d_state=mb.d_state, d_conv=mb.d_conv,
                                    dtype=dtype)
    elif spec.mixer == "mlstm":
        p["mlstm"] = xlstm.mlstm_init(kmix, cfg.d_model, cfg.mlstm_heads,
                                      dtype=dtype)
    elif spec.mixer == "slstm":
        p["slstm"] = xlstm.slstm_init(kmix, cfg.d_model, cfg.mlstm_heads,
                                      dtype=dtype)
    else:
        raise ValueError(spec.mixer)

    if spec.ffn != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
    if spec.ffn == "dense":
        p["ffn"] = ffn_init(kffn, cfg.ffn_kind, cfg.d_model, cfg.d_ff, dtype)
    elif spec.ffn == "moe":
        mo = cfg.moe
        p["moe"] = moe_mod.moe_init(
            kffn, cfg.d_model, mo.d_expert, mo.num_experts,
            ffn_kind=cfg.ffn_kind, num_shared=mo.num_shared,
            shared_d_ff=mo.shared_d_ff, dtype=dtype)
    return p


def _mixer_apply(params, x, positions, spec: LayerSpec, cfg: ModelConfig,
                 attn_impl: str):
    if spec.mixer == "gqa":
        return attn.multihead_attention(
            params["attn"], x, positions, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            causal=cfg.causal, window=spec.window,
            rope_theta=cfg.rope_theta, impl=attn_impl)
    if spec.mixer == "mla":
        m = cfg.mla
        return mla_mod.mla_attention(
            params["mla"], x, positions, num_heads=cfg.num_heads,
            kv_rank=m.kv_rank, nope_dim=m.nope_dim, rope_dim=m.rope_dim,
            v_dim=m.v_dim, rope_theta=cfg.rope_theta, causal=cfg.causal,
            impl=attn_impl if attn_impl in ("naive", "chunked") else "auto")
    if spec.mixer == "mamba":
        mb = cfg.mamba
        return ssm.mamba(params["mamba"], x, expand=mb.expand,
                         d_state=mb.d_state, d_conv=mb.d_conv)
    if spec.mixer == "mlstm":
        impl = {"naive": "parallel", "chunked": "recurrent"}.get(attn_impl,
                                                                 "auto")
        return xlstm.mlstm(params["mlstm"], x, num_heads=cfg.mlstm_heads,
                           impl=impl)
    if spec.mixer == "slstm":
        return xlstm.slstm(params["slstm"], x, num_heads=cfg.mlstm_heads)
    raise ValueError(spec.mixer)


def _pin(x, ctx):
    """Residual-stream constraint at sublayer boundaries (§Perf levers):
    REPRO_SEQ_PARALLEL ⇒ S sharded over the model axis (sequence
    parallelism); REPRO_PIN_RESIDUAL ⇒ replicated over model."""
    from jax.sharding import PartitionSpec as _P

    from repro import flags as _flags
    if ctx.mesh is None:
        return x
    ba = ctx.batch_axes
    blead = ba if len(ba) != 1 else ba[0]
    if _flags.seq_parallel():
        return ctx.constrain(x, _P(blead, ctx.model_axis, None))
    if _flags.pin_residual():
        return ctx.constrain(x, _P(blead, None, None))
    return x


def _pin_norm(y, ctx):
    """REPRO_PIN_NORM=1 (§Perf): constrain the rmsnorm output to
    P(batch, None, None).  The TP backward then all-reduces ONE bf16
    cotangent at this boundary instead of three f32 x-shaped intermediates
    inside the norm's backward (observed 8.56 GB/layer → bf16 boundary)."""
    from repro import flags
    if not flags.pin_norm() or ctx.mesh is None:
        return y
    from jax.sharding import PartitionSpec as _P
    ba = ctx.batch_axes
    return ctx.constrain(y, _P(ba if len(ba) != 1 else ba[0], None, None))


def layer_apply(params, x, positions, spec: LayerSpec, cfg: ModelConfig,
                ctx, placement=None, attn_impl: str = "auto",
                a2a_chunks: int = 1):
    """Pre-LN residual layer. Returns (x, moe_aux or None)."""
    x = _pin(x, ctx)
    x = x + _mixer_apply(params, _pin_norm(rmsnorm(params["norm1"], x), ctx),
                         positions, spec, cfg, attn_impl)
    x = _pin(x, ctx)
    aux = None
    if spec.ffn == "dense":
        x = x + ffn_apply(cfg.ffn_kind, params["ffn"],
                          _pin_norm(rmsnorm(params["norm2"], x), ctx))
    elif spec.ffn == "moe":
        mo = cfg.moe
        y, aux = moe_mod.moe_apply(
            params["moe"], rmsnorm(params["norm2"], x), placement, ctx,
            num_experts=mo.num_experts, top_k=mo.top_k,
            d_expert=mo.d_expert, ffn_kind=cfg.ffn_kind,
            capacity_factor=mo.capacity_factor,
            shadow_capacity_factor=mo.shadow_capacity_factor,
            s_max=mo.s_max, a2a_chunks=a2a_chunks)
        x = x + y
    return x, aux


# ---------------------------------------------------------------------------
# Decode (single token with caches)
# ---------------------------------------------------------------------------

def layer_init_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                     max_len: int, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    if spec.mixer == "gqa":
        shape = (batch, max_len, cfg.num_kv_heads, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if spec.mixer == "mla":
        m = cfg.mla
        return {"ckv": jnp.zeros((batch, max_len, m.kv_rank), dtype),
                "krope": jnp.zeros((batch, max_len, m.rope_dim), dtype)}
    if spec.mixer == "mamba":
        mb = cfg.mamba
        return ssm.mamba_init_state(batch, cfg.d_model, expand=mb.expand,
                                    d_state=mb.d_state, d_conv=mb.d_conv,
                                    dtype=dtype)
    if spec.mixer == "mlstm":
        return xlstm.mlstm_init_state(batch, cfg.d_model, cfg.mlstm_heads)
    if spec.mixer == "slstm":
        return xlstm.slstm_init_state(batch, cfg.d_model, cfg.mlstm_heads)
    raise ValueError(spec.mixer)


def _mixer_decode(params, x, cache, cache_index, spec: LayerSpec,
                  cfg: ModelConfig):
    if spec.mixer == "gqa":
        y, k, v = attn.decode_attention(
            params["attn"], x, cache["k"], cache["v"], cache_index,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, window=spec.window,
            rope_theta=cfg.rope_theta)
        return y, {"k": k, "v": v}
    if spec.mixer == "mla":
        m = cfg.mla
        y, ckv, krope = mla_mod.mla_decode(
            params["mla"], x, cache["ckv"], cache["krope"], cache_index,
            num_heads=cfg.num_heads, kv_rank=m.kv_rank, nope_dim=m.nope_dim,
            rope_dim=m.rope_dim, v_dim=m.v_dim, rope_theta=cfg.rope_theta)
        return y, {"ckv": ckv, "krope": krope}
    if spec.mixer == "mamba":
        mb = cfg.mamba
        return ssm.mamba_decode(params["mamba"], x, cache, expand=mb.expand,
                                d_state=mb.d_state, d_conv=mb.d_conv)
    if spec.mixer == "mlstm":
        return xlstm.mlstm_decode(params["mlstm"], x, cache,
                                  num_heads=cfg.mlstm_heads)
    if spec.mixer == "slstm":
        return xlstm.slstm_decode(params["slstm"], x, cache,
                                  num_heads=cfg.mlstm_heads)
    raise ValueError(spec.mixer)


def layer_decode(params, x, cache, cache_index, spec: LayerSpec,
                 cfg: ModelConfig, ctx, placement=None):
    y, cache = _mixer_decode(params, rmsnorm(params["norm1"], x), cache,
                             cache_index, spec, cfg)
    x = x + y
    if spec.ffn == "dense":
        x = x + ffn_apply(cfg.ffn_kind, params["ffn"],
                          rmsnorm(params["norm2"], x))
    elif spec.ffn == "moe":
        mo = cfg.moe
        y, _ = moe_mod.moe_apply(
            params["moe"], rmsnorm(params["norm2"], x), placement, ctx,
            num_experts=mo.num_experts, top_k=mo.top_k,
            d_expert=mo.d_expert, ffn_kind=cfg.ffn_kind,
            capacity_factor=mo.capacity_factor,
            shadow_capacity_factor=mo.shadow_capacity_factor,
            s_max=mo.s_max)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# Stages (scan over stacked layers)
# ---------------------------------------------------------------------------

def stage_init(key, stage: Stage, cfg: ModelConfig, dtype=jnp.float32):
    """Params: {pos: stacked-layer-params [repeats, ...]}."""
    keys = jax.random.split(key, stage.repeats)

    def one(k):
        ks = jax.random.split(k, len(stage.macro))
        return {str(i): layer_init(ks[i], spec, cfg, dtype)
                for i, spec in enumerate(stage.macro)}

    if stage.repeats == 1:
        p = one(keys[0])
        return jax.tree.map(lambda a: a[None], p)
    return jax.vmap(one)(keys)


def moe_positions(stage: Stage) -> List[int]:
    return [i for i, s in enumerate(stage.macro) if s.ffn == "moe"]


def stage_apply(params, x, positions, stage: Stage, cfg: ModelConfig, ctx,
                placements=None, attn_impl: str = "auto",
                remat: bool = True, a2a_chunks: int = 1):
    """placements: dict of arrays with leading dims [repeats, m_moe, ...]
    (m_moe = MoE layers per macro) or None.  ``a2a_chunks`` is one static
    chunk count for every MoE layer in the stage (layers share a single
    scanned trace, so a per-layer K cannot vary inside a stage).
    Returns (x, counts [repeats*m_moe, ep, E] or None)."""
    mpos = moe_positions(stage)

    def body(carry, per_layer):
        x = carry
        layer_params, pl_slice = per_layer
        counts_out = []
        for i, spec in enumerate(stage.macro):
            pl = None
            if spec.ffn == "moe" and pl_slice is not None:
                j = mpos.index(i)
                pl = {k: v[j] for k, v in pl_slice.items()}
            x, aux = layer_apply(layer_params[str(i)], x, positions, spec,
                                 cfg, ctx, pl, attn_impl, a2a_chunks)
            if aux is not None:
                counts_out.append(aux["counts"])
        stacked = jnp.stack(counts_out) if counts_out else jnp.zeros((0, 1, 1),
                                                                     jnp.int32)
        return x, stacked

    fn = jax.checkpoint(body) if remat else body
    x, counts = jax.lax.scan(fn, x, (params, placements))
    if counts.shape[1] == 0:
        return x, None
    r, m = counts.shape[0], counts.shape[1]
    return x, counts.reshape(r * m, *counts.shape[2:])


def stage_init_cache(stage: Stage, cfg: ModelConfig, batch: int,
                     max_len: int, dtype=jnp.float32):
    caches = {str(i): layer_init_cache(spec, cfg, batch, max_len, dtype)
              for i, spec in enumerate(stage.macro)}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (stage.repeats,) + a.shape).copy()
        if stage.repeats > 1 else a[None], caches)


def stage_decode(params, x, caches, cache_index, stage: Stage,
                 cfg: ModelConfig, ctx, placements=None):
    mpos = moe_positions(stage)

    def body(carry, per_layer):
        x = carry
        layer_params, layer_cache, pl_slice = per_layer
        new_cache = {}
        for i, spec in enumerate(stage.macro):
            pl = None
            if spec.ffn == "moe" and pl_slice is not None:
                j = mpos.index(i)
                pl = {k: v[j] for k, v in pl_slice.items()}
            x, new_cache[str(i)] = layer_decode(
                layer_params[str(i)], x, layer_cache[str(i)], cache_index,
                spec, cfg, ctx, pl)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params, caches, placements))
    return x, new_caches
