"""Cache size accounting (decode memory planning / roofline inputs).

Cache construction itself lives in blocks.layer_init_cache; this module
answers "how many bytes per token does arch X cache?" for the memory
analysis in EXPERIMENTS.md.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig


def cache_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Bytes of decode state appended per generated/consumed token."""
    total = 0
    for spec in cfg.layer_specs:
        if spec.mixer == "gqa":
            total += 2 * cfg.num_kv_heads * cfg.resolved_head_dim * dtype_bytes
        elif spec.mixer == "mla":
            total += (cfg.mla.kv_rank + cfg.mla.rope_dim) * dtype_bytes
        # mamba / mlstm / slstm: O(1) state, nothing per token.
    return total


def state_bytes(cfg: ModelConfig, batch: int, dtype_bytes: int = 4) -> int:
    """Fixed-size recurrent state (SSM/xLSTM) for a batch."""
    total = 0
    for spec in cfg.layer_specs:
        if spec.mixer == "mamba":
            di = cfg.mamba.expand * cfg.d_model
            total += batch * di * (cfg.mamba.d_state + cfg.mamba.d_conv - 1) \
                * dtype_bytes
        elif spec.mixer == "mlstm":
            di = 2 * cfg.d_model
            dh = di // cfg.mlstm_heads
            total += batch * cfg.mlstm_heads * (dh * dh + dh + 1) * dtype_bytes
        elif spec.mixer == "slstm":
            total += batch * 4 * cfg.d_model * dtype_bytes
    return total


def decode_cache_bytes(cfg: ModelConfig, batch: int, seq_len: int,
                       dtype_bytes: int = 2) -> int:
    return batch * seq_len * cache_bytes_per_token(cfg, dtype_bytes) + \
        state_bytes(cfg, batch)
