"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and KV are projected through low-rank latents; only the KV latent
``c_kv`` [r_kv] and the shared rope key ``k_rope`` [dr] are cached at decode
(the MLA memory win: 512+64 floats/token instead of 2·H·dh).

Training path materializes full K/V and reuses the chunked attention
machinery.  Decode path uses the *absorbed* form: q_nope is pushed through
W_uk so scores are taken directly against the latent cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .attention import _chunked, _naive
from .common import apply_rope, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


def mla_init(key, d_model: int, num_heads: int, *, q_rank: int,
             kv_rank: int, nope_dim: int, rope_dim: int, v_dim: int,
             dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    return {
        "wdq": dense_init(ks[0], (d_model, q_rank), dtype),
        "q_norm": rmsnorm_init(q_rank, dtype),
        "wuq": dense_init(ks[1], (q_rank, num_heads * (nope_dim + rope_dim)), dtype),
        "wdkv": dense_init(ks[2], (d_model, kv_rank + rope_dim), dtype),
        "kv_norm": rmsnorm_init(kv_rank, dtype),
        "wuk": dense_init(ks[3], (kv_rank, num_heads * nope_dim), dtype),
        "wuv": dense_init(ks[4], (kv_rank, num_heads * v_dim), dtype),
        "wo": dense_init(ks[5], (num_heads * v_dim, d_model), dtype),
    }


def _latents(params, x, *, kv_rank: int, rope_dim: int):
    dkv = x @ params["wdkv"]                       # [B,S,r+dr]
    c_kv = rmsnorm(params["kv_norm"], dkv[..., :kv_rank])
    k_rope = dkv[..., kv_rank:]                    # [B,S,dr] shared across heads
    return c_kv, k_rope


def _queries(params, x, positions, *, num_heads, nope_dim, rope_dim,
             rope_theta):
    B, S, _ = x.shape
    q = rmsnorm(params["q_norm"], x @ params["wdq"]) @ params["wuq"]
    q = q.reshape(B, S, num_heads, nope_dim + rope_dim)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    return q_nope, q_rope


def mla_attention(params, x, positions, *, num_heads: int, kv_rank: int,
                  nope_dim: int, rope_dim: int, v_dim: int,
                  rope_theta: float = 10000.0, causal: bool = True,
                  impl: str = "auto", q_block: int = 512):
    """Training/prefill path: materialize K/V, grouped-attention inner."""
    B, S, _ = x.shape
    H = num_heads
    q_nope, q_rope = _queries(params, x, positions, num_heads=H,
                              nope_dim=nope_dim, rope_dim=rope_dim,
                              rope_theta=rope_theta)
    c_kv, k_rope = _latents(params, x, kv_rank=kv_rank, rope_dim=rope_dim)
    k_rope = apply_rope(k_rope[..., None, :], positions, rope_theta)  # [B,S,1,dr]
    k_nope = (c_kv @ params["wuk"]).reshape(B, S, H, nope_dim)
    v = (c_kv @ params["wuv"]).reshape(B, S, H, v_dim)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope_dim))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # MLA is effectively MHA (K == H groups of 1): reuse the inner impls
    # with K=H, G=1.  Scale uses the full (nope+rope) q/k dim.
    qg = q[:, :, :, None, :]  # [B,S,H,1,dh]
    scale = (nope_dim + rope_dim) ** -0.5
    if impl == "auto":
        impl = "chunked" if S > 2048 else "naive"
    if impl == "naive":
        o = _naive(qg, k, v, causal=causal, window=None, scale=scale)
    else:
        qb = min(q_block, S)
        o = _chunked(qg, k, v, causal=causal, window=None, scale=scale,
                     q_block=qb, kv_block=qb)
    o = o.reshape(B, S, H * v_dim)
    return o @ params["wo"]


def mla_decode(params, x, cache_ckv, cache_krope, cache_index, *,
               num_heads: int, kv_rank: int, nope_dim: int, rope_dim: int,
               v_dim: int, rope_theta: float = 10000.0):
    """Absorbed decode: cache only (c_kv, k_rope); scores in latent space.

    score_h(t) = q_nope_h · W_uk_h · c_kv(t) + q_rope_h · k_rope(t)
    out_h     = Σ_t p_h(t) · c_kv(t) · W_uv_h
    """
    B, one, _ = x.shape
    H = num_heads
    pos = jnp.full((B, 1), cache_index, jnp.int32)
    q_nope, q_rope = _queries(params, x, pos, num_heads=H, nope_dim=nope_dim,
                              rope_dim=rope_dim, rope_theta=rope_theta)
    c_kv, k_rope = _latents(params, x, kv_rank=kv_rank, rope_dim=rope_dim)
    k_rope = apply_rope(k_rope[..., None, :], pos, rope_theta)[..., 0, :]
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv.astype(cache_ckv.dtype), cache_index, axis=1)
    cache_krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, k_rope.astype(cache_krope.dtype), cache_index, axis=1)

    wuk = params["wuk"].reshape(kv_rank, H, nope_dim)
    # Absorb W_uk into q: q_lat [B,H,r]
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))
    s = jnp.einsum("bhr,bsr->bhs", q_lat, cache_ckv.astype(jnp.float32))
    s = s + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                       cache_krope.astype(jnp.float32))
    s = s * (nope_dim + rope_dim) ** -0.5
    mask = jnp.arange(cache_ckv.shape[1]) <= cache_index
    s = jnp.where(mask[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, cache_ckv.astype(jnp.float32))
    wuv = params["wuv"].reshape(kv_rank, H, v_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, wuv.astype(jnp.float32))
    o = o.astype(x.dtype).reshape(B, 1, H * v_dim)
    return o @ params["wo"], cache_ckv, cache_krope
