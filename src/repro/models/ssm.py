"""Mamba selective-SSM block (Jamba's sequence mixer, arXiv:2403.19887).

Training/prefill uses an associative scan (parallel prefix) over the
sequence; decode is an O(1) state update.  State per layer:
``h`` [B, d_inner, d_state] plus a depthwise-conv tail [B, K−1, d_inner].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, truncated_normal


def mamba_init(key, d_model: int, *, expand: int = 2, d_state: int = 16,
               d_conv: int = 4, dt_rank: int | None = None,
               dtype=jnp.float32):
    d_inner = expand * d_model
    dt_rank = dt_rank or max(16, d_model // 16)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A.
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_inner), dtype),
        "conv_w": truncated_normal(ks[1], (d_conv, d_inner), 0.5, dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], (d_inner, dt_rank + 2 * d_state), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_inner), dtype),
        "dt_bias": truncated_normal(ks[4], (d_inner,), 0.5, dtype),
        "a_log": jnp.log(a).astype(dtype),
        "d": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[5], (d_inner, d_model), dtype),
    }


def _ssm_params(params, xz, *, d_state: int, dt_rank: int):
    """Per-token Δ, B, C from the post-conv activations."""
    proj = xz @ params["x_proj"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ params["dt_proj"]
                         + params["dt_bias"])                 # [.., d_inner]
    b = proj[..., dt_rank:dt_rank + d_state]                   # [.., d_state]
    c = proj[..., dt_rank + d_state:]                          # [.., d_state]
    return dt, b, c


def _causal_conv(x, w, b):
    """Depthwise causal conv over seq. x [B,S,C]; w [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def mamba(params, x, *, expand: int = 2, d_state: int = 16, d_conv: int = 4,
          dt_rank: int | None = None):
    """Full-sequence forward via associative scan. x [B,S,d]."""
    B, S, d_model = x.shape
    d_inner = expand * d_model
    dt_rank = dt_rank or max(16, d_model // 16)
    xz = x @ params["in_proj"]
    xs, z = xz[..., :d_inner], xz[..., d_inner:]
    xs = jax.nn.silu(_causal_conv(xs, params["conv_w"], params["conv_b"]))
    dt, b, c = _ssm_params(params, xs, d_state=d_state, dt_rank=dt_rank)

    a = -jnp.exp(params["a_log"].astype(jnp.float32))          # [d_inner,ds]
    # Discretize: a_bar [B,S,d_inner,ds], b_bar·x [B,S,d_inner,ds]
    dta = dt.astype(jnp.float32)[..., None] * a                 # [B,S,di,ds]
    a_bar = jnp.exp(dta)
    bx = (dt * xs).astype(jnp.float32)[..., None] * b.astype(jnp.float32)[..., None, :]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, c.astype(jnp.float32))
    y = y.astype(x.dtype) + params["d"] * xs
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"]


def mamba_decode(params, x, state, *, expand: int = 2, d_state: int = 16,
                 d_conv: int = 4, dt_rank: int | None = None):
    """Single-token step. x [B,1,d]; state dict {h, conv}."""
    B, one, d_model = x.shape
    d_inner = expand * d_model
    dt_rank = dt_rank or max(16, d_model // 16)
    xz = x[:, 0] @ params["in_proj"]
    xs, z = xz[..., :d_inner], xz[..., d_inner:]
    conv = jnp.concatenate([state["conv"], xs[:, None]], axis=1)  # [B,K,di]
    xs = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv, params["conv_w"])
                     + params["conv_b"])
    new_conv = conv[:, 1:]
    dt, b, c = _ssm_params(params, xs, d_state=d_state, dt_rank=dt_rank)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    a_bar = jnp.exp(dt.astype(jnp.float32)[..., None] * a)       # [B,di,ds]
    bx = (dt * xs).astype(jnp.float32)[..., None] * b.astype(jnp.float32)[..., None, :]
    h = state["h"] * a_bar + bx
    y = jnp.einsum("bdn,bn->bd", h, c.astype(jnp.float32)).astype(x.dtype)
    y = (y + params["d"] * xs) * jax.nn.silu(z)
    return (y @ params["out_proj"])[:, None], {"h": h, "conv": new_conv}


def mamba_init_state(batch: int, d_model: int, *, expand: int = 2,
                     d_state: int = 16, d_conv: int = 4, dtype=jnp.float32):
    d_inner = expand * d_model
    return {"h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype)}
