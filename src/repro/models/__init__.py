"""Model substrate: composable JAX layer definitions for all assigned
architectures (dense GQA, MLA, sliding-window, MoE, Mamba, xLSTM, encoder,
VLM/audio backbones) plus KV/SSM caches for decode."""
from . import attention, blocks, common, ffn, kvcache, mla, model, moe, ssm, xlstm  # noqa: F401
