"""Dense feed-forward sublayers: SwiGLU (llama-family) and GeLU MLP
(paper's MoE-GPT experts, HuBERT)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], (d_model, d_ff), dtype),
        "wi": dense_init(ks[1], (d_model, d_ff), dtype),
        "wo": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def swiglu(params, x):
    g = jax.nn.silu(x @ params["wg"])
    return (g * (x @ params["wi"])) @ params["wo"]


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "wi": dense_init(ks[0], (d_model, d_ff), dtype),
        "wo": dense_init(ks[1], (d_ff, d_model), dtype),
    }


def gelu_mlp(params, x):
    return jax.nn.gelu(x @ params["wi"]) @ params["wo"]


def ffn_init(key, kind: str, d_model: int, d_ff: int, dtype=jnp.float32):
    if kind == "swiglu":
        return swiglu_init(key, d_model, d_ff, dtype)
    if kind == "gelu":
        return gelu_mlp_init(key, d_model, d_ff, dtype)
    raise ValueError(kind)


def ffn_apply(kind: str, params, x):
    return swiglu(params, x) if kind == "swiglu" else gelu_mlp(params, x)
