"""Dense feed-forward sublayers: SwiGLU (llama-family) and GeLU MLP
(paper's MoE-GPT experts, HuBERT)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], (d_model, d_ff), dtype),
        "wi": dense_init(ks[1], (d_model, d_ff), dtype),
        "wo": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def swiglu(params, x, *, use_pallas: bool = False):
    if use_pallas:
        # Fused Pallas epilogue (one HBM read of x for both projections);
        # a dense layer is the G=1, fully-occupied case of the ragged MoE
        # kernels.  Only safe outside pjit-partitioned meshes.
        from repro.kernels import ops
        shape = x.shape
        xf = x.reshape(1, -1, shape[-1])
        gs = jnp.full((1, 1), xf.shape[1], jnp.int32)
        h = ops.gmm_swiglu(xf, params["wg"][None], params["wi"][None], gs)
        return ops.ragged_gmm(h, params["wo"][None], gs).reshape(shape)
    g = jax.nn.silu(x @ params["wg"])
    return (g * (x @ params["wi"])) @ params["wo"]


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "wi": dense_init(ks[0], (d_model, d_ff), dtype),
        "wo": dense_init(ks[1], (d_ff, d_model), dtype),
    }


def gelu_mlp(params, x):
    return jax.nn.gelu(x @ params["wi"]) @ params["wo"]


def ffn_init(key, kind: str, d_model: int, d_ff: int, dtype=jnp.float32):
    if kind == "swiglu":
        return swiglu_init(key, d_model, d_ff, dtype)
    if kind == "gelu":
        return gelu_mlp_init(key, d_model, d_ff, dtype)
    raise ValueError(kind)


def ffn_apply(kind: str, params, x, *, use_pallas: bool = False):
    if kind == "swiglu":
        return swiglu(params, x, use_pallas=use_pallas)
    return gelu_mlp(params, x)
