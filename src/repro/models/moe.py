"""Expert-parallel MoE layer with Pro-Prophet lightweight placements.

Layout (DESIGN.md §6):
  * experts sharded over the ``model`` axis (EP groups of size 16),
  * each expert's matrices FSDP-sharded over ``data`` (and ``pod``) —
    gathered at use, reduce-scattered in backward (ZeRO-3 style),
  * tokens flattened and sharded over all mesh axes; dispatch is
    capacity-bucketed sort-based (no [N, E, C] one-hot), moved by a single
    tiled ``all_to_all`` over the EP axis.

Pro-Prophet integration (the paper's primitives, traced):
  * ``Trans``  — shadow-slot parameters materialized by a masked ``psum``
    over the EP axis (owner contributes, everyone receives).  Static
    ``s_max`` slots; selection is dynamic (``shadow_idx``).
  * shadow compute — tokens routed to a shadowed expert on a device inside
    its placement subset are computed locally and *excluded* from the a2a.
  * ``Agg``   — falls out of autodiff: the vjp of the masked psum delivers
    each shadow replica's parameter gradient back to the owner.

Chunked a2a↔FEC pipelining (paper §V, realized on-device): the expert
path optionally splits its ``[E, C, d]`` capacity buffer into K chunks
along the capacity axis.  Each chunk's send ``all_to_all``, ragged FEC,
and return ``all_to_all`` carry **no cross-chunk data dependencies**, so
XLA's async collective scheduler overlaps a2a(chunk k+1) with
expert_ffn(chunk k) — forward and, through autodiff, backward.  The
shadow ``Trans`` psum is hoisted ahead of the a2a path (and its ``Agg``
cotangent correspondingly trails the backward chunks) so the shadow
collective rides under the first chunk instead of serializing with it.
K comes from the engine's scheduler timeline on profiled stats
(``ProProphetEngine.chunk_plan``; ``REPRO_A2A_CHUNKS`` overrides); K=1
reproduces the unchunked path bit-identically.  Per-chunk occupancies
are threaded as ``group_sizes`` into the ragged Pallas kernels so tile
skipping still applies chunk-locally.

Token permutation (``REPRO_DISPATCH_PALLAS``, default on for TPU): the
two data-dependent permutes around the expert FFN — ``capacity_dispatch``
into the ``[E, C, d]`` buffer and the gate-weighted ``capacity_combine``
out of it — run through the Pallas kernels in
:mod:`repro.kernels.token_permute`.  Dispatch inverts the
``(bucket, pos)`` layout into a per-slot source map and becomes a
sorted *gather* (one read of the tokens, one write of the buffer — no
``[N·k, d]`` activation repeat, no serialized ``.at[].add``); combine
fuses the k-way gate reduction into the gather epilogue with f32
register accumulation (the ``[N, k, d]`` gather is never materialized,
let alone upcast to f32).  Both produce the *identical* slot layout,
so the chunked pipeline's per-chunk capacity slices ``[lo, hi)`` and
``chunk_occupancy`` are unchanged for any K.  The flag-off path is the
original jnp scatter/gather, bit-identical to the pre-kernel layer;
the perfmodel prices both legs (``PerfModel.t_dispatch``/``t_combine``)
and ``benchmarks/dispatch.py`` sweeps the modeled traffic.

All collectives are conditional on axis names so the same code runs
single-device (axis=None ⇒ identity) for CPU smoke tests.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import dense_init
from .ffn import ffn_init

# ---------------------------------------------------------------------------
# Router (runs in pjit land, outside shard_map)
# ---------------------------------------------------------------------------

def router_init(key, d_model: int, num_experts: int, dtype=jnp.float32):
    return {"w": dense_init(key, (d_model, num_experts), dtype)}


def router_topk(params, x, k: int, *, renormalize: bool = True):
    """x [..., d] → (gate [..., k] f32, idx [..., k] i32, probs [..., E])."""
    logits = (x.astype(jnp.float32) @ params["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    if renormalize:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return gate, idx.astype(jnp.int32), probs


def load_balance_loss(probs, idx, num_experts: int):
    """Switch-style aux loss — OFF by default (Pro-Prophet is system-level
    and must not perturb convergence); exposed for ablations.

    The dispatch-fraction term counts **all** top-k selections (normalized
    by k via the mean over the flattened ``[..., k]`` dims), not just the
    first choice — for ``top_k > 1`` the k-th selections drive real a2a
    load and must shape the loss."""
    me = probs.mean(axis=tuple(range(probs.ndim - 1)))
    onehot = jax.nn.one_hot(idx, num_experts)          # [..., k, E]
    ce = onehot.mean(axis=tuple(range(onehot.ndim - 1)))
    return num_experts * jnp.sum(me * ce)


# ---------------------------------------------------------------------------
# Sort-based capacity dispatch / combine
# ---------------------------------------------------------------------------

def capacity_positions(expert: jnp.ndarray, num_buckets: int):
    """Position of each (token, choice) within its expert bucket.

    expert: int32 [Nk] bucket ids in [0, num_buckets] (the top value is
    the drop sentinel).  Returns pos int32 [Nk] — 0-based arrival order
    within the bucket.

    Within-bucket ranks come from one stable argsort plus a cumsum'd
    histogram (position in sorted order minus the bucket's start): the
    second O(Nk log Nk) pass the old ``searchsorted(sorted, sorted)``
    formulation paid is gone, and the result is exactly equal (oracle
    test in tests/test_token_permute.py).
    """
    nk = expert.shape[0]
    hist = jnp.zeros((num_buckets + 1,), jnp.int32).at[expert].add(
        1, mode="drop")
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(hist)[:-1]])
    order = jnp.argsort(expert, stable=True)
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[expert[order]]
    return jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted)


def capacity_dispatch(xf, expert, capacity: int, num_buckets: int, *,
                      use_pallas: bool = False):
    """Scatter tokens into [num_buckets, capacity, d] (drop over capacity
    and sentinel buckets).  expert [N, k]; xf [N, d].

    ``use_pallas`` (REPRO_DISPATCH_PALLAS) routes through the
    token-permutation kernel (repro.kernels.token_permute): a sorted
    gather over the same (bucket, pos) slot layout — bit-identical
    buffer, no [N·k, d] repeat, no serialized scatter-add."""
    N, k = expert.shape
    d = xf.shape[-1]
    flat_e = expert.reshape(-1)
    pos = capacity_positions(flat_e, num_buckets)
    if use_pallas:
        from repro.kernels import ops
        buf = ops.dispatch_tokens(xf, expert, pos.reshape(N, k),
                                  num_buckets=num_buckets,
                                  capacity=capacity)
        return buf, pos.reshape(N, k)
    xrep = jnp.repeat(xf[:, None], k, axis=1).reshape(N * k, d)
    buf = jnp.zeros((num_buckets, capacity, d), xf.dtype)
    buf = buf.at[flat_e, pos].add(xrep, mode="drop")
    return buf, pos.reshape(N, k)


def capacity_combine(buf, expert, pos, gate, *, use_pallas: bool = False):
    """Gather per-(token, choice) outputs and gate-combine. buf [G,C,d].

    ``use_pallas`` fuses the gate-weighted k-way reduction into the
    gather epilogue (f32 register accumulation) instead of
    materializing — and upcasting — the [N, k, d] gather."""
    if use_pallas:
        from repro.kernels import ops
        return ops.combine_tokens(buf, expert, pos, gate)
    vals = buf.at[expert, pos].get(mode="fill", fill_value=0)  # [N,k,d]
    return jnp.einsum("nkd,nk->nd", vals.astype(jnp.float32),
                      gate.astype(jnp.float32)).astype(buf.dtype)


def kept_counts(expert, num_buckets: int, cap: int):
    """[num_buckets] occupied slots per bucket after capacity clamping —
    dispatch fills a contiguous prefix of each bucket, so these are both
    the ragged kernels' group_sizes and the kept-token telemetry.
    ``expert`` may carry the sentinel id == num_buckets (dropped)."""
    hist = jnp.zeros((num_buckets + 1,), jnp.int32).at[
        expert.reshape(-1)].add(1, mode="drop")[:num_buckets]
    return jnp.minimum(hist, cap)


# ---------------------------------------------------------------------------
# Grouped expert FFN
# ---------------------------------------------------------------------------

def gmm(x, w):
    """Grouped matmul [G,T,d]×[G,d,f] → [G,T,f] (jnp baseline; the Pallas
    TPU kernel in repro.kernels implements the same contract)."""
    return jnp.einsum("gtd,gdf->gtf", x, w)


def expert_ffn(kind: str, x, wi, wo, wg=None, *, group_sizes=None,
               seg_len: Optional[int] = None, use_pallas: bool = False):
    """x [G,T,d] → [G,T,d] through each group's expert.

    With ``use_pallas`` and per-group occupancy ``group_sizes`` ([G] or
    [G, S] with S segments of ``seg_len`` rows — the post-a2a peer
    layout), both matmuls run through the ragged Pallas kernels: MXU
    work ∝ actual tokens per expert instead of the full capacity buffer,
    and the SwiGLU gate is fused into the first kernel's epilogue.
    """
    if use_pallas and group_sizes is not None:
        from repro.kernels import ops
        if kind == "swiglu":
            h = ops.gmm_swiglu(x, wg, wi, group_sizes, seg_len=seg_len)
        else:  # gelu
            h = jax.nn.gelu(ops.ragged_gmm(x, wi, group_sizes,
                                           seg_len=seg_len))
        return ops.ragged_gmm(h, wo, group_sizes, seg_len=seg_len)
    if kind == "swiglu":
        h = jax.nn.silu(gmm(x, wg)) * gmm(x, wi)
    else:  # gelu
        h = jax.nn.gelu(gmm(x, wi))
    return gmm(h, wo)


# ---------------------------------------------------------------------------
# The expert-parallel inner function (runs under shard_map, or directly in
# single-device mode with all axis names None).
# ---------------------------------------------------------------------------

def _gather_weight(w, dims_axes):
    """all_gather ``w`` along (dim, axis) pairs; identity for axis=None."""
    for dim, axis in dims_axes:
        if axis is not None:
            w = jax.lax.all_gather(w, axis, axis=dim, tiled=True)
    return w


def _psum(x, axes):
    for ax in axes:
        if ax is not None:
            x = jax.lax.psum(x, ax)
    return x


def _trans_weights(onehot, shards, fulls, *, ep_axis, fsdp_axis, pod_axis):
    """The ``Trans`` primitive for all expert matrices at once: owners
    contribute their expert params into the shadow slots, one psum over
    the EP axis materializes them everywhere (autodiff of this psum is
    ``Agg``).  ``shards``/``fulls`` are (wi, wg, wo) tuples of the local
    FSDP shards and the gathered weights; entries may be None (no gate).

    With ``REPRO_TRANS_SHARDED`` (beyond-paper §Perf) the psum runs on
    the FSDP *shards* and the gather happens after — the EP-axis
    all-reduce moves 1/fsdp of the bytes.
    """
    from repro import flags
    # (einsum spec, gather (dim, axis) pairs) per matrix: wi/wg are
    # [E, d, f] (gather f over fsdp, d over pod); wo is [E, f, d].
    plans = (("se,edf->sdf", [(2, fsdp_axis), (1, pod_axis)]),   # wi
             ("se,edf->sdf", [(2, fsdp_axis), (1, pod_axis)]),   # wg
             ("se,efd->sfd", [(1, fsdp_axis), (2, pod_axis)]))   # wo
    out = []
    for (spec, gather), shard, full in zip(plans, shards, fulls):
        if full is None:
            out.append(None)
        elif flags.trans_sharded():
            out.append(_gather_weight(
                _psum(jnp.einsum(spec, onehot.astype(shard.dtype), shard),
                      [ep_axis]), gather))
        else:
            out.append(_psum(jnp.einsum(spec, onehot.astype(full.dtype),
                                        full), [ep_axis]))
    return tuple(out)


def _chunk_bounds(capacity: int, num_chunks: int):
    """Static [lo, hi) ranges splitting the capacity axis into exactly
    ``min(num_chunks, capacity)`` balanced chunks (sizes differ by at
    most one row) — the device always runs the K the chooser scored and
    the telemetry reports, and the sizes stay as close to the timeline's
    equal-chunk model as integer rows allow."""
    k = max(1, min(int(num_chunks), capacity))
    edges = [(i * capacity) // k for i in range(k + 1)]
    return list(zip(edges, edges[1:]))


def moe_inner(xf, gate, idx, wi, wg, wo, shadow_idx, shadow_valid,
              shadow_devs, expert_slot, *, num_experts: int, capacity: int,
              shadow_capacity: int, ffn_kind: str, ep_axis: Optional[str],
              fsdp_axis: Optional[str], pod_axis: Optional[str],
              s_max: int, use_pallas: bool = False, num_chunks: int = 1,
              permute_pallas: bool = False):
    """Expert-parallel MoE on local token shard.

    xf [T_loc, d]; gate/idx [T_loc, k]; wi/wg/wo local expert shards
    [E_loc, d, f/..]; shadow_* placement arrays (replicated);
    ``expert_slot`` int32 [E] — expert → physical weight slot (the
    engine's owner re-layout permutation; identity when nothing
    migrated).  Tokens are bucketed by *slot*, so the a2a destination is
    the expert's **current** owner instead of the implicit ``e // e_loc``
    home, and device ``me``'s local weight row ``j`` is expert
    ``slot_expert[me·e_loc + j]``.
    ``use_pallas`` routes both expert FFNs (a2a and shadow buffers)
    through the ragged Pallas kernels with the routing counts as
    group_sizes (REPRO_MOE_PALLAS; see repro.kernels.ragged_gmm).
    ``num_chunks`` splits the a2a path along the capacity axis into a
    dependency-free software pipeline (module docstring); 1 is the
    bit-identical serial path.
    ``permute_pallas`` routes the token permutation (capacity dispatch +
    gate combine, a2a and shadow buffers alike) through the Pallas
    kernels in repro.kernels.token_permute (REPRO_DISPATCH_PALLAS): the
    same (bucket, pos) slot layout — so per-chunk capacity slices and
    ``chunk_occupancy`` are unchanged — with the k× dispatch repeat and
    the [N, k, d] f32 combine blow-up gone.
    Returns (y [T_loc, d], counts [E] routing distribution of this EP
    member, dropped fraction scalar).
    """
    T, d = xf.shape
    k = idx.shape[-1]
    E = num_experts
    ep = 1 if ep_axis is None else jax.lax.psum(1, ep_axis)  # static int
    e_loc = E // ep
    me = 0 if ep_axis is None else jax.lax.axis_index(ep_axis)
    # slot lookup with the sentinel id E (padded tokens) mapping to the
    # sentinel (drop) bucket, and the inverse slot → expert permutation.
    slot_lut = jnp.concatenate([expert_slot.astype(jnp.int32),
                                jnp.array([E], jnp.int32)])
    slot_expert = jnp.zeros((E,), jnp.int32).at[expert_slot].set(
        jnp.arange(E, dtype=jnp.int32))
    tok_slot_a2a = slot_lut[idx]                                 # [T,k]

    # ---- gather FSDP-sharded expert weights (ZeRO-3 style) --------------
    gather_spec = [(2, fsdp_axis), (1, pod_axis)]
    wi_f = _gather_weight(wi, gather_spec)
    wo_f = _gather_weight(wo, [(1, fsdp_axis), (2, pod_axis)])
    wg_f = _gather_weight(wg, gather_spec) if wg is not None else None

    # ---- routing bookkeeping --------------------------------------------
    counts = jnp.zeros((E,), jnp.int32).at[idx.reshape(-1)].add(1, mode="drop")
    counts = _psum(counts, [fsdp_axis, pod_axis])

    # ---- shadow slot lookup ----------------------------------------------
    # slot_of[e] = slot index if expert e is shadowed *and this device is in
    # its placement subset*, else -1.  Padding slots carry idx == E.
    my_mask = shadow_devs[:, me] * shadow_valid                  # [s_max]
    slot_ids = jnp.where(my_mask > 0, jnp.arange(s_max, dtype=jnp.int32), -1)
    slot_of = jnp.full((E + 1,), -1, jnp.int32).at[shadow_idx].max(
        slot_ids, mode="drop")
    tok_slot = slot_of[jnp.clip(idx, 0, E)]                      # [T,k]
    use_local = tok_slot >= 0

    # ---- shadow Trans, hoisted off the a2a critical path -----------------
    # The psum depends only on placements and weights, so issuing it ahead
    # of the a2a chunks lets it overlap the first chunk's wire + FEC time
    # (and puts its Agg cotangent after the backward chunks).  The paper's
    # operator/blockwise strategies, on-device.
    if s_max > 0:
        # Experts this device owns = the experts in its slot range (the
        # identity arange before any migration).
        my_globals = slot_expert[me * e_loc + jnp.arange(e_loc)]  # [E_loc]
        onehot = (shadow_idx[:, None] == my_globals[None, :])
        onehot = (onehot * (shadow_valid[:, None] > 0)).astype(jnp.float32)
        sh_wi, sh_wg, sh_wo = _trans_weights(
            onehot, (wi, wg, wo), (wi_f, wg_f, wo_f), ep_axis=ep_axis,
            fsdp_axis=fsdp_axis, pod_axis=pod_axis)

    # ---- a2a path (chunked software pipeline) ----------------------------
    # Tokens are bucketed by *slot*, not expert id: the all_to_all lands
    # bucket s on device s // e_loc, i.e. on the expert's current owner.
    a2a_expert = jnp.where(use_local, E, tok_slot_a2a)           # sentinel ⇒ drop
    a2a_counts = kept_counts(a2a_expert, E, capacity)            # [E] per slot
    # num_buckets == E: the sentinel id E is out of range for both the
    # jnp scatter (mode="drop") and the kernel's slot plan, so sentinel
    # choices drop without allocating — or, on the Pallas path, gathering
    # and writing — a throwaway [1, C, d] bucket.
    buf, pos = capacity_dispatch(xf, a2a_expert, capacity, E,
                                 use_pallas=permute_pallas)      # [E,C,d]
    bounds = _chunk_bounds(capacity, num_chunks)
    if ep_axis is not None:
        # Each peer's segment of the recv buffer has its own occupancy:
        # gather everyone's counts once, slice per chunk below.
        gs_all = jax.lax.all_gather(a2a_counts, ep_axis)         # [ep, E]
    # No chunk's send/FEC/return depends on any other chunk, so XLA's
    # async scheduler can run all_to_all(chunk k+1) under the ragged FEC
    # of chunk k (and symmetrically on the return a2a / in the backward).
    from repro.kernels.ragged_gmm import chunk_occupancy
    recvs, sizes = [], []
    for lo, hi in bounds:
        chunk = jax.lax.slice_in_dim(buf, lo, hi, axis=1)        # [E,Ck,d]
        if ep_axis is not None:
            recvs.append(jax.lax.all_to_all(
                chunk, ep_axis, split_axis=0, concat_axis=1,
                tiled=True))                                     # [E_loc, ep*Ck, d]
            csz = chunk_occupancy(gs_all, lo, hi)                # [ep, E]
            sizes.append(jax.lax.dynamic_slice_in_dim(
                csz, me * e_loc, e_loc, axis=1).T)               # [E_loc, ep]
        else:
            recvs.append(chunk)
            sizes.append(chunk_occupancy(a2a_counts, lo, hi)[:, None])
    outs = []
    for (lo, hi), recv, recv_sizes in zip(bounds, recvs, sizes):
        hidden = expert_ffn(ffn_kind, recv, wi_f, wo_f, wg_f,
                            group_sizes=recv_sizes, seg_len=hi - lo,
                            use_pallas=use_pallas)
        if ep_axis is not None:
            hidden = jax.lax.all_to_all(hidden, ep_axis, split_axis=1,
                                        concat_axis=0, tiled=True)  # [E,Ck,d]
        outs.append(hidden)
    buf_out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    # Locally-computed choices carry the drop sentinel E (not a clamp to
    # bucket 0): their gates are zero either way, but the sentinel keeps
    # the (bucket, pos) pairs of *valid* choices unique — the contract
    # the sorted-gather dispatch in combine's backward inverts, and a
    # slot a zero-gate clamp could otherwise collide with.
    y = capacity_combine(buf_out, jnp.where(use_local, E, tok_slot_a2a),
                         pos, gate * (~use_local),
                         use_pallas=permute_pallas)

    # ---- Pro-Prophet shadow compute (weights already Trans'd above) ------
    if s_max > 0:
        s_expert = jnp.where(use_local, tok_slot, s_max)
        s_counts = kept_counts(s_expert, s_max, shadow_capacity)  # [s_max]
        sbuf, spos = capacity_dispatch(xf, s_expert, shadow_capacity,
                                       s_max,
                                       use_pallas=permute_pallas)
        s_hidden = expert_ffn(ffn_kind, sbuf, sh_wi, sh_wo, sh_wg,
                              group_sizes=s_counts[:, None],
                              seg_len=shadow_capacity,
                              use_pallas=use_pallas)
        y = y + capacity_combine(s_hidden,
                                 jnp.where(use_local, tok_slot, s_max),
                                 spos, gate * use_local,
                                 use_pallas=permute_pallas)

    # dropped-token fraction (over-capacity), for telemetry
    total = jnp.maximum(counts.sum(), 1)
    kept_a2a = a2a_counts.sum()
    kept_local = s_counts.sum() if s_max else 0
    kept = _psum(kept_a2a + kept_local, [fsdp_axis, pod_axis])
    dropped = 1.0 - kept.astype(jnp.float32) / total.astype(jnp.float32)
    # Rank-expand so shard_map out_specs can stack over the EP axis.
    return y, counts[None, :], dropped[None]


# ---------------------------------------------------------------------------
# Public layer API
# ---------------------------------------------------------------------------

def moe_init(key, d_model: int, d_expert: int, num_experts: int, *,
             ffn_kind: str = "swiglu", num_shared: int = 0,
             shared_d_ff: int = 0, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    nm = 3 if ffn_kind == "swiglu" else 2
    wkeys = jax.random.split(ks[0], num_experts)
    def stack(i, shape):
        return jnp.stack([dense_init(jax.random.fold_in(wkeys[e], i), shape,
                                     dtype) for e in range(num_experts)])
    p = {
        "router": router_init(ks[1], d_model, num_experts, dtype),
        "wi": stack(0, (d_model, d_expert)),
        "wo": stack(1, (d_expert, d_model)),
    }
    if ffn_kind == "swiglu":
        p["wg"] = stack(2, (d_model, d_expert))
    if num_shared:
        p["shared"] = ffn_init(ks[2], ffn_kind, d_model,
                               shared_d_ff or d_expert * num_shared, dtype)
    return p


def moe_apply(params, x, placement, ctx, *, num_experts: int, top_k: int,
              d_expert: int, ffn_kind: str = "swiglu",
              capacity_factor: float = 1.25,
              shadow_capacity_factor: float = 2.0, s_max: int = 8,
              a2a_chunks: int = 1):
    """x [B, S, d] → (y, aux dict with routing counts / drop frac).

    ``placement``: dict of placement arrays for THIS layer
    (shadow_idx [s_max] i32 — padded with ``num_experts``;
     shadow_valid [s_max] f32; shadow_devs [s_max, ep] f32;
     optionally expert_slot [E] i32 — the owner re-layout permutation,
     identity when absent) or None for plain EP.  ``ctx``: repro.parallel.ParallelCtx.  ``a2a_chunks``:
    static chunk count of the a2a↔FEC software pipeline (module
    docstring); ``REPRO_A2A_CHUNKS`` overrides, 1 ⇒ bit-identical
    serial path.  Like every ``REPRO_*`` flag the override is read at
    *trace* time: under a caller's jit it cannot retarget executables
    already cached for a given ``a2a_chunks`` — set it before the
    process jits (the trainer re-reads it per dispatch and re-keys the
    jit cache, so the CLI/engine path is exempt from this caveat).
    """
    B, S, d = x.shape
    gate, idx, probs = router_topk(params["router"], x, top_k)

    # One source of truth for the placement arrays' device width: the EP
    # axis size of the mesh the layer actually runs on.  The trainer
    # asserts the engine was built against the same width when it binds
    # engine to mesh (repro.train.trainer), so an engine/mesh divergence
    # fails loudly instead of silently mis-shaping the fallback arrays.
    ep_width = max(ctx.ep_size, 1)
    if placement is None:
        sidx = jnp.full((s_max,), num_experts, jnp.int32)
        svalid = jnp.zeros((s_max,), jnp.float32)
        sdevs = jnp.zeros((s_max, ep_width), jnp.float32)
        eslot = jnp.arange(num_experts, dtype=jnp.int32)
    else:
        sidx, svalid, sdevs = (placement["shadow_idx"],
                               placement["shadow_valid"],
                               placement["shadow_devs"])
        assert sdevs.shape[-1] == ep_width, (
            f"placement shadow_devs width {sdevs.shape[-1]} != EP size "
            f"{ep_width} — engine and mesh disagree on num_devices")
        eslot = placement.get("expert_slot")
        if eslot is None:   # pre-migration callers: identity layout
            eslot = jnp.arange(num_experts, dtype=jnp.int32)

    # Flatten tokens and shard over every mesh axis.
    T = B * S
    xf = x.reshape(T, d)
    gf = gate.reshape(T, top_k).astype(jnp.float32)
    ef = idx.reshape(T, top_k)
    pad = (-T) % max(ctx.num_devices, 1)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        gf = jnp.pad(gf, ((0, pad), (0, 0)))
        # sentinel expert id == E routes padded tokens to the drop bucket in
        # every dispatch path; their gates are zeroed as well.
        ef = jnp.pad(ef, ((0, pad), (0, 0)), constant_values=num_experts)
        gf = gf * (jnp.arange(T + pad) < T)[:, None]
    t_loc = (T + pad) // max(ctx.num_devices, 1)
    from repro import flags as _flags
    cf_override = _flags.capacity_factor_override()
    if cf_override is not None:
        capacity_factor = cf_override
    capacity = max(8, int(t_loc * top_k / num_experts * capacity_factor))
    shadow_capacity = max(8, int(t_loc * top_k / max(s_max, 1)
                                 * shadow_capacity_factor)) if s_max else 8

    num_chunks = _flags.a2a_chunks() or max(1, int(a2a_chunks))
    inner = functools.partial(
        moe_inner, num_experts=num_experts, capacity=capacity,
        shadow_capacity=shadow_capacity, ffn_kind=ffn_kind,
        ep_axis=ctx.ep_axis, fsdp_axis=ctx.fsdp_axis, pod_axis=ctx.pod_axis,
        s_max=s_max, use_pallas=_flags.moe_pallas(), num_chunks=num_chunks,
        permute_pallas=_flags.dispatch_pallas())

    wg = params.get("wg")
    if ctx.mesh is None:
        y, counts, dropped = inner(xf, gf, ef, params["wi"], wg, params["wo"],
                                   sidx, svalid, sdevs, eslot)
    else:
        from jax.experimental.shard_map import shard_map
        all_axes = ctx.all_axes  # e.g. ("pod","data","model")
        tok_spec = P(all_axes, None)
        w_spec = P(ctx.ep_axis, ctx.pod_axis, ctx.fsdp_axis)
        wo_spec = P(ctx.ep_axis, ctx.fsdp_axis, ctx.pod_axis)
        f = shard_map(
            inner, mesh=ctx.mesh,
            in_specs=(tok_spec, tok_spec, tok_spec, w_spec,
                      None if wg is None else w_spec, wo_spec,
                      P(None), P(None), P(None), P(None)),
            out_specs=(tok_spec, P(ctx.ep_axis, None), P(ctx.ep_axis)),
            check_rep=False)
        y, counts, dropped = f(xf, gf, ef, params["wi"], wg, params["wo"],
                               sidx, svalid, sdevs, eslot)
    dropped = jnp.mean(dropped)

    y = y[:T].reshape(B, S, d).astype(x.dtype)
    if "shared" in params:
        from .ffn import ffn_apply
        y = y + ffn_apply(ffn_kind, params["shared"], x,
                          use_pallas=_flags.moe_pallas() and ctx.mesh is None)
    aux = {"counts": counts, "dropped": dropped,
           "probs_entropy": -jnp.mean(jnp.sum(
               probs * jnp.log(probs + 1e-9), axis=-1))}
    return y, aux
