"""Shared building blocks: norms, RoPE, embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def dense_init(key, shape, dtype=jnp.float32, fan_in: int | None = None):
    """LeCun-normal style init; fan_in defaults to shape[-2]."""
    fi = fan_in if fan_in is not None else (shape[-2] if len(shape) >= 2 else shape[-1])
    return truncated_normal(key, shape, stddev=1.0 / np.sqrt(fi), dtype=dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    from repro import flags
    if flags.norm_bf16() and dt == jnp.bfloat16:
        # §Perf collective lever: no f32 x-shaped island — the variance is
        # f32-accumulated from bf16 reads, the normalization stays bf16,
        # so delayed TP all-reduces of the backward move bf16 tensors.
        d = x.shape[-1]
        var = jnp.einsum("...d,...d->...", x, x,
                         preferred_element_type=jnp.float32)[..., None] / d
        y = x * jax.lax.rsqrt(var + eps).astype(dt)
        return y * params["scale"].astype(dt)
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, Dh]; positions: [..., S] int32."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta))  # [Dh/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    # 1/sqrt(d) keeps tied-unembedding logits O(1) after the final RMSNorm.
    return {"table": truncated_normal(key, (vocab, d_model),
                                      1.0 / np.sqrt(d_model), dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    """Tied unembedding: logits in f32 for a stable softmax/xent."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))


def chunked_unembed_xent(x, table, labels, chunk: int, mask=None):
    """Streaming tied-unembedding cross entropy (§Perf memory lever).

    Never materializes the [B, S, V] logits: scans the vocab in chunks of
    ``chunk`` rows, maintaining an online logsumexp and picking the gold
    logit on the fly.  x [B,S,d]; table [V,d]; labels int [B,S]."""
    V, d = table.shape
    pad = (-V) % chunk
    tbl = jnp.pad(table, ((0, pad), (0, 0))) if pad else table
    nc = tbl.shape[0] // chunk
    x32 = x.astype(jnp.float32)

    def body(carry, ci):
        m, l, gold = carry
        rows = jax.lax.dynamic_slice_in_dim(tbl, ci * chunk, chunk, 0)
        logits = jnp.einsum("bsd,vd->bsv", x32, rows.astype(jnp.float32))
        valid = ci * chunk + jnp.arange(chunk) < V
        logits = jnp.where(valid, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        l = l * jnp.exp(m - m_new) + jnp.exp(logits - m_new[..., None]).sum(-1)
        loc = labels - ci * chunk
        in_rng = (loc >= 0) & (loc < chunk)
        gold_c = jnp.take_along_axis(
            logits, jnp.clip(loc, 0, chunk - 1)[..., None], axis=-1)[..., 0]
        gold = gold + jnp.where(in_rng, gold_c, 0.0)
        return (m_new, l, gold), None

    B, S, _ = x.shape
    init = (jnp.full((B, S), -1e30, jnp.float32),
            jnp.zeros((B, S), jnp.float32),
            jnp.zeros((B, S), jnp.float32))
    (m, l, gold), _ = jax.lax.scan(body, init, jnp.arange(nc))
    nll = m + jnp.log(jnp.maximum(l, 1e-30)) - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def cross_entropy_loss(logits, labels, mask=None):
    """Token-level cross entropy; logits [..., V] f32, labels int."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
