"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent).

mLSTM training uses the stabilized quadratic (attention-like) form for
short sequences and a chunked recurrent scan for long ones; decode is an
O(1) matrix-memory update.  sLSTM is a lax.scan over time with
block-diagonal recurrent weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, num_heads: int, *, expand: int = 2,
               dtype=jnp.float32):
    d_inner = expand * d_model
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], (d_model, 2 * d_inner), dtype),
        "wq": dense_init(ks[1], (d_inner, d_inner), dtype),
        "wk": dense_init(ks[2], (d_inner, d_inner), dtype),
        "wv": dense_init(ks[3], (d_inner, d_inner), dtype),
        "w_if": dense_init(ks[4], (d_inner, 2 * num_heads), dtype),
        "norm": rmsnorm_init(d_inner, dtype),
        "down_proj": dense_init(ks[5], (d_inner, d_model), dtype),
    }


def _mlstm_qkvif(params, xs, num_heads):
    B, S, d_inner = xs.shape
    dh = d_inner // num_heads
    q = (xs @ params["wq"]).reshape(B, S, num_heads, dh)
    k = (xs @ params["wk"]).reshape(B, S, num_heads, dh) * dh ** -0.5
    v = (xs @ params["wv"]).reshape(B, S, num_heads, dh)
    gates = (xs @ params["w_if"]).reshape(B, S, num_heads, 2).astype(jnp.float32)
    log_i = -jax.nn.softplus(-gates[..., 0])      # log σ(i)
    log_f = -jax.nn.softplus(-gates[..., 1])      # log σ(f)
    return q, k, v, log_i, log_f


def mlstm_parallel(params, x, *, num_heads: int, expand: int = 2):
    """Stabilized quadratic form — O(S²) scores, for short sequences."""
    B, S, _ = x.shape
    up = x @ params["up_proj"]
    d_inner = up.shape[-1] // 2
    xs, z = up[..., :d_inner], up[..., d_inner:]
    q, k, v, log_i, log_f = _mlstm_qkvif(params, xs, num_heads)
    F = jnp.cumsum(log_f, axis=1)                                 # [B,S,H]
    # log D[t,s] = F_t − F_s + log i_s  (s ≤ t)
    logd_ts = F[:, :, None, :] - F[:, None, :, :] + log_i[:, None, :, :]
    logd = jnp.where(jnp.arange(S)[:, None] >= jnp.arange(S)[None, :],
                     logd_ts.transpose(0, 3, 1, 2), NEG_INF)      # [B,H,S,S]
    m = jnp.max(logd, axis=-1, keepdims=True)
    d = jnp.exp(logd - jnp.maximum(m, 0.0))
    s = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    num = jnp.einsum("bhts,bhts,bshd->bthd", s, d, v.astype(jnp.float32))
    den = jnp.abs(jnp.einsum("bhts,bhts->bth", s, d))
    den = jnp.maximum(den, jnp.exp(-jnp.maximum(m, 0.0))[..., 0].transpose(0, 2, 1))
    h = (num / den[..., None]).astype(x.dtype)
    h = h.reshape(B, S, -1)
    h = rmsnorm(params["norm"], h) * jax.nn.silu(z)
    return h @ params["down_proj"]


def mlstm_recurrent(params, x, *, num_heads: int, expand: int = 2):
    """lax.scan over time — O(S) memory, for long sequences/prefill."""
    B, S, _ = x.shape
    up = x @ params["up_proj"]
    d_inner = up.shape[-1] // 2
    xs, z = up[..., :d_inner], up[..., d_inner:]
    q, k, v, log_i, log_f = _mlstm_qkvif(params, xs, num_heads)
    dh = d_inner // num_heads

    def step(carry, inp):
        C, n, m = carry                     # [B,H,dh,dh], [B,H,dh], [B,H]
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        fa = jnp.exp(lf + m - m_new)[..., None]
        ia = jnp.exp(li - m_new)[..., None]
        C = C * fa[..., None] + ia[..., None] * (kt[..., :, None] *
                                                 vt[..., None, :])
        n = n * fa + ia * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    init = (jnp.zeros((B, num_heads, dh, dh), jnp.float32),
            jnp.zeros((B, num_heads, dh), jnp.float32),
            jnp.full((B, num_heads), NEG_INF, jnp.float32))
    xsT = lambda a: jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    _, hs = jax.lax.scan(step, init, (xsT(q), xsT(k), xsT(v),
                                      xsT(log_i), xsT(log_f)))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype).reshape(B, S, -1)
    h = rmsnorm(params["norm"], h) * jax.nn.silu(z)
    return h @ params["down_proj"]


def mlstm(params, x, *, num_heads: int, expand: int = 2, impl: str = "auto"):
    if impl == "auto":
        impl = "parallel" if x.shape[1] <= 1024 else "recurrent"
    fn = mlstm_parallel if impl == "parallel" else mlstm_recurrent
    return fn(params, x, num_heads=num_heads, expand=expand)


def mlstm_decode(params, x, state, *, num_heads: int, expand: int = 2):
    """x [B,1,d]; state {C,n,m}."""
    B = x.shape[0]
    up = x[:, 0] @ params["up_proj"]
    d_inner = up.shape[-1] // 2
    xs, z = up[..., :d_inner], up[..., d_inner:]
    q, k, v, log_i, log_f = _mlstm_qkvif(params, xs[:, None], num_heads)
    qt, kt, vt = q[:, 0], k[:, 0], v[:, 0]
    li, lf = log_i[:, 0], log_f[:, 0]
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fa = jnp.exp(lf + m - m_new)[..., None]
    ia = jnp.exp(li - m_new)[..., None]
    C = C * fa[..., None] + ia[..., None] * (kt.astype(jnp.float32)[..., :, None]
                                             * vt.astype(jnp.float32)[..., None, :])
    n = n * fa + ia * kt.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qt.astype(jnp.float32), C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh",
                                         qt.astype(jnp.float32), n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(x.dtype).reshape(B, -1)
    h = rmsnorm(params["norm"], h) * jax.nn.silu(z)
    return (h @ params["down_proj"])[:, None], {"C": C, "n": n, "m": m_new}


def mlstm_init_state(batch: int, d_model: int, num_heads: int, *,
                     expand: int = 2):
    d_inner = expand * d_model
    dh = d_inner // num_heads
    return {"C": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, num_heads, dh), jnp.float32),
            "m": jnp.full((batch, num_heads), NEG_INF, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, num_heads: int, dtype=jnp.float32):
    dh = d_model // num_heads
    ks = jax.random.split(key, 3)
    return {
        # 4 gates (i, f, z, o) from input and block-diagonal recurrence.
        "w_in": dense_init(ks[0], (d_model, 4 * d_model), dtype),
        "r": dense_init(ks[1], (num_heads, dh, 4 * dh), dtype, fan_in=dh),
        "norm": rmsnorm_init(d_model, dtype),
        "out": dense_init(ks[2], (d_model, d_model), dtype),
    }


def _slstm_scan(params, x_gates, h0, c0, n0, m0, num_heads):
    """x_gates [B,S,4d] precomputed input contributions."""
    B, S, _ = x_gates.shape
    d_model = x_gates.shape[-1] // 4
    dh = d_model // num_heads

    def step(carry, xt):
        h, c, n, m = carry                       # h [B,H,dh] etc.
        rec = jnp.einsum("bhd,hde->bhe", h, params["r"].astype(jnp.float32))
        g = xt.reshape(B, num_heads, 4 * dh).astype(jnp.float32) + rec
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        log_i = -jax.nn.softplus(-gi)
        log_f = -jax.nn.softplus(-gf)
        m_new = jnp.maximum(log_f + m, log_i)
        i_a = jnp.exp(log_i - m_new)
        f_a = jnp.exp(log_f + m - m_new)
        c = f_a * c + i_a * jnp.tanh(gz)
        n = f_a * n + i_a
        h_new = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
        return (h_new, c, n, m_new), h_new

    init = (h0, c0, n0, m0)
    (_, c, n, m), hs = jax.lax.scan(step, init,
                                    jnp.moveaxis(x_gates, 1, 0))
    return jnp.moveaxis(hs, 0, 1), (c, n, m)


def slstm(params, x, *, num_heads: int):
    B, S, d_model = x.shape
    dh = d_model // num_heads
    xg = x @ params["w_in"]
    h0 = jnp.zeros((B, num_heads, dh), jnp.float32)
    c0 = jnp.zeros_like(h0)
    n0 = jnp.zeros_like(h0)
    m0 = jnp.full((B, num_heads, dh), NEG_INF, jnp.float32)
    hs, _ = _slstm_scan(params, xg, h0, c0, n0, m0, num_heads)
    y = rmsnorm(params["norm"], hs.reshape(B, S, d_model).astype(x.dtype))
    return y @ params["out"]


def slstm_decode(params, x, state, *, num_heads: int):
    """x [B,1,d]; state {h,c,n,m}."""
    B, _, d_model = x.shape
    xg = x @ params["w_in"]
    hs, (c, n, m) = _slstm_scan(params, xg, state["h"], state["c"],
                                state["n"], state["m"], num_heads)
    dh = d_model // num_heads
    h_new = hs[:, -1].reshape(B, num_heads, dh)
    y = rmsnorm(params["norm"], hs.reshape(B, 1, d_model).astype(x.dtype))
    return y @ params["out"], {"h": h_new, "c": c, "n": n, "m": m}


def slstm_init_state(batch: int, d_model: int, num_heads: int):
    dh = d_model // num_heads
    z = jnp.zeros((batch, num_heads, dh), jnp.float32)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((batch, num_heads, dh), NEG_INF, jnp.float32)}
