from .adamw import adamw, apply_updates, clip_by_global_norm
from .schedule import constant, cosine, linear_warmup, wsd

__all__ = ["adamw", "apply_updates", "clip_by_global_norm", "constant",
           "cosine", "linear_warmup", "wsd"]
