"""LR schedules: linear warmup, cosine, constant, and WSD
(Warmup-Stable-Decay, MiniCPM arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(peak: float, warmup_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return peak * jnp.minimum(1.0, s / max(warmup_steps, 1))
    return fn


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine(peak: float, warmup_steps: int, total_steps: int,
           final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup_steps, 1))
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak * warm * cos
    return fn


def wsd(peak: float, warmup_steps: int, stable_steps: int, decay_steps: int,
        final_frac: float = 0.01):
    """Warmup → Stable (constant peak) → Decay (exponential-ish linear).

    MiniCPM's schedule: the stable phase allows continual data mixing; the
    short decay phase recovers the cosine's final loss."""
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup_steps, 1))
        in_decay = jnp.clip((s - warmup_steps - stable_steps)
                            / max(decay_steps, 1), 0.0, 1.0)
        decay = final_frac ** in_decay   # exp decay from 1 → final_frac
        return peak * warm * decay
    return fn
