"""AdamW with decoupled weight decay and global-norm clipping.

Self-contained (no optax in this environment).  The optimizer is a pair of
pure functions over pytrees; state dtype is configurable so the big-MoE
dry-runs can use bf16 moments (see EXPERIMENTS.md memory notes).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray     # scalar int32
    mu: object            # pytree like params
    nu: object            # pytree like params


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Optional[jnp.dtype] = None   # None ⇒ follow param dtype

    def init(self, params) -> AdamWState:
        def zeros(p):
            dt = self.state_dtype or p.dtype
            return jnp.zeros(p.shape, dt)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        b1, b2 = self.b1, self.b2

        def upd_mu(m, g):
            return (b1 * m.astype(jnp.float32)
                    + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype)

        def upd_nu(v, g):
            g32 = g.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32)
                    + (1 - b2) * g32 * g32).astype(v.dtype)

        mu = jax.tree.map(upd_mu, state.mu, grads)
        nu = jax.tree.map(upd_nu, state.nu, grads)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.learning_rate(step)

        def delta(m, v, p):
            mh = m.astype(jnp.float32) / c1
            vh = v.astype(jnp.float32) / c2
            d = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # no decay on norms/bias
                d = d + self.weight_decay * p.astype(jnp.float32)
            return (-lr * d).astype(p.dtype)

        updates = jax.tree.map(delta, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


def adamw(learning_rate, **kw) -> AdamW:
    lr = learning_rate if callable(learning_rate) else (
        lambda step, v=learning_rate: jnp.asarray(v, jnp.float32))
    return AdamW(learning_rate=lr, **kw)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32)
                                   * scale).astype(g.dtype), grads)
