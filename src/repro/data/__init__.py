from .pipeline import SyntheticLM, make_batch_specs, synthetic_batch

__all__ = ["SyntheticLM", "make_batch_specs", "synthetic_batch"]
