"""Deterministic synthetic data pipeline.

Produces learnable token streams (noisy affine next-token structure over a
Zipfian marginal) so the end-to-end examples show real loss curves, plus
modality batches for the audio/vlm stubs.  Fully seeded and shardable: a
batch is a pure function of (seed, step), so every host can materialize its
slice independently — the multi-pod story for input data.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def _tokens(rng: np.random.Generator, batch: int, seq: int, vocab: int
            ) -> np.ndarray:
    """Markov-ish stream: next = (5·cur + drift) mod V with Zipf restarts."""
    restart = rng.zipf(1.5, size=(batch, seq)) % vocab
    toks = np.empty((batch, seq), np.int64)
    toks[:, 0] = restart[:, 0]
    drift = rng.integers(0, 7, size=(batch, seq))
    reset = rng.random((batch, seq)) < 0.1
    for t in range(1, seq):
        nxt = (5 * toks[:, t - 1] + drift[:, t]) % vocab
        toks[:, t] = np.where(reset[:, t], restart[:, t], nxt)
    return toks.astype(np.int32)


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, *, step: int,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """One batch as numpy (host) arrays; pure function of (seed, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    out: Dict[str, np.ndarray] = {}
    if cfg.modality == "audio":
        out["frame_embeds"] = rng.normal(
            0, 0.5, size=(batch, seq, cfg.d_model)).astype(np.float32)
        out["labels"] = rng.integers(0, cfg.vocab_size,
                                     size=(batch, seq)).astype(np.int32)
        mask = rng.random((batch, seq)) < 0.35      # HuBERT-style masking
        out["loss_mask"] = mask.astype(np.float32)
        # Masked positions get their embeddings zeroed (mask token).
        out["frame_embeds"] = out["frame_embeds"] * (~mask)[..., None]
        return out
    stream = _tokens(rng, batch, seq + 1, cfg.vocab_size)
    out["tokens"] = stream[:, :-1]
    out["labels"] = stream[:, 1:]
    if cfg.modality == "vlm":
        out["prefix_embeds"] = rng.normal(
            0, 0.5, size=(batch, cfg.num_prefix_tokens,
                          cfg.d_model)).astype(np.float32)
    return out


def make_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                     dtype=jnp.float32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins matching synthetic_batch (dry-run)."""
    sd = jax.ShapeDtypeStruct
    if cfg.modality == "audio":
        return {"frame_embeds": sd((batch, seq, cfg.d_model), dtype),
                "labels": sd((batch, seq), jnp.int32),
                "loss_mask": sd((batch, seq), jnp.float32)}
    out = {"tokens": sd((batch, seq), jnp.int32),
           "labels": sd((batch, seq), jnp.int32)}
    if cfg.modality == "vlm":
        out["prefix_embeds"] = sd((batch, cfg.num_prefix_tokens, cfg.d_model),
                                  dtype)
    return out


@dataclasses.dataclass
class SyntheticLM:
    """Iterator facade used by the trainer/examples."""

    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield synthetic_batch(self.cfg, self.batch, self.seq,
                                  step=step, seed=self.seed)
            step += 1

    def at_step(self, step: int) -> Dict[str, np.ndarray]:
        return synthetic_batch(self.cfg, self.batch, self.seq, step=step,
                               seed=self.seed)
