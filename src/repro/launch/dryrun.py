"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) combination with ShapeDtypeStruct stand-ins (no allocation), record
memory_analysis / cost_analysis / collective bytes for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # full sweep
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k --mesh single --out artifacts/dryrun
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data import make_batch_specs
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.optim import adamw, constant
from repro.optim.adamw import apply_updates
from repro.parallel import make_ctx, param_shardings, zero1_pspec
from repro.parallel.sharding import param_pspec
from repro.train.trainer import TrainState

ARCHS = [
    "paligemma-3b", "jamba-v0.1-52b", "xlstm-350m", "qwen3-moe-235b-a22b",
    "minicpm-2b", "gemma3-27b", "smollm-360m", "hubert-xlarge",
    "qwen2-1.5b", "deepseek-v3-671b",
]

# name: (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

LONG_OK = {"jamba-v0.1-52b", "xlstm-350m", "gemma3-27b"}


def skip_reason(arch: str, shape: str) -> Optional[str]:
    cfg = get_config(arch)
    kind = SHAPES[shape][2]
    if kind == "decode":
        if not cfg.supports_decode:
            return "encoder-only: no decode step (DESIGN.md §5)"
        if shape == "long_500k" and arch not in LONG_OK:
            return "full-attention arch: long_500k needs sub-quadratic path"
    return None


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable)
# ---------------------------------------------------------------------------

def _sds(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def placement_specs(cfg: ModelConfig, ctx, mesh):
    if cfg.moe is None:
        return None
    L, s = cfg.num_moe_layers, cfg.moe.s_max
    rep = NamedSharding(mesh, P())
    E = cfg.moe.num_experts
    return {
        "shadow_idx": jax.ShapeDtypeStruct((L, s), jnp.int32, sharding=rep),
        "shadow_valid": jax.ShapeDtypeStruct((L, s), jnp.float32, sharding=rep),
        "shadow_devs": jax.ShapeDtypeStruct((L, s, ctx.ep_size), jnp.float32,
                                            sharding=rep),
        # owner re-layout permutation — always in the engine's step
        # arrays (identity when migration is off), so the lowered step
        # must trace the same slot-bucketed dispatch path real runs use.
        "expert_slot": jax.ShapeDtypeStruct((L, E), jnp.int32, sharding=rep),
    }


def batch_specs(cfg: ModelConfig, ctx, mesh, seq: int, batch: int,
                dtype=jnp.bfloat16):
    raw = make_batch_specs(cfg, batch, seq, dtype)
    out = {}
    for k, v in raw.items():
        spec = ctx.batch_spec(len(v.shape), batch)
        out[k] = jax.ShapeDtypeStruct(v.shape, v.dtype,
                                      sharding=NamedSharding(mesh, spec))
    return out


def _greedy_cache_spec(shape, ctx, start_dim: int = 1) -> P:
    """Assign (pod, data, model) greedily to divisible dims (dim0 = layer
    stack stays replicated); largest dims first."""
    entries = [None] * len(shape)
    axes = [a for a in (ctx.pod_axis, ctx.data_axis, ctx.model_axis) if a]
    dims = sorted(range(start_dim, len(shape)), key=lambda i: -shape[i])
    for ax in axes:
        size = ctx.axis_size(ax)
        for i in dims:
            if entries[i] is None and shape[i] % size == 0 and shape[i] >= size:
                entries[i] = ax
                break
    return P(*entries)


def cache_specs(cfg: ModelConfig, ctx, mesh, batch: int, seq: int,
                dtype=jnp.bfloat16):
    shapes = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, batch, seq, dtype))
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, l.dtype,
            sharding=NamedSharding(mesh, _greedy_cache_spec(l.shape, ctx))),
        shapes)


def state_specs(cfg: ModelConfig, ctx, mesh, optimizer,
                dtype=jnp.bfloat16):
    params_sds = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg, dtype))
    pshard = param_shardings(params_sds, ctx)
    opt_sds = jax.eval_shape(optimizer.init, params_sds)

    def opt_shard(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        stacked = 1 if "stages" in keys else 0
        spec = param_pspec(keys, leaf.shape, ctx, stacked_dims=stacked)
        spec = zero1_pspec(spec, leaf.shape, ctx)
        return NamedSharding(mesh, spec)

    mu_shard = jax.tree_util.tree_map_with_path(opt_shard, opt_sds.mu)
    nu_shard = jax.tree_util.tree_map_with_path(opt_shard, opt_sds.nu)
    state = TrainState(
        params=_sds(params_sds, pshard),
        opt=type(opt_sds)(
            step=jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P())),
            mu=_sds(opt_sds.mu, mu_shard),
            nu=_sds(opt_sds.nu, nu_shard)),
    )
    return state


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def build_step(cfg: ModelConfig, ctx, kind: str, optimizer=None):
    if kind == "train":
        def step(state, batch, placements=None):
            def lf(p):
                return model_lib.loss_fn(p, batch, cfg, ctx,
                                         placements=placements,
                                         attn_impl="auto", remat=True)
            (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(
                state.params)
            updates, opt = optimizer.update(grads, state.opt, state.params)
            params = apply_updates(state.params, updates)
            out = {"loss": loss}
            if aux.get("counts") is not None:
                out["counts"] = aux["counts"]
            return TrainState(params, opt), out
        return step
    if kind == "prefill":
        def step(params, batch, placements=None):
            logits, aux = model_lib.forward(
                params, batch.get("tokens"), cfg, ctx, placements=placements,
                attn_impl="auto", prefix_embeds=batch.get("prefix_embeds"),
                frame_embeds=batch.get("frame_embeds"), remat=True)
            # Serving returns only the last position (next-token dist).
            return logits[:, -1]
        return step
    if kind == "decode":
        def step(params, caches, token, index, placements=None):
            return model_lib.decode_step(params, caches, token, index, cfg,
                                         ctx, placements=placements)
        return step
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Collective-byte extraction from HLO text
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, by op kind.

    Per-device view (the module is the per-partition SPMD program), so the
    numbers are bytes-through-this-device — what the roofline needs."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (\w[\w\-]*)\(", ls)
        if not m:
            continue
        shape_part, opname = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if opname == kind or opname == kind + "-start":
                out[kind] += _shape_bytes(shape_part)
                out["count"] += 1
                break
    return out


# ---------------------------------------------------------------------------
# Per-layer probes: XLA's cost_analysis counts a lax.scan body ONCE (the
# while-loop trip count is invisible to it), so full-step numbers undercount
# by ~num_layers.  For the roofline we therefore lower *one layer of each
# distinct kind* at the production shapes and scale by its occurrence count.
# ---------------------------------------------------------------------------

def _probe_record(lowered) -> Dict:
    compiled = lowered.compile()
    rec: Dict = {}
    ca = compiled.cost_analysis()
    if ca:
        rec["flops"] = float(ca.get("flops", -1))
        rec["bytes_accessed"] = float(ca.get("bytes accessed", -1))
    rec["collectives"] = collective_bytes(compiled.as_text())
    return rec


def probe_layers(cfg: ModelConfig, ctx, mesh, kind: str, seq: int,
                 gbatch: int, dtype=jnp.bfloat16) -> Dict[str, Dict]:
    """Lower one layer per distinct LayerSpec + the embed/unembed head."""
    from repro.models import blocks as blocks_lib
    from repro.models.common import cross_entropy_loss, embed, unembed

    distinct: Dict[str, Tuple] = {}
    for spec in cfg.layer_specs:
        key = f"{spec.mixer}:{spec.ffn}:w{spec.window}"
        if key in distinct:
            distinct[key] = (spec, distinct[key][1] + 1)
        else:
            distinct[key] = (spec, 1)

    bspec = ctx.batch_spec(3, gbatch)
    out: Dict[str, Dict] = {}
    for key, (spec, count) in distinct.items():
        params_sds = jax.eval_shape(
            lambda s=spec: blocks_lib.layer_init(jax.random.PRNGKey(0), s,
                                                 cfg, dtype))
        pshard = param_shardings(params_sds, ctx)
        params_sds = _sds(params_sds, pshard)
        placement = None
        if spec.ffn == "moe":
            rep = NamedSharding(mesh, P())
            s = cfg.moe.s_max
            placement = {
                "shadow_idx": jax.ShapeDtypeStruct((s,), jnp.int32,
                                                   sharding=rep),
                "shadow_valid": jax.ShapeDtypeStruct((s,), jnp.float32,
                                                     sharding=rep),
                "shadow_devs": jax.ShapeDtypeStruct((s, ctx.ep_size),
                                                    jnp.float32,
                                                    sharding=rep),
                "expert_slot": jax.ShapeDtypeStruct((cfg.moe.num_experts,),
                                                    jnp.int32, sharding=rep),
            }
        try:
            if kind in ("train", "prefill"):
                x = jax.ShapeDtypeStruct((gbatch, seq, cfg.d_model), dtype,
                                         sharding=NamedSharding(mesh, bspec))
                pos = jax.ShapeDtypeStruct(
                    (gbatch, seq), jnp.int32,
                    sharding=NamedSharding(mesh, ctx.batch_spec(2, gbatch)))

                def fwd(p, xx, pp, pl, _spec=spec):
                    y, _ = blocks_lib.layer_apply(p, xx, pp, _spec, cfg, ctx,
                                                  pl, "auto")
                    # Sum in the activation dtype: an f32 seed would poison
                    # every backward cotangent to f32 and inflate the
                    # measured TP all-reduce bytes 2× vs the real step.
                    return jnp.sum(y)

                if kind == "train":
                    fn = jax.grad(fwd)
                else:
                    fn = fwd
                lowered = jax.jit(fn).lower(params_sds, x, pos, placement)
            else:  # decode
                cache_sds = jax.eval_shape(
                    lambda s=spec: blocks_lib.layer_init_cache(s, cfg,
                                                               gbatch, seq,
                                                               dtype))
                cache_sds = jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct(
                        l.shape, l.dtype,
                        sharding=NamedSharding(
                            mesh, _greedy_cache_spec(l.shape, ctx,
                                                     start_dim=1))),
                    cache_sds)
                x = jax.ShapeDtypeStruct(
                    (gbatch, 1, cfg.d_model), dtype,
                    sharding=NamedSharding(mesh, ctx.batch_spec(3, gbatch)))
                idx = jax.ShapeDtypeStruct((), jnp.int32,
                                           sharding=NamedSharding(mesh, P()))

                def dec(p, xx, cc, ii, pl, _spec=spec):
                    return blocks_lib.layer_decode(p, xx, cc, ii, _spec, cfg,
                                                   ctx, pl)

                lowered = jax.jit(dec).lower(params_sds, x, cache_sds, idx,
                                             placement)
            rec = _probe_record(lowered)
            rec["count"] = count
            out[key] = rec
        except Exception as e:  # noqa: BLE001
            out[key] = {"count": count, "error": f"{type(e).__name__}: {e}"}

    # Head probe: embed → unembed → xent (train adds grad).
    if cfg.modality != "audio":
        try:
            emb_sds = jax.eval_shape(
                lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg,
                                              dtype)["embed"])
            emb_sds = _sds(emb_sds, param_shardings(emb_sds, ctx))
            hseq = 1 if kind == "decode" else seq
            tok = jax.ShapeDtypeStruct(
                (gbatch, hseq), jnp.int32,
                sharding=NamedSharding(mesh, ctx.batch_spec(2, gbatch)))

            from repro import flags as _flags
            _chunk = _flags.xent_chunk()

            def head(e, t):
                x = embed(e, t)
                if _chunk:
                    from repro.models.common import chunked_unembed_xent
                    return chunked_unembed_xent(x, e["table"], t, _chunk)
                logits = unembed(e, x)
                return cross_entropy_loss(logits, t)

            fn = jax.grad(head) if kind == "train" else head
            rec = _probe_record(jax.jit(fn).lower(emb_sds, tok))
            rec["count"] = 1
            out["head"] = rec
        except Exception as e:  # noqa: BLE001
            out["head"] = {"count": 1, "error": f"{type(e).__name__}: {e}"}
    return out


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_one(arch: str, shape: str, mesh_kind: str, out_dir: str,
            dtype=jnp.bfloat16) -> Dict:
    cfg = get_config(arch)
    seq, gbatch, kind = SHAPES[shape]
    rec: Dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "kind": kind, "seq": seq, "batch": gbatch,
                 "params": cfg.param_count(),
                 "active_params": cfg.active_param_count()}
    reason = skip_reason(arch, shape)
    if reason:
        rec["status"] = "SKIP"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ctx = make_ctx(mesh)
    t0 = time.time()
    try:
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            placements = placement_specs(cfg, ctx, mesh)
            if kind == "train":
                optimizer = adamw(constant(1e-4), state_dtype=jnp.float32)
                step = build_step(cfg, ctx, kind, optimizer)
                state = state_specs(cfg, ctx, mesh, optimizer, dtype)
                batch = batch_specs(cfg, ctx, mesh, seq, gbatch, dtype)
                lowered = jax.jit(step).lower(state, batch, placements)
            elif kind == "prefill":
                step = build_step(cfg, ctx, kind)
                params = state_specs(
                    cfg, ctx, mesh, adamw(constant(1e-4)), dtype).params
                batch = batch_specs(cfg, ctx, mesh, seq, gbatch, dtype)
                lowered = jax.jit(step).lower(params, batch, placements)
            else:  # decode
                step = build_step(cfg, ctx, kind)
                params = state_specs(
                    cfg, ctx, mesh, adamw(constant(1e-4)), dtype).params
                caches = cache_specs(cfg, ctx, mesh, gbatch, seq, dtype)
                token = jax.ShapeDtypeStruct(
                    (gbatch, 1), jnp.int32,
                    sharding=NamedSharding(mesh, ctx.batch_spec(2, gbatch)))
                index = jax.ShapeDtypeStruct((), jnp.int32,
                                             sharding=NamedSharding(mesh, P()))
                lowered = jax.jit(step).lower(params, caches, token, index,
                                              placements)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

            ma = compiled.memory_analysis()
            if ma is not None:
                for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                             "temp_size_in_bytes", "alias_size_in_bytes",
                             "generated_code_size_in_bytes"):
                    v = getattr(ma, attr, None)
                    if v is not None:
                        rec[attr] = int(v)
            ca = compiled.cost_analysis()
            if ca:
                rec["flops"] = float(ca.get("flops", -1))
                rec["bytes_accessed"] = float(ca.get("bytes accessed", -1))
                rec["transcendentals"] = float(ca.get("transcendentals", 0))
            txt = compiled.as_text()
            rec["collectives"] = collective_bytes(txt)
            rec["hlo_chars"] = len(txt)
            # Per-layer probes for scan-aware roofline accounting (single
            # -pod only; multi-pod reuses single-pod probes scaled).
            if mesh_kind == "single":
                rec["probes"] = probe_layers(cfg, ctx, mesh, kind, seq,
                                             gbatch)
            rec["status"] = "OK"
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                path = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
                if os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    print(f"[cached] {arch} {shape} {mk}: {rec['status']}")
                    results.append(rec)
                    continue
                print(f"[dryrun] {arch} {shape} {mk} ...", flush=True)
                rec = run_one(arch, shape, mk, args.out)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                msg = rec.get("reason") or rec.get("error", "")
                print(f"  -> {rec['status']} "
                      f"lower={rec.get('lower_s', '-')}s "
                      f"compile={rec.get('compile_s', '-')}s "
                      f"flops={rec.get('flops', '-')} {msg}", flush=True)
                results.append(rec)
    ok = sum(r["status"] == "OK" for r in results)
    skip = sum(r["status"] == "SKIP" for r in results)
    fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\nDRY-RUN SUMMARY: {ok} OK, {skip} SKIP, {fail} FAIL "
          f"of {len(results)}")
    if fail:
        for r in results:
            if r["status"] == "FAIL":
                print(f"  FAIL {r['arch']} {r['shape']} {r['mesh']}: "
                      f"{r['error']}")


if __name__ == "__main__":
    main()
