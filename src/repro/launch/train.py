"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch moe-gpt-s --steps 100 \
      --batch 8 --seq 128 --policy pro_prophet [--reduced] [--mesh d,m]

On this CPU container use ``--reduced`` (smoke-scale variant) or the small
paper models; on a real cluster drop ``--reduced`` and pass the production
mesh.  ``--mesh 2,4`` builds a (data, model) host-device mesh (requires
XLA_FLAGS=--xla_force_host_platform_device_count=8 or real devices).
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moe-gpt-s")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--policy", default="pro_prophet",
                    choices=["pro_prophet", "fastermoe", "top2", "top3",
                             "none"])
    ap.add_argument("--replan-interval", type=int, default=1)
    ap.add_argument("--migration", action="store_true",
                    help="dynamic expert migration: the planner may "
                         "re-home persistently hot experts (one-time "
                         "EP-axis weight/optimizer exchange) instead of "
                         "shadowing them every step; REPRO_MIGRATION "
                         "overrides")
    ap.add_argument("--async-plan", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="pipelined runtime: plan on a background thread "
                         "overlapped with device execution (default on; "
                         "REPRO_ASYNC_PLAN=0 is the env escape hatch)")
    ap.add_argument("--a2a-chunks", type=int, default=None,
                    help="force the MoE a2a↔FEC chunk count (sets "
                         "REPRO_A2A_CHUNKS; default: the engine picks K "
                         "per layer from the scheduler timeline, K=1 is "
                         "the bit-identical serial path)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="device mesh shape: '8' (model/EP axis), "
                         "'2,4' (data, model) or '2,2,2' "
                         "(pod, data, model)")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint root: atomic step-<n> directories "
                         "(repro.checkpoint.save_checkpoint), a final "
                         "save at --steps, and periodic saves with "
                         "--ckpt-every")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save an atomic retained checkpoint every N "
                         "steps during the run (0 = final save only)")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="retained step-<n> checkpoints under --ckpt")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    if args.a2a_chunks is not None:
        os.environ["REPRO_A2A_CHUNKS"] = str(args.a2a_chunks)

    import jax

    from repro.configs import get_config, reduced
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant, cosine, wsd
    from repro.parallel import local_ctx, make_ctx
    from repro.train import Trainer
    from repro.train.trainer import make_engine_for

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    if args.mesh:
        from repro.launch.mesh import mesh_axis_names
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh(shape, mesh_axis_names(len(shape)))
        ctx = make_ctx(mesh)
    else:
        mesh = None
        ctx = local_ctx()

    sched = {"cosine": lambda: cosine(args.lr, 10, args.steps),
             "wsd": lambda: wsd(args.lr, 10, int(args.steps * 0.7),
                                int(args.steps * 0.2)),
             "constant": lambda: constant(args.lr)}[args.schedule]()
    engine = None
    if cfg.moe is not None and args.policy != "none":
        engine = make_engine_for(cfg, ctx, policy=args.policy,
                                 replan_interval=args.replan_interval,
                                 migration=args.migration)
    trainer = Trainer(cfg, ctx, adamw(sched), attn_impl="auto",
                      remat=not args.reduced, engine=engine,
                      async_plan=args.async_plan)
    state = trainer.init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq)

    from repro.train.runtime import OverlapTelemetry
    telemetry = OverlapTelemetry()
    ctxmgr = mesh if mesh is not None else _null()
    with ctxmgr:
        state, hist = trainer.run(state, data, num_steps=args.steps,
                                  log_every=args.log_every,
                                  telemetry=telemetry,
                                  ckpt_dir=args.ckpt,
                                  ckpt_every=args.ckpt_every,
                                  ckpt_keep=args.ckpt_keep)
    print(f"final loss: {hist[-1]:.4f} (start {hist[0]:.4f})")
    if engine is not None:
        s = telemetry.summary()
        print(f"overlap: plan {s['mean_plan_s'] * 1e3:.2f}ms/step "
              f"({s['hidden_frac']:.0%} hidden), host overhead "
              f"{s['host_overhead_s'] * 1e3:.2f}ms/step "
              f"(serial would pay {s['serial_overhead_s'] * 1e3:.2f}ms)")
        if s["mean_a2a_gbytes"] > 0.0:
            print(f"a2a: {s['mean_a2a_gbytes']:.3g}GB/step, "
                  f"{s['comm_hidden_frac']:.0%} hidden under the chunked "
                  f"expert pipeline (modeled)")
    if args.ckpt:
        from repro.checkpoint import save_checkpoint
        # Checkpoints are always in the home (identity) expert layout —
        # a restored run binds a fresh engine that assumes it.
        state = trainer.restore_home_layout(state)
        path = save_checkpoint(state, args.ckpt, step=args.steps,
                               keep=args.ckpt_keep,
                               extra={"arch": cfg.name,
                                      "expert_layout": "home"})
        print(f"checkpoint written to {path}")


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
