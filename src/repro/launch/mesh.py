"""Production meshes (DESIGN.md §6).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first jax
init)."""
from __future__ import annotations

import jax

# Canonical axis names by mesh rank.  A single axis is the EP/tensor
# ("model") axis — that is what exercises the Pro-Prophet engine and what
# `--mesh 8` means on an 8-device host.
MESH_AXIS_NAMES = {
    1: ("model",),
    2: ("data", "model"),
    3: ("pod", "data", "model"),
}


def mesh_axis_names(ndim: int):
    """Axis-name tuple for an ``ndim``-axis mesh (1, 2 or 3 axes)."""
    try:
        return MESH_AXIS_NAMES[ndim]
    except KeyError:
        raise ValueError(
            f"mesh must have 1, 2 or 3 axes, got {ndim}") from None


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 v5e pod (256 chips) or 2 pods (512 chips).

    Axes: ``data`` — batch / ZeRO / expert-FSDP; ``model`` — tensor
    parallel + expert parallel (EP groups of 16); ``pod`` — pure data
    parallelism across the inter-pod link."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small host-device mesh for subprocess integration tests."""
    return jax.make_mesh(shape, axes)
