"""Production meshes (DESIGN.md §6).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first jax
init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 v5e pod (256 chips) or 2 pods (512 chips).

    Axes: ``data`` — batch / ZeRO / expert-FSDP; ``model`` — tensor
    parallel + expert parallel (EP groups of 16); ``pod`` — pure data
    parallelism across the inter-pod link."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small host-device mesh for subprocess integration tests."""
    return jax.make_mesh(shape, axes)
