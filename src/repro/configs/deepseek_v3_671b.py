"""deepseek-v3-671b [moe]: 61L, MLA attention, 1 shared + 256 routed
experts top-8, first 3 layers dense, MTP head. [arXiv:2412.19437]"""
from .base import (LayerSpec, MLASettings, ModelConfig, MoESettings, Stage,
                   register)

_dense = LayerSpec("mla", "dense")
_moe = LayerSpec("mla", "moe")

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,           # MLA: effectively MHA over latent KV
    head_dim=128,
    d_ff=18432,                 # dense-layer ffn dim (first 3 layers)
    vocab_size=129280,
    stages=(
        Stage(macro=(_dense,), repeats=3),
        Stage(macro=(_moe,), repeats=58),
    ),
    ffn_kind="swiglu",
    mla=MLASettings(q_rank=1536, kv_rank=512, nope_dim=128, rope_dim=64,
                    v_dim=128),
    moe=MoESettings(num_experts=256, top_k=8, d_expert=2048, num_shared=1,
                    shared_d_ff=2048, capacity_factor=1.25, s_max=8),
    source="arXiv:2412.19437",
))

# Multi-token prediction (MTP): one extra depth-1 prediction module, built
# by repro.train.mtp when enabled.
MTP_DEPTH = 1
