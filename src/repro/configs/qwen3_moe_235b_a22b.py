"""qwen3-moe-235b-a22b [moe]: 94L, 128 experts top-8, fine-grained
d_expert=1536, GQA kv=4. Primary Pro-Prophet showcase (large E, small
experts ⇒ cheap Trans relative to compute).
[hf:Qwen/Qwen3-235B-A22B, dims per assignment / Qwen3-30B-A3B card]"""
from .base import LayerSpec, ModelConfig, MoESettings, register, uniform_stages

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                 # per-expert ffn dim
    vocab_size=151936,
    stages=uniform_stages(94, LayerSpec("gqa", "moe")),
    ffn_kind="swiglu",
    rope_theta=1e6,
    moe=MoESettings(num_experts=128, top_k=8, d_expert=1536,
                    capacity_factor=1.25, s_max=8),
    source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)",
))
