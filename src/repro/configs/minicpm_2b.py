"""minicpm-2b [dense]: llama-like, MHA (kv=36), trained with the WSD
schedule (wired to repro.optim.schedule.wsd in its train recipe).
[arXiv:2404.06395]"""
from .base import LayerSpec, ModelConfig, register, uniform_stages

CONFIG = register(ModelConfig(
    name="minicpm-2b",
    arch_type="dense",
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    stages=uniform_stages(40, LayerSpec("gqa", "dense")),
    ffn_kind="swiglu",
    source="arXiv:2404.06395",
))

# Training recipe marker consumed by repro.train.trainer.
SCHEDULE = "wsd"
