"""Architecture config system: every assigned architecture is a
:class:`ModelConfig` built from stages of heterogeneous macro-blocks.

A *stage* scans ``repeats`` copies of a *macro-block* — an ordered tuple of
:class:`LayerSpec`s unrolled inside the scan body.  This expresses every
assigned pattern exactly:

  uniform decoder      1 stage,  macro = (gqa+ffn,)            × L
  gemma3 5:1           stage A   macro = (local×5, global)     × 10, +rem
  jamba 1:7 / moe 1:2  stage A   macro = 8 mixed layers        × 4
  deepseek 3 dense     stage A = (mla+dense)×3, stage B = (mla+moe)×58
  xlstm 5:1            stage A   macro = (mlstm×5, slstm)      × 4
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

_REGISTRY: Dict[str, "ModelConfig"] = {}


@dataclasses.dataclass(frozen=True)
class MoESettings:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    shadow_capacity_factor: float = 2.0
    s_max: int = 8                      # Pro-Prophet shadow-slot budget
    aux_loss_coef: float = 0.0          # off: system-level balancing only


@dataclasses.dataclass(frozen=True)
class MLASettings:
    q_rank: int = 1536
    kv_rank: int = 512
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaSettings:
    expand: int = 2
    d_state: int = 16
    d_conv: int = 4


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str                  # gqa | mla | mamba | mlstm | slstm
    ffn: str                    # dense | moe | none
    window: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class Stage:
    macro: Tuple[LayerSpec, ...]
    repeats: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    stages: Tuple[Stage, ...]
    head_dim: Optional[int] = None
    ffn_kind: str = "swiglu"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True                 # False ⇒ encoder (hubert)
    moe: Optional[MoESettings] = None
    mla: Optional[MLASettings] = None
    mamba: Optional[MambaSettings] = None
    mlstm_heads: int = 4
    modality: str = "text"              # text | vlm | audio
    num_prefix_tokens: int = 0          # VLM patch embeddings
    tie_embeddings: bool = True
    source: str = ""                    # citation

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_layers(self) -> int:
        return sum(len(s.macro) * s.repeats for s in self.stages)

    @property
    def layer_specs(self):
        out = []
        for s in self.stages:
            for _ in range(s.repeats):
                out.extend(s.macro)
        return out

    @property
    def num_moe_layers(self) -> int:
        return sum(1 for l in self.layer_specs if l.ffn == "moe")

    @property
    def supports_decode(self) -> bool:
        return self.causal and self.modality != "audio"

    @property
    def sub_quadratic(self) -> bool:
        """Every attention layer windowed, or attention-free ⇒ long-context
        decode allowed.  MLA is full attention (latent KV is still O(S))."""
        return all(l.mixer not in ("gqa", "mla") or
                   (l.mixer == "gqa" and l.window is not None)
                   for l in self.layer_specs)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        nm = 3 if self.ffn_kind == "swiglu" else 2
        for spec in self.layer_specs:
            if spec.mixer == "gqa":
                total += d * (self.num_heads + self.num_kv_heads * 2) * hd
                total += self.num_heads * hd * d
            elif spec.mixer == "mla":
                m = self.mla
                total += d * m.q_rank + m.q_rank * self.num_heads * (m.nope_dim + m.rope_dim)
                total += d * (m.kv_rank + m.rope_dim)
                total += m.kv_rank * self.num_heads * (m.nope_dim + m.v_dim)
                total += self.num_heads * m.v_dim * d
            elif spec.mixer == "mamba":
                di = self.mamba.expand * d
                dt_rank = max(16, d // 16)
                total += d * 2 * di + di * (dt_rank + 2 * self.mamba.d_state)
                total += dt_rank * di + di * d + di * self.mamba.d_state
            elif spec.mixer in ("mlstm", "slstm"):
                if spec.mixer == "mlstm":
                    di = 2 * d
                    total += d * 2 * di + 3 * di * di + di * 2 * self.mlstm_heads + di * d
                else:
                    total += d * 4 * d + d * 4 * (d // self.mlstm_heads) + d * d
            if spec.ffn == "dense":
                total += nm * d * self.d_ff
            elif spec.ffn == "moe":
                mo = self.moe
                total += nm * d * mo.d_expert * mo.num_experts + d * mo.num_experts
                if mo.num_shared:
                    total += nm * d * (mo.shared_d_ff or mo.d_expert * mo.num_shared)
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        nm = 3 if self.ffn_kind == "swiglu" else 2
        mo = self.moe
        inactive = nm * self.d_model * mo.d_expert * (mo.num_experts - mo.top_k)
        return self.param_count() - inactive * self.num_moe_layers


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs():
    _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (deepseek_v3_671b, gemma3_27b, hubert_xlarge,  # noqa: F401
                   jamba_v0_1_52b, minicpm_2b, moe_gpt, paligemma_3b,
                   qwen2_1_5b, qwen3_moe_235b_a22b, smollm_360m, xlstm_350m)


def uniform_stages(num_layers: int, spec: LayerSpec) -> Tuple[Stage, ...]:
    return (Stage(macro=(spec,), repeats=num_layers),)


def reduced(cfg: ModelConfig, *, d_model: int = 256, layers: int = 2,
            vocab: int = 512, d_ff: int = 512, max_experts: int = 4,
            seq_window: int = 64) -> ModelConfig:
    """Smoke-test variant of the same family: ≤2 layers, d_model ≤512,
    ≤4 experts — structure (mixers/ffn kinds/pattern) preserved."""
    specs = cfg.layer_specs
    # Keep a structurally representative prefix: first `layers` distinct
    # (mixer, ffn, windowed?) combos, else the first `layers` layers.
    seen, macro = [], []
    for l in specs:
        key = (l.mixer, l.ffn, l.window is not None)
        if key not in seen:
            seen.append(key)
            macro.append(LayerSpec(l.mixer, l.ffn,
                                   seq_window if l.window else None))
        if len(macro) >= max(layers, len(seen)):
            break
    while len(macro) < layers:
        macro.append(macro[-1])
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    while heads % kv:
        kv -= 1
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, max_experts),
            top_k=min(cfg.moe.top_k, 2), d_expert=d_ff // 2,
            num_shared=min(cfg.moe.num_shared, 1), shared_d_ff=d_ff // 2,
            s_max=2)
    mla = dataclasses.replace(cfg.mla, q_rank=64, kv_rank=32, nope_dim=32,
                              rope_dim=16, v_dim=32) if cfg.mla else None
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", d_model=d_model, num_heads=heads,
        num_kv_heads=kv, head_dim=d_model // heads, d_ff=d_ff,
        vocab_size=vocab, stages=(Stage(tuple(macro), 1),), moe=moe, mla=mla,
        num_prefix_tokens=min(cfg.num_prefix_tokens, 4))
