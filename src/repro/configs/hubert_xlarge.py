"""hubert-xlarge [audio]: encoder-only transformer (w2v2 arch), 48L,
masked-prediction over 504 cluster units.  The mel/conv feature frontend
is a stub — input_specs provides frame embeddings. [arXiv:2106.07447]"""
from .base import LayerSpec, ModelConfig, register, uniform_stages

CONFIG = register(ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    stages=uniform_stages(48, LayerSpec("gqa", "dense")),
    ffn_kind="gelu",
    causal=False,               # bidirectional encoder
    modality="audio",
    source="arXiv:2106.07447",
))
