from .base import (LayerSpec, MambaSettings, MLASettings, ModelConfig,
                   MoESettings, Stage, get_config, list_configs, reduced,
                   register, uniform_stages)

__all__ = ["LayerSpec", "MambaSettings", "MLASettings", "ModelConfig",
           "MoESettings", "Stage", "get_config", "list_configs", "reduced",
           "register", "uniform_stages"]
