"""qwen2-1.5b [dense]: GQA with QKV bias. [arXiv:2407.10671]"""
from .base import LayerSpec, ModelConfig, register, uniform_stages

CONFIG = register(ModelConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    stages=uniform_stages(28, LayerSpec("gqa", "dense")),
    ffn_kind="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
    source="arXiv:2407.10671",
))
