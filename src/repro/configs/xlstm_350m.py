"""xlstm-350m [ssm]: sLSTM + mLSTM blocks, d_ff=0 (the blocks carry their
own up/down projections). 24 layers = 4 × (5 mLSTM + 1 sLSTM).
[arXiv:2405.04517]"""
from .base import LayerSpec, ModelConfig, Stage, register

_m = LayerSpec("mlstm", "none")
_s = LayerSpec("slstm", "none")

CONFIG = register(ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    stages=(Stage(macro=(_m, _m, _m, _m, _m, _s), repeats=4),),
    mlstm_heads=4,
    source="arXiv:2405.04517",
))
