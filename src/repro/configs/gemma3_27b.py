"""gemma3-27b [dense]: 5 local (sliding-window 1024) : 1 global layers,
128k context. 62 layers = (5 local + 1 global) × 10 + 2 local remainder.
[hf:google/gemma-3-* family]"""
from .base import LayerSpec, ModelConfig, Stage, register

LOCAL_WINDOW = 1024

_local = LayerSpec("gqa", "dense", window=LOCAL_WINDOW)
_global = LayerSpec("gqa", "dense", window=None)

CONFIG = register(ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    stages=(
        Stage(macro=(_local,) * 5 + (_global,), repeats=10),
        Stage(macro=(_local, _local), repeats=1),
    ),
    ffn_kind="swiglu",
    rope_theta=1e6,
    source="hf:google/gemma-3-1b-pt (27b dims)",
))
