"""The paper's own benchmark models (Table III): MoE-GPT-{S,M,L,DS,DM}.

GPT blocks with every FFN replaced by a MoE layer (GeLU experts, as in
FastMoE/DeepSpeed-MoE GPT variants).  "Embedding" = d_model, "Hidden" =
d_ff.  The number of experts per layer equals the number of devices in the
paper's runs; we default to 16 and the benchmark harness overrides it.
"""
import dataclasses

from .base import LayerSpec, ModelConfig, MoESettings, register, uniform_stages


def _moe_gpt(name: str, layers: int, d_model: int, d_ff: int,
             num_experts: int = 16, top_k: int = 1) -> ModelConfig:
    return ModelConfig(
        name=name,
        arch_type="moe",
        d_model=d_model,
        num_heads=max(4, d_model // 64),
        num_kv_heads=max(4, d_model // 64),
        head_dim=64,
        d_ff=d_ff,
        vocab_size=50304,
        stages=uniform_stages(layers, LayerSpec("gqa", "moe")),
        ffn_kind="gelu",
        moe=MoESettings(num_experts=num_experts, top_k=top_k,
                        d_expert=d_ff, capacity_factor=1.25, s_max=4),
        source="Pro-Prophet Table III",
    )


MOE_GPT_S = register(_moe_gpt("moe-gpt-s", 12, 512, 1024))
MOE_GPT_M = register(_moe_gpt("moe-gpt-m", 12, 1024, 2048))
MOE_GPT_L = register(_moe_gpt("moe-gpt-l", 12, 2048, 4096))
MOE_GPT_DS = register(_moe_gpt("moe-gpt-ds", 24, 512, 1024))
MOE_GPT_DM = register(_moe_gpt("moe-gpt-dm", 24, 1024, 2048))


def with_experts(cfg: ModelConfig, num_experts: int, top_k: int = 1
                 ) -> ModelConfig:
    return dataclasses.replace(
        cfg, name=f"{cfg.name}-e{num_experts}k{top_k}",
        moe=dataclasses.replace(cfg.moe, num_experts=num_experts,
                                top_k=top_k))
