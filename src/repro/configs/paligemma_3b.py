"""paligemma-3b [vlm]: gemma-style decoder (MQA kv=1) consuming SigLIP
patch embeddings through a projector.  The vision tower is a stub —
input_specs provides 256 patch embeddings. [arXiv:2407.07726]"""
from .base import LayerSpec, ModelConfig, register, uniform_stages

NUM_PATCHES = 256

CONFIG = register(ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    stages=uniform_stages(18, LayerSpec("gqa", "dense")),
    ffn_kind="swiglu",
    modality="vlm",
    num_prefix_tokens=NUM_PATCHES,
    source="arXiv:2407.07726",
))
