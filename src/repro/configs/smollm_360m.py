"""smollm-360m [dense]: llama-arch small model.
[hf:HuggingFaceTB/SmolLM-135M family]"""
from .base import LayerSpec, ModelConfig, register, uniform_stages

CONFIG = register(ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    stages=uniform_stages(32, LayerSpec("gqa", "dense")),
    ffn_kind="swiglu",
    source="hf:HuggingFaceTB/SmolLM-360M",
))
