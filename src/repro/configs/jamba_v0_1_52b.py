"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave, MoE every 2nd
layer (16 experts top-2). 32 layers = 4 × 8-layer period; attention sits at
index 4 of each period, MoE FFN on odd indices. [arXiv:2403.19887]"""
from .base import (LayerSpec, MambaSettings, ModelConfig, MoESettings, Stage,
                   register)

# Attention layers use a sliding window at extreme contexts so the assigned
# long_500k decode stays sub-quadratic; within-window behaviour matches
# full attention for seq <= window during training (train_4k < 32768).
ATTN_WINDOW = 32768


def _layer(i: int) -> LayerSpec:
    mixer = "gqa" if i % 8 == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    return LayerSpec(mixer, ffn, window=ATTN_WINDOW if mixer == "gqa" else None)


CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    stages=(Stage(macro=tuple(_layer(i) for i in range(8)), repeats=4),),
    ffn_kind="swiglu",
    mamba=MambaSettings(expand=2, d_state=16, d_conv=4),
    moe=MoESettings(num_experts=16, top_k=2, d_expert=14336,
                    capacity_factor=1.25, s_max=4),
    source="arXiv:2403.19887",
))
