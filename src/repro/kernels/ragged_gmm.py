"""Ragged (load-proportional) grouped matmul + fused SwiGLU epilogue.

The MoE capacity buffers are ``[G, T, d]`` with only a *prefix* of each
group's rows occupied (tokens actually routed there); after the EP
``all_to_all`` the occupied rows are a prefix of each of the ``S`` peer
*segments* of length ``seg_len`` (``T == S * seg_len``).  The dense
``gmm`` kernel burns MXU cycles on every padded slot regardless of load —
exactly the waste Pro-Prophet's load balancing is supposed to eliminate —
so these kernels take the per-(group, segment) occupancy counts
(``group_sizes`` ``[G, S]`` int32, scalar-prefetched into SMEM) and

* skip the MXU dot entirely for output tiles that overlap no occupied
  rows (compute cost ∝ actual load, tile-granular), and
* mask the rows beyond each segment's count in the epilogue, so the op
  is well-defined (``out[g, i] = 0``) even when the padded slots hold
  garbage.

``gmm_swiglu`` additionally fuses the SwiGLU gate: both ``x @ wg`` and
``x @ wi`` accumulate from the *same* VMEM-resident ``x`` tile, and
``silu(a) * b`` runs as the epilogue — the activation buffer is read
from HBM once instead of twice and the intermediate never round-trips.

VMEM budget per grid step (defaults bt = bf = bd = 128, bf16 inputs):
``bt·bd + bd·bf + bt·bf`` tile bytes + one (``gmm_swiglu``: two) f32
``bt×bf`` accumulators ≈ 160–224 KiB — far inside the ~16 MiB/core VMEM,
leaving headroom for the pipeline's double buffering.

Both ops carry custom VJPs so the backward pass (the paper's BEC) gets
the same ragged savings: dx is another ragged gmm on the swapped
weights, dw accumulates only over occupied row tiles, and the SwiGLU
backward recomputes the two projections ragged instead of saving them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gmm import _pad_to


# ---------------------------------------------------------------------------
# Occupancy predicates (shared by all kernels)
# ---------------------------------------------------------------------------

def _tile_active(gs_ref, g, t_start: int, bt: int, seg_len: int, S: int):
    """Scalar: does row tile [t_start, t_start+bt) overlap any occupied
    prefix [p*seg_len, p*seg_len + gs[g, p])?  S is static ⇒ unrolled."""
    act = jnp.bool_(False)
    for p in range(S):
        lo = p * seg_len
        hi = lo + gs_ref[g, p]
        act = act | (jnp.minimum(t_start + bt, hi) > jnp.maximum(t_start, lo))
    return act


def _rows_active(gs_ref, g, t_start: int, bt: int, seg_len: int, S: int):
    """[bt, 1] bool mask of occupied rows within this tile (padded rows
    past S*seg_len fall in no segment and come out False)."""
    rows = t_start + jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
    act = jnp.zeros((bt, 1), jnp.bool_)
    for p in range(S):
        lo = p * seg_len
        act = act | ((rows >= lo) & (rows < lo + gs_ref[g, p]))
    return act


def chunk_occupancy(counts, lo: int, hi: int):
    """Occupancy of capacity rows ``[lo, hi)`` given full-buffer prefix
    counts: prefix-filled buffers chunk into prefix-filled sub-buffers,
    ``clip(counts - lo, 0, hi - lo)``.  This is what the chunked a2a↔FEC
    pipeline (repro.models.moe) threads as per-chunk ``group_sizes`` so
    tile-skipping stays exact chunk-locally — a chunk past a group's
    prefix costs zero MXU tiles."""
    return jnp.clip(counts - lo, 0, hi - lo)


def _normalize_group_sizes(group_sizes, T: int, seg_len):
    """→ (gs [G, S] int32 clipped to [0, seg_len], seg_len) with
    S * seg_len == T.  A 1-D [G] input means one segment per group."""
    gs = jnp.asarray(group_sizes, jnp.int32)
    if gs.ndim == 1:
        gs = gs[:, None]
    S = gs.shape[1]
    if seg_len is None:
        assert T % S == 0, (T, S)
        seg_len = T // S
    assert S * seg_len == T, (S, seg_len, T)
    return jnp.clip(gs, 0, seg_len), int(seg_len)


# ---------------------------------------------------------------------------
# Forward kernels
# ---------------------------------------------------------------------------

def _fwd_kernel(gs_ref, x_ref, w_ref, o_ref, acc_ref, *,
                nd: int, bt: int, seg_len: int, S: int):
    g, t, d = pl.program_id(0), pl.program_id(1), pl.program_id(3)
    t0 = t * bt

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(_tile_active(gs_ref, g, t0, bt, seg_len, S))
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _done():
        mask = _rows_active(gs_ref, g, t0, bt, seg_len, S)
        o_ref[0] = jnp.where(mask, acc_ref[...], 0.0).astype(o_ref.dtype)


def _swiglu_kernel(gs_ref, x_ref, wg_ref, wi_ref, o_ref, accg_ref, acci_ref,
                   *, nd: int, bt: int, seg_len: int, S: int):
    g, t, d = pl.program_id(0), pl.program_id(1), pl.program_id(3)
    t0 = t * bt

    @pl.when(d == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        acci_ref[...] = jnp.zeros_like(acci_ref)

    @pl.when(_tile_active(gs_ref, g, t0, bt, seg_len, S))
    def _accum():
        x = x_ref[0]  # one VMEM read feeds both MXU passes
        accg_ref[...] += jnp.dot(x, wg_ref[0],
                                 preferred_element_type=jnp.float32)
        acci_ref[...] += jnp.dot(x, wi_ref[0],
                                 preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _done():
        mask = _rows_active(gs_ref, g, t0, bt, seg_len, S)
        h = jax.nn.silu(accg_ref[...]) * acci_ref[...]
        o_ref[0] = jnp.where(mask, h, 0.0).astype(o_ref.dtype)


def _dw_kernel(gs_ref, x_ref, dy_ref, o_ref, acc_ref, *,
               nt: int, bt: int, seg_len: int, S: int):
    """dw[g] = Σ_valid rows x[g]ᵀ dy[g]; the row-tile loop is innermost so
    empty tiles are skipped the same way as in the forward."""
    g, t = pl.program_id(0), pl.program_id(3)
    t0 = t * bt

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(_tile_active(gs_ref, g, t0, bt, seg_len, S))
    def _accum():
        mask = _rows_active(gs_ref, g, t0, bt, seg_len, S)
        xm = jnp.where(mask, x_ref[0], 0.0)
        acc_ref[...] += jax.lax.dot_general(
            xm, dy_ref[0], dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call plumbing
# ---------------------------------------------------------------------------

def _fwd_impl(x, w, gs, seg_len, bt, bf, bd, interpret):
    G, T, D = x.shape
    F = w.shape[2]
    S = gs.shape[1]
    x, _ = _pad_to(x, 1, bt)
    x, _ = _pad_to(x, 2, bd)
    w, _ = _pad_to(w, 1, bd)
    w, _ = _pad_to(w, 2, bf)
    Tp, Dp, Fp = x.shape[1], x.shape[2], w.shape[2]
    nt, nf, nd = Tp // bt, Fp // bf, Dp // bd
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, nt, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda g, t, f, d, gs_ref: (g, t, d)),
            pl.BlockSpec((1, bd, bf), lambda g, t, f, d, gs_ref: (g, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bt, bf),
                               lambda g, t, f, d, gs_ref: (g, t, f)),
        scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, nd=nd, bt=bt, seg_len=seg_len, S=S),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, Tp, Fp), x.dtype),
        interpret=interpret,
    )(gs, x, w)
    return out[:, :T, :F]


def _swiglu_impl(x, wg, wi, gs, seg_len, bt, bf, bd, interpret):
    G, T, D = x.shape
    F = wg.shape[2]
    S = gs.shape[1]
    x, _ = _pad_to(x, 1, bt)
    x, _ = _pad_to(x, 2, bd)
    wg, _ = _pad_to(wg, 1, bd)
    wg, _ = _pad_to(wg, 2, bf)
    wi, _ = _pad_to(wi, 1, bd)
    wi, _ = _pad_to(wi, 2, bf)
    Tp, Dp, Fp = x.shape[1], x.shape[2], wg.shape[2]
    nt, nf, nd = Tp // bt, Fp // bf, Dp // bd
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, nt, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda g, t, f, d, gs_ref: (g, t, d)),
            pl.BlockSpec((1, bd, bf), lambda g, t, f, d, gs_ref: (g, d, f)),
            pl.BlockSpec((1, bd, bf), lambda g, t, f, d, gs_ref: (g, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bt, bf),
                               lambda g, t, f, d, gs_ref: (g, t, f)),
        scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32),
                        pltpu.VMEM((bt, bf), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_swiglu_kernel, nd=nd, bt=bt, seg_len=seg_len, S=S),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, Tp, Fp), x.dtype),
        interpret=interpret,
    )(gs, x, wg, wi)
    return out[:, :T, :F]


def _dw_impl(x, dy, gs, seg_len, bt, bf, bd, interpret):
    G, T, D = x.shape
    F = dy.shape[2]
    S = gs.shape[1]
    x, _ = _pad_to(x, 1, bt)
    x, _ = _pad_to(x, 2, bd)
    dy, _ = _pad_to(dy, 1, bt)
    dy, _ = _pad_to(dy, 2, bf)
    Tp, Dp, Fp = x.shape[1], x.shape[2], dy.shape[2]
    nt, nk, nf = Tp // bt, Dp // bd, Fp // bf
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, nk, nf, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda g, k, f, t, gs_ref: (g, t, k)),
            pl.BlockSpec((1, bt, bf), lambda g, k, f, t, gs_ref: (g, t, f)),
        ],
        out_specs=pl.BlockSpec((1, bd, bf),
                               lambda g, k, f, t, gs_ref: (g, k, f)),
        scratch_shapes=[pltpu.VMEM((bd, bf), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_dw_kernel, nt=nt, bt=bt, seg_len=seg_len, S=S),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, Dp, Fp), jnp.float32),
        interpret=interpret,
    )(gs, x, dy)
    return out[:, :D, :F]


# ---------------------------------------------------------------------------
# Custom VJPs (the ragged BEC)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _ragged_gmm(x, w, gs, seg_len, bt, bf, bd, interpret):
    return _fwd_impl(x, w, gs, seg_len, bt, bf, bd, interpret)


def _ragged_gmm_fwd(x, w, gs, seg_len, bt, bf, bd, interpret):
    return _fwd_impl(x, w, gs, seg_len, bt, bf, bd, interpret), (x, w, gs)


def _ragged_gmm_bwd(seg_len, bt, bf, bd, interpret, res, dy):
    x, w, gs = res
    # dx: ragged over the same row occupancy, contraction now over F.
    dx = _fwd_impl(dy, jnp.swapaxes(w, 1, 2), gs, seg_len,
                   bt, bd, bf, interpret)
    dw = _dw_impl(x, dy, gs, seg_len, bt, bf, bd, interpret)
    return (dx.astype(x.dtype), dw.astype(w.dtype),
            np.zeros(gs.shape, jax.dtypes.float0))


_ragged_gmm.defvjp(_ragged_gmm_fwd, _ragged_gmm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _gmm_swiglu(x, wg, wi, gs, seg_len, bt, bf, bd, interpret):
    return _swiglu_impl(x, wg, wi, gs, seg_len, bt, bf, bd, interpret)


def _gmm_swiglu_fwd(x, wg, wi, gs, seg_len, bt, bf, bd, interpret):
    out = _swiglu_impl(x, wg, wi, gs, seg_len, bt, bf, bd, interpret)
    return out, (x, wg, wi, gs)


def _gmm_swiglu_bwd(seg_len, bt, bf, bd, interpret, res, dy):
    x, wg, wi, gs = res
    # Recompute both projections ragged (cheaper than saving two [G,T,F]
    # activations across the backward a2a window).
    a = _fwd_impl(x, wg, gs, seg_len, bt, bf, bd, interpret)
    b = _fwd_impl(x, wi, gs, seg_len, bt, bf, bd, interpret)
    a32, b32, dy32 = (a.astype(jnp.float32), b.astype(jnp.float32),
                      dy.astype(jnp.float32))
    s = jax.nn.sigmoid(a32)
    da = (dy32 * b32 * (s * (1.0 + a32 * (1.0 - s)))).astype(x.dtype)
    db = (dy32 * (a32 * s)).astype(x.dtype)
    dx = (_fwd_impl(da, jnp.swapaxes(wg, 1, 2), gs, seg_len,
                    bt, bd, bf, interpret)
          + _fwd_impl(db, jnp.swapaxes(wi, 1, 2), gs, seg_len,
                      bt, bd, bf, interpret))
    dwg = _dw_impl(x, da, gs, seg_len, bt, bf, bd, interpret)
    dwi = _dw_impl(x, db, gs, seg_len, bt, bf, bd, interpret)
    return (dx.astype(x.dtype), dwg.astype(wg.dtype), dwi.astype(wi.dtype),
            np.zeros(gs.shape, jax.dtypes.float0))


_gmm_swiglu.defvjp(_gmm_swiglu_fwd, _gmm_swiglu_bwd)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

# prophetlint: bounded(seg_len): shape-derived — T // S from the traced
#   capacity-buffer shape (one value per (shape, chunking) pair)
# prophetlint: bounded(bt): config — MXU tile size
# prophetlint: bounded(bf): config — MXU tile size
# prophetlint: bounded(bd): config — MXU tile size
# prophetlint: bounded(interpret): bool
@functools.partial(jax.jit,
                   static_argnames=("seg_len", "bt", "bf", "bd", "interpret"))
def ragged_gmm(x, w, group_sizes, *, seg_len: int = None, bt: int = 128,
               bf: int = 128, bd: int = 128, interpret: bool = False):
    """[G,T,D] × [G,D,F] → [G,T,F], only the occupied prefix of each
    ``seg_len`` segment computed; rows past the count come out zero.

    ``group_sizes``: [G] (one segment) or [G, S] (S segments of
    ``seg_len`` rows each, ``S*seg_len == T``) occupancy counts.
    """
    gs, seg = _normalize_group_sizes(group_sizes, x.shape[1], seg_len)
    return _ragged_gmm(x, w, gs, seg, bt, bf, bd, interpret)


# prophetlint: bounded(seg_len): shape-derived — T // S from the traced
#   capacity-buffer shape (one value per (shape, chunking) pair)
# prophetlint: bounded(bt): config — MXU tile size
# prophetlint: bounded(bf): config — MXU tile size
# prophetlint: bounded(bd): config — MXU tile size
# prophetlint: bounded(interpret): bool
@functools.partial(jax.jit,
                   static_argnames=("seg_len", "bt", "bf", "bd", "interpret"))
def gmm_swiglu(x, wg, wi, group_sizes, *, seg_len: int = None, bt: int = 128,
               bf: int = 128, bd: int = 128, interpret: bool = False):
    """Fused ragged ``silu(x @ wg) * (x @ wi)`` — one pass over ``x``."""
    gs, seg = _normalize_group_sizes(group_sizes, x.shape[1], seg_len)
    return _gmm_swiglu(x, wg, wi, gs, seg, bt, bf, bd, interpret)


# ---------------------------------------------------------------------------
# Modeled cost (mirrors the kernels' tile predication exactly — feeds the
# perfmodel ragged-FEC term and the moe_ffn microbenchmark)
# ---------------------------------------------------------------------------

def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def active_row_tiles(T: int, group_sizes, seg_len: int = None,
                     *, bt: int = 128):
    """(active, total) row tiles across groups for the given occupancy."""
    # prophetlint: allow(host-sync): host-side cost model — callers pass
    #   engine-side numpy counts, never in-flight device arrays
    gs = np.asarray(group_sizes)
    if gs.ndim == 1:
        gs = gs[:, None]
        seg_len = T if seg_len is None else seg_len
    G, S = gs.shape
    if seg_len is None:
        seg_len = T // S
    nt = _ceil_to(T, bt) // bt
    active = 0
    for g in range(G):
        for t in range(nt):
            t0, t1 = t * bt, t * bt + bt
            # prophetlint: allow(host-sync): gs is host numpy (see above)
            if any(min(t1, p * seg_len + int(gs[g, p])) > max(t0, p * seg_len)
                   for p in range(S)):
                active += 1
    return active, G * nt


def modeled_flops(T: int, D: int, F: int, group_sizes, seg_len: int = None,
                  *, bt: int = 128, bf: int = 128, bd: int = 128,
                  num_mats: int = 1):
    """(ragged_flops, dense_flops) for ``num_mats`` [T,D]×[D,F] grouped
    matmuls under this occupancy, at the kernel's tile granularity."""
    active, total = active_row_tiles(T, group_sizes, seg_len, bt=bt)
    per_tile = 2 * bt * _ceil_to(D, bd) * _ceil_to(F, bf)
    return num_mats * active * per_tile, num_mats * total * per_tile
