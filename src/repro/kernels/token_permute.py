"""Pallas token-permutation kernels: fused capacity dispatch / combine.

The MoE hot path moves every token twice around the expert FFN: once
*into* the ``[G, C, d]`` capacity buffer (dispatch) and once back *out*
of it with the gate-weighted k-way reduction (combine).  The jnp
baseline (:func:`repro.models.moe.capacity_dispatch` /
``capacity_combine``) pays an un-modeled memory tax on both legs:

* dispatch materializes a ``[N·k, d]`` token *repeat* and scatter-adds
  it into the buffer — the activations cross HBM ``k``× more often than
  the information content requires, and the serialized ``.at[].add``
  read-modify-writes the whole buffer on top;
* combine gathers ``[N, k, d]`` and upcasts **all of it** to f32 for
  the gate einsum — a ``k × 2×`` (bf16→f32) activation blow-up per
  layer, forward and (transposed) backward.

These kernels make token movement load-proportional, the same way
:mod:`repro.kernels.ragged_gmm` did for the expert FLOPs:

* :func:`dispatch_tokens` — a *sorted-gather* scatter.  The
  ``(bucket, pos)`` layout from ``capacity_positions`` is inverted
  once (cheap int32 ops) into a per-slot source-row map, turning the
  scatter into a race-free gather: each occupied capacity slot pulls
  its token row straight from ``x`` — no ``jnp.repeat``, no
  ``.at[].add``, one read of ``x`` and one write of the buffer.
* :func:`combine_tokens` — the transpose gather with the gate-weighted
  k-way accumulation fused into the epilogue: each output row
  accumulates its k gathered buffer rows in f32 *registers* and casts
  once on the way out — the ``[N, k, d]`` f32 intermediate never
  exists.

Numerics: dispatch is pure data movement — bit-identical to the jnp
scatter path.  Combine accumulates in f32 in ascending choice order,
the same order as ``ref.combine_tokens_ref``; agreement is exact up to
XLA's FP contraction (the compiler may FMA-fuse a product into an add
in one program but not the other), i.e. bit-exact for k = 1 and within
1 ulp per add for k > 1.

The two are transposes of each other, so each custom VJP reuses the
other kernel: ``dispatch``'s dx is a ``combine`` over the same slot
map, ``combine``'s dbuf is a gate-weighted ``dispatch`` of the
cotangent, and the gate cotangent is a per-(token, choice) row-dot
(segment-sum over d) computed with f32 accumulation but bf16 operands.
That row-dot is the one place the backward still gathers ``[N, k, d]``
(in the input dtype — never f32): the no-materialization claim above
is a *forward-path* property, and the gate-cotangent gather is the
remaining candidate for a fused kernel (ROADMAP).

Memory model (``*_modeled_bytes``; mirrored by
``PerfModel.t_dispatch`` / ``t_combine`` — the agreement is pinned to
< 1e-12 in ``benchmarks/perfmodel_accuracy.py``).  With ``N`` local
tokens, ``k`` choices, ``G·C`` capacity slots and itemsize ``B``:

=============  =======================================  ==============
leg            jnp baseline                             Pallas kernel
=============  =======================================  ==============
dispatch       ``B·d·(N + 2Nk + 3GC)``                  ``B·d·(N + GC)``
combine        ``B·d·(2Nk + N) + 8·d·Nk``               ``B·d·(GC + N)``
=============  =======================================  ==============

(The jnp dispatch terms are repeat write+read and buffer init +
read-modify-write; the jnp combine terms are gather read, ``[N,k,d]``
write, and its f32 copy write+read.  The kernels stream ``x`` and the
buffer exactly once each.)

VMEM budget per grid step: the full token (dispatch) or buffer
(combine) panel of one ``bd``-wide d-slice stays resident across the
row-tile loop — ``N·bd`` resp. ``G·C·bd`` elements (≈2–5 MiB in bf16
at model sizes) plus one ``bt×bd`` output tile, inside the ~16 MiB/core
budget.  The slot→row maps and the per-slot weights ride in SMEM via
scalar prefetch (weights bitcast to int32 for portability).

Contract notes:
* ``(bucket, pos)`` pairs of *valid* (in-range) choices must be unique —
  guaranteed when callers keep the dispatch layout from
  ``capacity_positions`` and mark dropped choices with the bucket
  sentinel (≥ G) rather than clamping them onto a real bucket: a
  zero-gate clamp contributes nothing forward but can collide with a
  genuine slot, and the backward's sorted-gather inversion (one source
  per slot) would then drop the genuine cotangent.
* Out-of-range buckets (sentinel ≥ G) and over-capacity positions
  (pos ≥ C) drop on dispatch and contribute zero on combine, matching
  the jnp ``mode="drop"`` / ``mode="fill"`` semantics.
* Chunk compatibility: the kernels reproduce the exact slot layout of
  the jnp path, so the chunked a2a↔FEC pipeline's per-chunk capacity
  slices ``[lo, hi)`` land identically and ``chunk_occupancy`` stays
  exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gmm import _pad_to

# Second-to-minor block dims are padded to this (covers bf16's 16-row
# sublane tiling; harmless for f32's 8).
_SUBLANE = 16


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _f32_bits(x):
    """f32 → int32 bit pattern (scalar-prefetch SMEM arrays are int32)."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)


# ---------------------------------------------------------------------------
# Slot-map planning (trace-time int32 ops, shared by fwd + bwd)
# ---------------------------------------------------------------------------

def _plan_dispatch(expert, pos, num_buckets: int, capacity: int, weights):
    """Invert (token, choice) → (bucket, pos) into per-slot source maps.

    Returns (tsrc [G·C] int32 — source token row, -1 ⇔ slot empty;
    wrow [G·C] f32 — per-slot scale, 0 for empty slots).  Because
    ``pos`` is the arrival rank within its bucket, occupied slots are
    hit by exactly one (token, choice): the scatter below is race-free
    and the kernel becomes a pure gather.
    """
    N, k = expert.shape
    e = expert.reshape(-1).astype(jnp.int32)
    p = pos.reshape(-1).astype(jnp.int32)
    valid = (e >= 0) & (e < num_buckets) & (p >= 0) & (p < capacity)
    slots = jnp.where(valid, e * capacity + p, num_buckets * capacity)
    src = jnp.full((num_buckets * capacity,), -1, jnp.int32).at[slots].set(
        jnp.arange(N * k, dtype=jnp.int32), mode="drop")
    tsrc = jnp.where(src >= 0, src // k, -1)
    if weights is None:
        wrow = (src >= 0).astype(jnp.float32)
    else:
        wrow = jnp.where(
            src >= 0,
            weights.reshape(-1).astype(jnp.float32)[jnp.maximum(src, 0)],
            0.0)
    return tsrc, wrow


def _plan_combine(expert, pos, gate, num_buckets: int, capacity: int):
    """(srow [N·k] int32 flat slot or -1, grow [N·k] f32 zeroed-invalid)."""
    e = expert.reshape(-1).astype(jnp.int32)
    p = pos.reshape(-1).astype(jnp.int32)
    valid = (e >= 0) & (e < num_buckets) & (p >= 0) & (p < capacity)
    srow = jnp.where(valid, e * capacity + p, -1).astype(jnp.int32)
    grow = jnp.where(valid, gate.reshape(-1).astype(jnp.float32), 0.0)
    return srow, grow


def _rowdot(buf, xlike, expert, pos):
    """Per-(token, choice) row dot ⟨buf[e, p], xlike[n]⟩ — the gate /
    weight cotangent (a segment-sum over d).  OOB slots gather zeros, so
    dropped choices come out 0.  Accumulates in f32 without an explicit
    upcast of the gathered rows."""
    vals = buf.at[expert, pos].get(mode="fill", fill_value=0)   # [N,k,d]
    return jnp.einsum("nkd,nd->nk", vals, xlike,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------

def _dispatch_kernel(tsrc_ref, wbits_ref, x_ref, o_ref, *, bt: int):
    """One [bt, bd] tile of the flattened [G·C, d] buffer: each row
    gathers its source token row (or zeros) scaled by its slot weight."""
    r0 = pl.program_id(1) * bt

    def row(i, carry):
        t = tsrc_ref[r0 + i]
        w = jax.lax.bitcast_convert_type(wbits_ref[r0 + i], jnp.float32)
        src = pl.load(x_ref, (pl.ds(jnp.maximum(t, 0), 1), slice(None)))
        val = jnp.where(t >= 0, src.astype(jnp.float32) * w, 0.0)
        pl.store(o_ref, (pl.ds(i, 1), slice(None)), val.astype(o_ref.dtype))
        return carry

    jax.lax.fori_loop(0, bt, row, 0)


def _combine_kernel(srow_ref, gbits_ref, buf_ref, o_ref, *, bt: int, k: int):
    """One [bt, bd] tile of y: each token row accumulates its k gathered
    buffer rows × gate in f32 registers, casting once in the epilogue —
    no [N, k, d] intermediate, let alone an f32 one."""
    r0 = pl.program_id(1) * bt
    bd = o_ref.shape[1]

    def row(i, carry):
        acc = jnp.zeros((1, bd), jnp.float32)
        for j in range(k):                      # static unroll, ascending j
            s = srow_ref[(r0 + i) * k + j]
            g = jax.lax.bitcast_convert_type(gbits_ref[(r0 + i) * k + j],
                                             jnp.float32)
            v = pl.load(buf_ref, (pl.ds(jnp.maximum(s, 0), 1), slice(None)))
            acc = acc + jnp.where(s >= 0, v.astype(jnp.float32) * g, 0.0)
        pl.store(o_ref, (pl.ds(i, 1), slice(None)), acc.astype(o_ref.dtype))
        return carry

    jax.lax.fori_loop(0, bt, row, 0)


# ---------------------------------------------------------------------------
# pallas_call plumbing
# ---------------------------------------------------------------------------

def _dispatch_impl(x, tsrc, wrow, num_buckets, capacity, bt, bd, interpret):
    N, d = x.shape
    R = num_buckets * capacity
    x, _ = _pad_to(x, 0, _SUBLANE)
    x, _ = _pad_to(x, 1, bd)
    Rp = _ceil_to(max(R, 1), bt)
    tsrc = jnp.pad(tsrc, (0, Rp - R), constant_values=-1)
    wrow = jnp.pad(wrow, (0, Rp - R))
    nr, ndb = Rp // bt, x.shape[1] // bd
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        # d outermost so the resident x panel is fetched once per slice.
        grid=(ndb, nr),
        in_specs=[pl.BlockSpec((x.shape[0], bd),
                               lambda dd, r, ts, ws: (0, dd))],
        out_specs=pl.BlockSpec((bt, bd), lambda dd, r, ts, ws: (r, dd)),
    )
    # prophetlint: allow(pallas-vmem): the resident x panel is
    #   (N_padded, bd) — N is the per-device token count, ≤ a few K rows
    #   · 128 lanes · 4 B ≈ 2 MiB for every config in configs/; the
    #   whole point of the d-outermost grid is keeping it VMEM-resident
    out = pl.pallas_call(
        functools.partial(_dispatch_kernel, bt=bt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Rp, x.shape[1]), x.dtype),
        interpret=interpret,
    )(tsrc, _f32_bits(wrow), x)
    return out[:R, :d].reshape(num_buckets, capacity, d)


def _combine_impl(buf, srow, grow, N, k, bt, bd, interpret):
    G, C, d = buf.shape
    flat = buf.reshape(G * C, d)
    flat, _ = _pad_to(flat, 0, _SUBLANE)
    flat, _ = _pad_to(flat, 1, bd)
    Np = _ceil_to(max(N, 1), bt)
    srow = jnp.pad(srow, (0, (Np - N) * k), constant_values=-1)
    grow = jnp.pad(grow, (0, (Np - N) * k))
    nr, ndb = Np // bt, flat.shape[1] // bd
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ndb, nr),
        in_specs=[pl.BlockSpec((flat.shape[0], bd),
                               lambda dd, r, ss, gs: (0, dd))],
        out_specs=pl.BlockSpec((bt, bd), lambda dd, r, ss, gs: (r, dd)),
    )
    # prophetlint: allow(pallas-vmem): the resident buffer panel is
    #   (G·C padded, bd) — capacity slots ≈ top_k · capacity_factor ·
    #   local tokens, same ≤ few-MiB bound as the dispatch leg
    out = pl.pallas_call(
        functools.partial(_combine_kernel, bt=bt, k=k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Np, flat.shape[1]), buf.dtype),
        interpret=interpret,
    )(srow, _f32_bits(grow), flat)
    return out[:N, :d]


# ---------------------------------------------------------------------------
# Custom VJPs (each leg's backward is the other leg)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _dispatch(x, w, expert, pos, num_buckets, capacity, bt, bd, interpret,
              need_dw):
    tsrc, wrow = _plan_dispatch(expert, pos, num_buckets, capacity, w)
    return _dispatch_impl(x, tsrc, wrow, num_buckets, capacity, bt, bd,
                          interpret)


def _dispatch_fwd(x, w, expert, pos, num_buckets, capacity, bt, bd,
                  interpret, need_dw):
    out = _dispatch(x, w, expert, pos, num_buckets, capacity, bt, bd,
                    interpret, need_dw)
    return out, (x, w, expert, pos)


def _dispatch_bwd(num_buckets, capacity, bt, bd, interpret, need_dw, res,
                  dbuf):
    x, w, expert, pos = res
    N, k = expert.shape
    # dx[n] = Σ_j w[n,j] · dbuf[e,p] — the transpose gather, i.e. combine.
    srow, grow = _plan_combine(expert, pos, w, num_buckets, capacity)
    dx = _combine_impl(dbuf, srow, grow, N, k, bt, bd, interpret)
    dw = (_rowdot(dbuf, x, expert, pos) if need_dw
          else jnp.zeros(w.shape, jnp.float32))
    return (dx.astype(x.dtype), dw.astype(w.dtype),
            np.zeros(expert.shape, jax.dtypes.float0),
            np.zeros(pos.shape, jax.dtypes.float0))


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _combine(buf, gate, expert, pos, bt, bd, interpret):
    G, C, _ = buf.shape
    N, k = expert.shape
    srow, grow = _plan_combine(expert, pos, gate, G, C)
    return _combine_impl(buf, srow, grow, N, k, bt, bd, interpret)


def _combine_fwd(buf, gate, expert, pos, bt, bd, interpret):
    out = _combine(buf, gate, expert, pos, bt, bd, interpret)
    return out, (buf, gate, expert, pos)


def _combine_bwd(bt, bd, interpret, res, dy):
    buf, gate, expert, pos = res
    G, C, _ = buf.shape
    # dbuf[e,p] = gate[n,j] · dy[n] — the gate-weighted dispatch of dy.
    tsrc, wrow = _plan_dispatch(expert, pos, G, C, gate)
    dbuf = _dispatch_impl(dy, tsrc, wrow, G, C, bt, bd, interpret)
    dgate = _rowdot(buf, dy, expert, pos)       # segment-sum over d
    return (dbuf.astype(buf.dtype), dgate.astype(gate.dtype),
            np.zeros(expert.shape, jax.dtypes.float0),
            np.zeros(pos.shape, jax.dtypes.float0))


_combine.defvjp(_combine_fwd, _combine_bwd)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

# prophetlint: bounded(num_buckets): config — the expert count from the
#   model config
# prophetlint: bounded(capacity): shape-derived — top_k · capacity_factor
#   · local tokens, fixed by the traced batch shape
# prophetlint: bounded(bt): config — tile size
# prophetlint: bounded(bd): config — tile size
# prophetlint: bounded(interpret): bool
@functools.partial(jax.jit, static_argnames=("num_buckets", "capacity",
                                             "bt", "bd", "interpret"))
def dispatch_tokens(x, expert, pos, *, num_buckets: int, capacity: int,
                    weights=None, bt: int = 128, bd: int = 128,
                    interpret: bool = False):
    """Scatter ``x [N, d]`` into ``[num_buckets, capacity, d]`` by the
    precomputed ``(expert, pos) [N, k]`` slot layout — as a sorted
    gather, with no token repeat and no serialized scatter-add.

    ``weights`` (optional ``[N, k]`` f32) scales each slot's row — this
    is how :func:`combine_tokens`'s backward reuses the kernel with the
    gates.  Out-of-range buckets and over-capacity positions drop.
    Bit-identical to the jnp scatter path for ``weights=None``.
    """
    N, k = expert.shape
    need_dw = weights is not None
    w = (jnp.ones((N, k), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    return _dispatch(x, w, expert.astype(jnp.int32), pos.astype(jnp.int32),
                     num_buckets, capacity, bt, bd, interpret, need_dw)


# prophetlint: bounded(bt): config — tile size
# prophetlint: bounded(bd): config — tile size
# prophetlint: bounded(interpret): bool
@functools.partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def combine_tokens(buf, expert, pos, gate, *, bt: int = 128, bd: int = 128,
                   interpret: bool = False):
    """Gather per-(token, choice) rows of ``buf [G, C, d]`` and
    gate-combine: ``y[n] = Σ_j gate[n,j] · buf[e[n,j], pos[n,j]]`` in
    ``buf.dtype``, accumulated in f32 registers (ascending j) — the
    ``[N, k, d]`` intermediate is never materialized in any dtype."""
    return _combine(buf, gate.astype(jnp.float32),
                    expert.astype(jnp.int32), pos.astype(jnp.int32),
                    bt, bd, interpret)


# ---------------------------------------------------------------------------
# Modeled HBM traffic (the table in the module docstring — feeds the
# perfmodel permute terms and the dispatch microbenchmark; agreement
# with PerfModel.t_dispatch/t_combine pinned in perfmodel_accuracy.py)
# ---------------------------------------------------------------------------

def dispatch_modeled_bytes(n_tokens: int, capacity_slots: int, d_model: int,
                           *, top_k: int = 1, itemsize: int = 2,
                           pallas: bool = True) -> float:
    """HBM bytes of one capacity dispatch of ``n_tokens`` rows into
    ``capacity_slots`` (= G·C) slots.  jnp: token read + [N·k, d] repeat
    write+read + buffer init + scatter-add read-modify-write.  Pallas:
    one token-panel read + one buffer write."""
    if pallas:
        return float((n_tokens + capacity_slots) * d_model * itemsize)
    return float((n_tokens + 2 * n_tokens * top_k + 3 * capacity_slots)
                 * d_model * itemsize)


def combine_modeled_bytes(n_tokens: int, capacity_slots: int, d_model: int,
                          *, top_k: int = 1, itemsize: int = 2,
                          pallas: bool = True) -> float:
    """HBM bytes of one gate-combine.  jnp: [N, k, d] gather read+write
    plus its f32 copy write+read (the ``8·d·N·k`` term) plus the y
    write.  Pallas: one buffer-panel read + one y write."""
    if pallas:
        return float((capacity_slots + n_tokens) * d_model * itemsize)
    return float((2 * n_tokens * top_k + n_tokens) * d_model * itemsize
                 + 8 * n_tokens * top_k * d_model)
