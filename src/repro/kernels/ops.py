"""Jit'd public wrappers for the Pallas kernels.

On non-TPU backends the kernels run in ``interpret=True`` mode (the kernel
body executes as traced JAX ops) so the same call sites work everywhere;
on TPU they lower to real Mosaic kernels.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import flags as _flags
from . import flash_attention as _fa
from . import gmm as _gmm
from . import ragged_gmm as _rg
from . import token_permute as _tp


def _interpret() -> bool:
    # The backend cannot change after jax initializes, so ride the
    # once-per-process probe cache in repro.flags instead of re-calling
    # jax.default_backend() on every trace-time wrapper call (the same
    # re-probe PR 4 removed from flags.moe_pallas).
    return _flags._default_backend() != "tpu"


def gmm(x, w, *, bt: int = 128, bf: int = 128, bd: int = 128):
    """Grouped expert matmul [G,T,D]×[G,D,F]→[G,T,F]."""
    return _gmm.gmm(x, w, bt=bt, bf=bf, bd=bd, interpret=_interpret())


def ragged_gmm(x, w, group_sizes, *, seg_len: int = None, bt: int = 128,
               bf: int = 128, bd: int = 128):
    """Load-proportional grouped matmul: only the occupied prefix of each
    ``seg_len`` row segment is computed (see kernels.ragged_gmm)."""
    return _rg.ragged_gmm(x, w, group_sizes, seg_len=seg_len, bt=bt, bf=bf,
                          bd=bd, interpret=_interpret())


def gmm_swiglu(x, wg, wi, group_sizes, *, seg_len: int = None, bt: int = 128,
               bf: int = 128, bd: int = 128):
    """Fused ragged ``silu(x@wg) * (x@wi)`` — x is read from HBM once."""
    return _rg.gmm_swiglu(x, wg, wi, group_sizes, seg_len=seg_len, bt=bt,
                          bf=bf, bd=bd, interpret=_interpret())


def dispatch_tokens(x, expert, pos, *, num_buckets: int, capacity: int,
                    weights=None, bt: int = 128, bd: int = 128):
    """Capacity dispatch as a sorted gather: x [N,d] → [G,C,d] by the
    precomputed (expert, pos) slot layout — no [N·k, d] repeat, no
    serialized scatter-add (see kernels.token_permute)."""
    return _tp.dispatch_tokens(x, expert, pos, num_buckets=num_buckets,
                               capacity=capacity, weights=weights, bt=bt,
                               bd=bd, interpret=_interpret())


def combine_tokens(buf, expert, pos, gate, *, bt: int = 128, bd: int = 128):
    """Gate-weighted k-way combine fused into the gather epilogue — f32
    register accumulation, no [N, k, d] materialization."""
    return _tp.combine_tokens(buf, expert, pos, gate, bt=bt, bd=bd,
                              interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    scale: float = None, bq: int = 128, bk: int = 128):
    """Grouped-head attention.

    Accepts q [B,S,K,G,dh], k/v [B,S,K,dh] (the shape the model uses) or
    pre-flattened [BH,S,dh]."""
    if q.ndim == 5:
        B, S, K, G, dh = q.shape
        H = K * G
        qf = q.transpose(0, 2, 3, 1, 4).reshape(B * H, S, dh)
        kf = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, dh)
        vf = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, dh)
        o = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                                scale=scale, bq=bq, bk=bk,
                                interpret=_interpret())
        return o.reshape(B, K, G, S, dh).transpose(0, 3, 1, 2, 4)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, bq=bq, bk=bk,
                               interpret=_interpret())
