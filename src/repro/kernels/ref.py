"""Pure-jnp oracles for the Pallas kernels (ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gmm_ref(x, w):
    """[G,T,D] × [G,D,F] → [G,T,F] in f32 accumulation."""
    return jnp.einsum("gtd,gdf->gtf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def row_mask_ref(T: int, group_sizes, seg_len: int = None):
    """[G, T] bool — occupied rows under the segmented-prefix layout of
    repro.kernels.ragged_gmm (True ⇔ row within its segment's count)."""
    gs = jnp.asarray(group_sizes, jnp.int32)
    if gs.ndim == 1:
        gs = gs[:, None]
    S = gs.shape[1]
    seg_len = T // S if seg_len is None else seg_len
    rows = jnp.arange(T)
    seg = jnp.minimum(rows // seg_len, S - 1)
    within = rows - seg * seg_len
    # padded rows (>= S*seg_len) must come out False
    return (within < gs[:, seg]) & (rows < S * seg_len)[None, :]


def ragged_gmm_ref(x, w, group_sizes, seg_len: int = None):
    """Oracle for ragged_gmm: masked rows contribute/receive zeros."""
    mask = row_mask_ref(x.shape[1], group_sizes, seg_len)[..., None]
    xm = jnp.where(mask, x.astype(jnp.float32), 0.0)
    return jnp.einsum("gtd,gdf->gtf", xm,
                      w.astype(jnp.float32)).astype(x.dtype)


def gmm_swiglu_ref(x, wg, wi, group_sizes, seg_len: int = None):
    """Oracle for the fused SwiGLU epilogue: silu(x@wg) * (x@wi), ragged."""
    mask = row_mask_ref(x.shape[1], group_sizes, seg_len)[..., None]
    xm = jnp.where(mask, x.astype(jnp.float32), 0.0)
    a = jnp.einsum("gtd,gdf->gtf", xm, wg.astype(jnp.float32))
    b = jnp.einsum("gtd,gdf->gtf", xm, wi.astype(jnp.float32))
    return jnp.where(mask, jax.nn.silu(a) * b, 0.0).astype(x.dtype)


def dispatch_tokens_ref(x, expert, pos, num_buckets, capacity,
                        weights=None):
    """Oracle for token_permute.dispatch_tokens: scatter of (optionally
    weighted) token rows into the [G, C, d] slot layout, drops on
    out-of-range buckets / over-capacity positions.  Values go through
    the same f32-scale-then-cast the kernel epilogue applies, so the
    comparison is bit-exact."""
    N, k = expert.shape
    d = x.shape[-1]
    w = (jnp.ones((N, k), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    rows = (x.astype(jnp.float32)[:, None, :] * w[..., None]).astype(x.dtype)
    buf = jnp.zeros((num_buckets, capacity, d), x.dtype)
    return buf.at[expert.reshape(-1), pos.reshape(-1)].add(
        rows.reshape(N * k, d), mode="drop")


def combine_tokens_ref(buf, expert, pos, gate):
    """Oracle for token_permute.combine_tokens: gather with fill-0 for
    dropped slots, gate-weighted sum accumulated in f32 in ascending
    choice order — the kernel's summation order.  Exact up to XLA's FP
    contraction: the compiler may FMA-fuse a product into an add on one
    side but not the other, so k > 1 float32 results can differ by
    ≤ 1 ulp per add (k = 1 and dispatch are bit-exact — no adds)."""
    N, k = expert.shape
    vals = buf.at[expert, pos].get(mode="fill", fill_value=0)   # [N,k,d]
    acc = jnp.zeros((N, buf.shape[-1]), jnp.float32)
    for j in range(k):
        acc = acc + (vals[:, j].astype(jnp.float32)
                     * gate[:, j:j + 1].astype(jnp.float32))
    return acc.astype(buf.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q,k,v [BH,S,dh] → [BH,S,dh]; naive masked softmax attention."""
    BH, S, dh = q.shape
    scale = dh ** -0.5 if scale is None else scale
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # Fully-masked rows (can't happen with causal self-attn) → zeros.
    p = jnp.where(mask.any(-1)[None, :, None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
