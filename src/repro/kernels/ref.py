"""Pure-jnp oracles for the Pallas kernels (ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gmm_ref(x, w):
    """[G,T,D] × [G,D,F] → [G,T,F] in f32 accumulation."""
    return jnp.einsum("gtd,gdf->gtf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q,k,v [BH,S,dh] → [BH,S,dh]; naive masked softmax attention."""
    BH, S, dh = q.shape
    scale = dh ** -0.5 if scale is None else scale
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # Fully-masked rows (can't happen with causal self-attn) → zeros.
    p = jnp.where(mask.any(-1)[None, :, None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
