"""Flash-attention Pallas kernel (causal / sliding-window).

Contract: q,k,v [BH, S, dh] (heads pre-flattened; GQA repeat handled by the
wrapper in ops.py).  Grid (BH, nq, nk) with the online-softmax state
(m, l, acc) in VMEM scratch carried across the innermost kv dimension;
each (1, bq, dh) q tile and (1, bk, dh) k/v tile is MXU-aligned.

Out-of-band tiles (kv block entirely above the causal diagonal or outside
the sliding window) still iterate but skip compute via @pl.when — block
*skipping* (grid pruning) is a recorded §Perf follow-up.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window, bq: int, bk: int, nk: int,
            seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Tile-level relevance: any (q, k) pair in range?
    q_first, q_last = qi * bq, qi * bq + bq - 1
    k_first, k_last = ki * bk, ki * bk + bk - 1
    relevant = True
    if causal:
        relevant = jnp.asarray(k_first <= q_last)
    if window is not None:
        relevant = jnp.logical_and(relevant,
                                   jnp.asarray(k_last > q_first - window))

    @pl.when(relevant)
    def _compute():
        s = jnp.dot(q_ref[0], k_ref[0].T,
                    preferred_element_type=jnp.float32) * scale
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - safe_m[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp(m_prev - safe_m))
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _pad_seq(x, mult: int):
    pad = (-x.shape[1]) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


# prophetlint: bounded(causal): bool
# prophetlint: bounded(interpret): bool
# prophetlint: bounded(window): config — sliding-window width fixed by
#   the model config (None or one int per process)
# prophetlint: bounded(scale): shape-derived — dh ** -0.5 from the traced
#   head dim (or a per-config constant)
# prophetlint: bounded(bq): config — MXU tile size
# prophetlint: bounded(bk): config — MXU tile size
@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    scale: float = None, bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q,k,v [BH, S, dh] → [BH, S, dh]."""
    BH, S, dh = q.shape
    scale = dh ** -0.5 if scale is None else scale
    q = _pad_seq(q, bq)
    k = _pad_seq(k, bk)
    v = _pad_seq(v, bk)
    Sq, Sk = q.shape[1], k.shape[1]
    nq, nk = Sq // bq, Sk // bk

    # prophetlint: allow(pallas-vmem): dh is the traced head dim, ≤ 256
    #   for every config in configs/ — tiles stay ≈ 4×(128·256)·4 B·2
    #   plus scratch, two orders of magnitude under the 16 MiB budget
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk, seq_len=S),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
