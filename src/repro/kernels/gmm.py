"""Grouped matmul Pallas kernel: [G,T,D] × [G,D,F] → [G,T,F].

One MXU-aligned (bt × bf) output tile per (group, t, f) grid cell,
accumulated over D in f32 VMEM scratch; the D loop is the innermost grid
dimension so the accumulator lives across its iterations.

VMEM budget per step: bt·bd + bd·bf + bt·bf (+f32 acc) — with the default
128³ tiles ≈ 192 KiB in bf16, comfortably inside the ~16 MiB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nd: int):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


# prophetlint: bounded(bt): config — MXU tile size
# prophetlint: bounded(bf): config — MXU tile size
# prophetlint: bounded(bd): config — MXU tile size
# prophetlint: bounded(interpret): bool
@functools.partial(jax.jit,
                   static_argnames=("bt", "bf", "bd", "interpret"))
def gmm(x, w, *, bt: int = 128, bf: int = 128, bd: int = 128,
        interpret: bool = False):
    """Grouped matmul with zero-padding to tile multiples."""
    G, T, D = x.shape
    G2, D2, F = w.shape
    assert G == G2 and D == D2, (x.shape, w.shape)
    x, _ = _pad_to(x, 1, bt)
    x, _ = _pad_to(x, 2, bd)
    w, _ = _pad_to(w, 1, bd)
    w, _ = _pad_to(w, 2, bf)
    Tp, Dp, Fp = x.shape[1], x.shape[2], w.shape[2]
    nt, nf, nd = Tp // bt, Fp // bf, Dp // bd

    out = pl.pallas_call(
        functools.partial(_kernel, nd=nd),
        grid=(G, nt, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda g, t, f, d: (g, t, d)),
            pl.BlockSpec((1, bd, bf), lambda g, t, f, d: (g, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bt, bf), lambda g, t, f, d: (g, t, f)),
        out_shape=jax.ShapeDtypeStruct((G, Tp, Fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :T, :F]
