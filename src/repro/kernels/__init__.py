"""Pallas TPU kernels for the compute hot spots:

* ``gmm``             — grouped expert matmul (the MoE FEC/BEC the paper's
                        load balancing targets),
* ``flash_attention`` — block-wise online-softmax attention (prefill and
                        sliding-window layers).

``ops`` exposes jit'd wrappers (interpret=True off-TPU); ``ref`` holds the
pure-jnp oracles the tests sweep against.
"""
from . import ops, ref  # noqa: F401
