"""Pallas TPU kernels for the compute hot spots:

* ``gmm``             — dense grouped expert matmul (kept as the
                        capacity-padded baseline),
* ``ragged_gmm``      — load-proportional grouped matmul: takes the
                        per-(group, segment) token counts produced by the
                        MoE router (``group_sizes``) and skips MXU tiles
                        past each occupancy prefix, so FEC/BEC cost
                        follows the *actual* expert load the paper's
                        balancer is shaping rather than the capacity
                        bound.  Carries a custom VJP (ragged backward).
* ``gmm_swiglu``      — ragged_gmm with the SwiGLU gate fused into the
                        epilogue: ``silu(x@wg) * (x@wi)`` accumulates
                        both products from one VMEM-resident ``x`` tile
                        (one HBM read of the activations instead of two).
                        VMEM/step at 128³ tiles is ≈224 KiB — see
                        ragged_gmm.py for the budget breakdown.
* ``dispatch_tokens`` / ``combine_tokens`` — the token-permutation pair
                        (kernels.token_permute): capacity dispatch as a
                        sorted gather (no [N·k, d] activation repeat, no
                        serialized scatter-add) and the gate-weighted
                        k-way combine fused into the gather epilogue
                        (f32 register accumulation — the [N, k, d] f32
                        intermediate never exists).  Custom VJPs reuse
                        each other (the ops are transposes) plus a
                        per-choice row-dot for the gate cotangent.
                        Enabled via ``REPRO_DISPATCH_PALLAS``.
* ``flash_attention`` — block-wise online-softmax attention (prefill and
                        sliding-window layers).

``ops`` exposes jit'd wrappers (interpret=True off-TPU — the same call
sites run everywhere, incl. CPU CI); ``ref`` holds the pure-jnp oracles
the tests sweep against.  The model enables the ragged MoE path via
``REPRO_MOE_PALLAS`` (repro.flags.moe_pallas — default on for TPU).
"""
from . import ops, ref  # noqa: F401
