"""Expert relocation execution: the one-time weight/optimizer exchange
realizing the planner's owner re-layout (dynamic expert migration).

The engine plans migrations as a slot permutation per MoE layer
(``ExpertPlacement.slot_of``); physically, every expert-stacked array —
``wi``/``wg``/``wo`` and their AdamW ``mu``/``nu`` slabs — must be
re-ordered so slot ``s`` holds the expert the new placement assigns
there.  On an EP-sharded mesh the leading expert axis is sharded over
the ``model`` axis, so the gather ``new[s] = old[gather[s]]`` with
cross-device entries lowers to the EP-axis exchange (XLA SPMD inserts
the collective); on a single device it is a plain row permutation.

This runs OFF the training step — the trainer fires it only on a
placement-version bump whose owner layout actually changed (rare: once
per migration decision, amortized over the locality window), then
dispatches the next step with the matching ``expert_slot`` arrays.  The
optimizer slabs move with their expert, so the update math is exactly
permutation-equivariant: with global-norm clipping disabled the whole
training trajectory is bit-identical to the never-migrated run (the
clip's cross-expert reduction re-associates under permutation and may
differ in the last ulp).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks

Array = np.ndarray

_EXPERT_LEAVES = ("wi", "wg", "wo")


def split_gathers(cfg: ModelConfig, gather: Array) -> List[Optional[Array]]:
    """Split a stacked ``[L_moe, E]`` slot gather into per-stage chunks
    shaped ``[repeats, m_moe, E]`` (None for MoE-free stages) — the same
    layer order as ``repro.models.model._split_placements``."""
    gather = np.asarray(gather)
    out: List[Optional[Array]] = []
    off = 0
    for st in cfg.stages:
        m = len(blocks.moe_positions(st))
        n = m * st.repeats
        if m == 0:
            out.append(None)
        else:
            out.append(gather[off:off + n].reshape(
                (st.repeats, m, gather.shape[-1])))
        off += n
    assert off == gather.shape[0], (off, gather.shape)
    return out


def active_gathers(cfg: ModelConfig, gather: Array):
    """:func:`split_gathers`, with untouched layers dropped: per stage a
    dict ``{macro_pos_j: int32 [repeats, E]}`` holding only the macro
    positions whose gather differs from identity somewhere, or None for
    stages with nothing to move.  Keeps the exchange from touching the
    (usually many) layers a relocation never moved — only scan-stacked
    repeats of an affected position still travel together."""
    out: List[Optional[dict]] = []
    for st, chunk in zip(cfg.stages, split_gathers(cfg, gather)):
        if chunk is None:
            out.append(None)
            continue
        ident = np.arange(chunk.shape[-1])
        live = {str(j): jnp.asarray(chunk[:, j], jnp.int32)
                for j in range(chunk.shape[1])
                if not all(np.array_equal(row, ident)
                           for row in chunk[:, j])}
        out.append(live or None)
    return out


def _permute_stages(cfg: ModelConfig, stages_params, perms):
    """Re-order the expert-stacked leaves of the affected MoE layers:
    leaf shape ``[repeats, E, ...]``, per-repeat gather ``perm[j]``
    (int32 ``[repeats, E]``, keyed by macro position index)."""
    new_stages = []
    for st, sp, perm in zip(cfg.stages, stages_params, perms):
        if perm is None:
            new_stages.append(sp)
            continue
        sp = dict(sp)
        mpos = blocks.moe_positions(st)
        for j_str, rows in perm.items():
            pos = mpos[int(j_str)]
            lp = dict(sp[str(pos)])
            mp = dict(lp["moe"])
            for nm in _EXPERT_LEAVES:
                if nm in mp:
                    mp[nm] = jax.vmap(
                        lambda w, p: jnp.take(w, p, axis=0))(mp[nm], rows)
            lp["moe"] = mp
            sp[str(pos)] = lp
        new_stages.append(sp)
    return new_stages


def make_relocate_fn(cfg: ModelConfig):
    """Jitted ``(state, perms) -> state`` applying a slot gather to the
    expert-stacked params and optimizer moments.  ``perms`` is the
    :func:`active_gathers` list (a pytree — None entries and dict keys
    are structural, so distinct relocation patterns get their own cached
    trace; relocations are rare, patterns few).  The input state is
    donated: relocations reuse its buffers."""

    def fn(state, perms):
        params = dict(state.params)
        params["stages"] = _permute_stages(cfg, state.params["stages"],
                                           perms)
        opt = state.opt
        mu = dict(opt.mu)
        mu["stages"] = _permute_stages(cfg, opt.mu["stages"], perms)
        nu = dict(opt.nu)
        nu["stages"] = _permute_stages(cfg, opt.nu["stages"], perms)
        return type(state)(params, opt._replace(mu=mu, nu=nu))

    return jax.jit(fn, donate_argnums=(0,))


def apply_relocation(state, cfg: ModelConfig, gather: Array, *,
                     relocate_fn=None):
    """Convenience wrapper: split the engine's ``[L_moe, E]`` gather,
    drop untouched layers, and run the (freshly jitted unless supplied)
    exchange step.  A fully-identity gather is a no-op returning the
    state untouched."""
    perms = active_gathers(cfg, gather)
    if all(p is None for p in perms):
        return state
    fn = relocate_fn or make_relocate_fn(cfg)
    return fn(state, perms)
