"""Expert relocation execution: the one-time weight/optimizer exchange
realizing the planner's owner re-layout (dynamic expert migration).

The engine plans migrations as a slot permutation per MoE layer
(``ExpertPlacement.slot_of``); physically, every expert-stacked array —
``wi``/``wg``/``wo`` and their AdamW ``mu``/``nu`` slabs — must be
re-ordered so slot ``s`` holds the expert the new placement assigns
there.  On an EP-sharded mesh the leading expert axis is sharded over
the ``model`` axis, so the gather ``new[s] = old[gather[s]]`` with
cross-device entries lowers to the EP-axis exchange (XLA SPMD inserts
the collective); on a single device it is a plain row permutation.

This runs OFF the training step — the trainer fires it only on a
placement-version bump whose owner layout actually changed (rare: once
per migration decision, amortized over the locality window), then
dispatches the next step with the matching ``expert_slot`` arrays.  The
optimizer slabs move with their expert, so the update math is exactly
permutation-equivariant: with global-norm clipping disabled the whole
training trajectory is bit-identical to the never-migrated run (the
clip's cross-expert reduction re-associates under permutation and may
differ in the last ulp).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks

Array = np.ndarray

_EXPERT_LEAVES = ("wi", "wg", "wo")


def split_gathers(cfg: ModelConfig, gather: Array) -> List[Optional[Array]]:
    """Split a stacked ``[L_moe, E]`` slot gather into per-stage chunks
    shaped ``[repeats, m_moe, E]`` (None for MoE-free stages) — the same
    layer order as ``repro.models.model._split_placements``."""
    gather = np.asarray(gather)
    out: List[Optional[Array]] = []
    off = 0
    for st in cfg.stages:
        m = len(blocks.moe_positions(st))
        n = m * st.repeats
        if m == 0:
            out.append(None)
        else:
            out.append(gather[off:off + n].reshape(
                (st.repeats, m, gather.shape[-1])))
        off += n
    assert off == gather.shape[0], (off, gather.shape)
    return out


def active_gathers(cfg: ModelConfig, gather: Array):
    """:func:`split_gathers`, with untouched layers dropped: per stage a
    dict ``{macro_pos_j: int32 [repeats, E]}`` holding only the macro
    positions whose gather differs from identity somewhere, or None for
    stages with nothing to move.  Keeps the exchange from touching the
    (usually many) layers a relocation never moved — only scan-stacked
    repeats of an affected position still travel together."""
    out: List[Optional[dict]] = []
    for st, chunk in zip(cfg.stages, split_gathers(cfg, gather)):
        if chunk is None:
            out.append(None)
            continue
        ident = np.arange(chunk.shape[-1])
        live = {str(j): jnp.asarray(chunk[:, j], jnp.int32)
                for j in range(chunk.shape[1])
                if not all(np.array_equal(row, ident)
                           for row in chunk[:, j])}
        out.append(live or None)
    return out


def _permute_stages(cfg: ModelConfig, stages_params, perms):
    """Re-order the expert-stacked leaves of the affected MoE layers:
    leaf shape ``[repeats, E, ...]``, per-repeat gather ``perm[j]``
    (int32 ``[repeats, E]``, keyed by macro position index)."""
    new_stages = []
    for st, sp, perm in zip(cfg.stages, stages_params, perms):
        if perm is None:
            new_stages.append(sp)
            continue
        sp = dict(sp)
        mpos = blocks.moe_positions(st)
        for j_str, rows in perm.items():
            pos = mpos[int(j_str)]
            lp = dict(sp[str(pos)])
            mp = dict(lp["moe"])
            for nm in _EXPERT_LEAVES:
                if nm in mp:
                    mp[nm] = jax.vmap(
                        lambda w, p: jnp.take(w, p, axis=0))(mp[nm], rows)
            lp["moe"] = mp
            sp[str(pos)] = lp
        new_stages.append(sp)
    return new_stages


def make_relocate_fn(cfg: ModelConfig, *, donate: bool = True):
    """Jitted ``(state, perms) -> state`` applying a slot gather to the
    expert-stacked params and optimizer moments.  ``perms`` is the
    :func:`active_gathers` list (a pytree — None entries and dict keys
    are structural, so distinct relocation patterns get their own cached
    trace; relocations are rare, patterns few).  With ``donate=True``
    (default) the input state is donated so relocations reuse its
    buffers; the transactional path passes ``donate=False`` so the
    pre-exchange state survives a failed/corrupt exchange for rollback."""

    def fn(state, perms):
        params = dict(state.params)
        params["stages"] = _permute_stages(cfg, state.params["stages"],
                                           perms)
        opt = state.opt
        mu = dict(opt.mu)
        mu["stages"] = _permute_stages(cfg, opt.mu["stages"], perms)
        nu = dict(opt.nu)
        nu["stages"] = _permute_stages(cfg, opt.nu["stages"], perms)
        return type(state)(params, opt._replace(mu=mu, nu=nu))

    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def apply_relocation(state, cfg: ModelConfig, gather: Array, *,
                     relocate_fn=None):
    """Convenience wrapper: split the engine's ``[L_moe, E]`` gather,
    drop untouched layers, and run the (freshly jitted unless supplied)
    exchange step.  A fully-identity gather is a no-op returning the
    state untouched."""
    perms = active_gathers(cfg, gather)
    if all(p is None for p in perms):
        return state
    fn = relocate_fn or make_relocate_fn(cfg)
    return fn(state, perms)


# ---------------------------------------------------------------------------
# Transactional exchange: fingerprint → permute → verify → commit/rollback
# ---------------------------------------------------------------------------

def _device_fingerprints(state, cfg: ModelConfig, perms) -> dict:
    """Device-resident variant of :func:`expert_fingerprints` — the same
    ``{(stage, macro_j, slab, leaf): [repeats, E]}`` reductions left as
    lazy ``jnp`` arrays.  The prefetch path issues these alongside the
    staged exchange so both queue behind the in-flight step; the commit
    materializes them (tiny ``[repeats, E]`` transfers) only when the
    swap actually lands."""
    out = {}
    slabs = (("params", state.params["stages"]),
             ("mu", state.opt.mu["stages"]),
             ("nu", state.opt.nu["stages"]))
    for si, (st, perm) in enumerate(zip(cfg.stages, perms)):
        if perm is None:
            continue
        mpos = blocks.moe_positions(st)
        for j_str in perm:
            pos = mpos[int(j_str)]
            for slab_name, stages_tree in slabs:
                mp = stages_tree[si][str(pos)]["moe"]
                for nm in _EXPERT_LEAVES:
                    if nm not in mp:
                        continue
                    arr = mp[nm]
                    fp = jnp.sum(jnp.abs(arr.astype(jnp.float32)),
                                 axis=tuple(range(2, arr.ndim)))
                    out[(si, j_str, slab_name, nm)] = fp
    return out


def expert_fingerprints(state, cfg: ModelConfig, perms) -> dict:
    """Per-expert content fingerprints of every slab the exchange will
    touch: ``{(stage, macro_j, slab, leaf): np [repeats, E]}`` where each
    entry is ``sum(|row|)`` over the expert row's trailing axes in f32.

    The reduction runs *within* one expert's row, so it is bit-identical
    under any permutation of the expert axis — the property the
    round-trip check relies on: after a correct exchange,
    ``post[r] == pre[r][rows[r]]`` exactly, on one device or across the
    EP mesh (rows move intact; the recomputed sum reads the same bytes
    in the same order)."""
    return {k: np.asarray(v)
            for k, v in _device_fingerprints(state, cfg, perms).items()}


def _fingerprints_roundtrip(pre: dict, post: dict, perms) -> bool:
    """True iff every post-exchange fingerprint equals its pre-exchange
    fingerprint gathered through the planned permutation, bitwise."""
    for key, fp_post in post.items():
        si, j_str = key[0], key[1]
        rows = np.asarray(perms[si][j_str])
        fp_pre = pre[key]
        for r in range(rows.shape[0]):
            if not np.array_equal(fp_post[r], fp_pre[r][rows[r]]):
                return False
    return True


def _corrupt_first_touched_leaf(state, cfg: ModelConfig, perms):
    """Fault-injection helper: perturb one element of the first
    expert leaf the exchange touched (a corruption the fingerprint
    round-trip check must catch)."""
    for si, (st, perm) in enumerate(zip(cfg.stages, perms)):
        if perm is None:
            continue
        mpos = blocks.moe_positions(st)
        j_str = next(iter(perm))
        pos = mpos[int(j_str)]
        params = dict(state.params)
        stages = list(params["stages"])
        sp = dict(stages[si])
        lp = dict(sp[str(pos)])
        mp = dict(lp["moe"])
        nm = next(n for n in _EXPERT_LEAVES if n in mp)
        leaf = mp[nm]
        mp[nm] = leaf.at[(0,) * leaf.ndim].add(jnp.asarray(1.0, leaf.dtype))
        lp["moe"] = mp
        sp[str(pos)] = lp
        stages[si] = sp
        params["stages"] = stages
        return type(state)(params, state.opt)
    return state


def apply_relocation_transactional(state, cfg: ModelConfig, gather: Array,
                                   *, relocate_fn=None):
    """Transactional :func:`apply_relocation` → ``(state, ok)``.

    Fingerprints the touched expert slabs, runs a **non-donating**
    exchange, and verifies the fingerprint round-trip before committing:
    any exception mid-exchange or any fingerprint mismatch returns the
    original state untouched with ``ok=False`` (the caller falls back —
    see ``Trainer._maybe_relocate``).  A supplied ``relocate_fn`` must
    have been built with ``donate=False``; a donating one would free the
    rollback copy."""
    perms = active_gathers(cfg, gather)
    if all(p is None for p in perms):
        return state, True
    from repro.testing import faults as _faults
    try:
        pre = expert_fingerprints(state, cfg, perms)
        fn = relocate_fn or make_relocate_fn(cfg, donate=False)
        new_state = fn(state, perms)
        inj = _faults.active()
        if inj is not None:
            f = inj.relocation_fault()
            if f is not None:
                if f.payload.get("mode", "corrupt") == "raise":
                    raise _faults.InjectedFault(
                        f"injected relocation failure (#{f.at})")
                new_state = _corrupt_first_touched_leaf(new_state, cfg,
                                                        perms)
        post = expert_fingerprints(new_state, cfg, perms)
        if not _fingerprints_roundtrip(pre, post, perms):
            return state, False
        return new_state, True
    except Exception:
        return state, False


# ---------------------------------------------------------------------------
# Prefetched exchange: stage under the in-flight step, commit at the swap
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StagedRelocation:
    """An issued-but-uncommitted transactional exchange.

    ``stage_relocation`` enqueues the non-donating exchange and the
    fingerprint reductions on the device queue *behind* the step already
    dispatched — none of it blocks the host.  The trainer holds the
    handle for one step and calls :func:`commit_staged` when the
    placement swap is due; staleness is detected structurally (the
    trainer compares ``src_state`` identity and the gather bytes before
    committing).  ``faulted`` records a stage-time injected ``raise``
    fault so the commit reports the same failure the synchronous path
    would have."""
    gather: Array
    perms: Any
    pre: dict
    post: dict
    new_state: Any
    src_state: Any
    faulted: bool = False


def stage_relocation(state, cfg: ModelConfig, gather: Array, *,
                     relocate_fn=None) -> Optional[StagedRelocation]:
    """Issue the transactional exchange for ``gather`` without waiting
    for it: returns a :class:`StagedRelocation` whose ``new_state`` and
    fingerprints are lazy device arrays, or None for an identity gather.
    Fault injection fires here (stage time) so injected failures land on
    the same relocation occurrence as the synchronous path; any host-side
    exception is reported as a pre-faulted handle the commit turns into
    a clean ``(src_state, False)``."""
    perms = active_gathers(cfg, gather)
    if all(p is None for p in perms):
        return None
    from repro.testing import faults as _faults
    gather = np.asarray(gather).copy()
    try:
        pre = _device_fingerprints(state, cfg, perms)
        fn = relocate_fn or make_relocate_fn(cfg, donate=False)
        new_state = fn(state, perms)
        faulted = False
        inj = _faults.active()
        if inj is not None:
            f = inj.relocation_fault()
            if f is not None:
                if f.payload.get("mode", "corrupt") == "raise":
                    faulted = True
                else:
                    new_state = _corrupt_first_touched_leaf(new_state, cfg,
                                                            perms)
        post = _device_fingerprints(new_state, cfg, perms)
        return StagedRelocation(gather, perms, pre, post, new_state, state,
                                faulted=faulted)
    except Exception:
        return StagedRelocation(gather, perms, {}, {}, state, state,
                                faulted=True)


def commit_staged(staged: StagedRelocation):
    """Finish a staged exchange → ``(state, ok)`` with the same contract
    as :func:`apply_relocation_transactional`: verify the fingerprint
    round-trip (materializing the tiny ``[repeats, E]`` reductions — the
    only blocking transfers on the commit path) and return the exchanged
    state, or the untouched source state with ``ok=False``."""
    if staged.faulted:
        return staged.src_state, False
    try:
        pre = {k: np.asarray(v) for k, v in staged.pre.items()}
        post = {k: np.asarray(v) for k, v in staged.post.items()}
        if not _fingerprints_roundtrip(pre, post, staged.perms):
            return staged.src_state, False
        return staged.new_state, True
    except Exception:
        return staged.src_state, False
