"""Training loop with Pro-Prophet in the loop.

Per iteration (paper Fig. 5, adapted to JAX — DESIGN.md §3):

  1. device: jitted ``train_step(state, batch, placements)`` runs fwd+bwd
     with the *current* placements; MoE layers return their routing
     matrices (the profiled input distributions).
  2. host, overlapped with the next dispatch: the engine ingests the
     routing matrices, the locality planner (re)plans, and packs the
     placement arrays for the next step — the ``Plan`` primitive.
  3. ``Trans`` / shadow-compute / ``Agg`` all live *inside* the jitted
     step (repro.models.moe), so the placement handoff is the only
     host↔device traffic Pro-Prophet adds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import EngineConfig, HardwareSpec, ProProphetEngine
from repro.models import model as model_lib
from repro.optim import adamw
from repro.optim.adamw import AdamW, AdamWState, apply_updates
from repro.parallel import ParallelCtx


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(cfg: ModelConfig, ctx: ParallelCtx, optimizer: AdamW,
                    *, attn_impl: str = "auto", remat: bool = True,
                    donate: bool = True) -> Callable:
    """Build the jitted train step.  ``placements`` may be None (plain EP)
    or the engine's stacked arrays — each choice compiles once."""

    def step(state: TrainState, batch, placements=None):
        def lf(params):
            return model_lib.loss_fn(params, batch, cfg, ctx,
                                     placements=placements,
                                     attn_impl=attn_impl, remat=remat)
        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(state.params)
        updates, opt = optimizer.update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss}
        if aux.get("counts") is not None:
            metrics["counts"] = aux["counts"]
        return TrainState(params, opt), metrics

    return jax.jit(step, donate_argnums=(0,) if donate else ())


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    ctx: ParallelCtx
    optimizer: AdamW
    attn_impl: str = "auto"
    remat: bool = True
    # Pro-Prophet wiring (None ⇒ plain EP / dense model).
    engine: Optional[ProProphetEngine] = None

    def __post_init__(self):
        self._step_fn = make_train_step(self.cfg, self.ctx, self.optimizer,
                                        attn_impl=self.attn_impl,
                                        remat=self.remat)

    def init_state(self, key, dtype=jnp.float32) -> TrainState:
        params = model_lib.init_params(key, self.cfg, dtype)
        return TrainState(params, self.optimizer.init(params))

    def run(self, state: TrainState, batches, num_steps: int,
            log_every: int = 10, log_fn=print) -> tuple:
        history = []
        it = iter(batches)
        t0 = time.perf_counter()
        for step in range(num_steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            placements = None
            if self.engine is not None:
                placements = {k: jnp.asarray(v)
                              for k, v in self.engine.step_arrays().items()}
            state, metrics = self._step_fn(state, batch, placements)
            loss = float(metrics["loss"])
            if self.engine is not None and "counts" in metrics:
                # counts [L_moe, D_ep, E] observed this step → plan next.
                counts = np.asarray(metrics["counts"])
                self.engine.observe([counts[i].T.astype(np.float64).T
                                     for i in range(counts.shape[0])])
            history.append(loss)
            if log_every and step % log_every == 0:
                dt = time.perf_counter() - t0
                extra = ""
                if self.engine is not None:
                    pt = self.engine.predicted_times()
                    extra = (f" plan_speedup={pt['speedup']:.2f}x"
                             f" shadows={sum(p.num_shadowed for p in self.engine.placements)}")
                log_fn(f"step {step:5d} loss {loss:.4f} "
                       f"({dt / (step + 1):.3f}s/it){extra}")
        return state, history


def make_engine_for(cfg: ModelConfig, ctx: ParallelCtx, *,
                    policy: str = "pro_prophet",
                    replan_interval: int = 1,
                    bandwidth: float = 25e9,
                    flops_per_s: float = 70e12) -> Optional[ProProphetEngine]:
    """Engine wired to a model config (None for non-MoE archs)."""
    if cfg.moe is None:
        return None
    nm = 3 if cfg.ffn_kind == "swiglu" else 2
    hw = HardwareSpec.from_model_dims(
        cfg.d_model, cfg.moe.d_expert, bandwidth=bandwidth,
        flops_per_s=flops_per_s, num_ffn_mats=nm)
    ec = EngineConfig(
        num_experts=cfg.moe.num_experts,
        num_devices=max(ctx.ep_size, 1),
        num_moe_layers=cfg.num_moe_layers,
        s_max=cfg.moe.s_max,
        replan_interval=replan_interval,
        policy=policy,
    )
    return ProProphetEngine(ec, hw)
