"""Training loop with Pro-Prophet in the loop.

Per iteration (paper Fig. 5, adapted to JAX — DESIGN.md §3):

  1. device: jitted ``train_step(state, batch, placements)`` runs fwd+bwd
     with the *current* placements; MoE layers return their routing
     matrices (the profiled input distributions).
  2. host, overlapped with the device step: the engine ingests the
     routing matrices, the locality planner (re)plans, and packs the
     placement arrays for the next step — the ``Plan`` primitive.
  3. ``Trans`` / shadow-compute / ``Agg`` all live *inside* the jitted
     step (repro.models.moe), so the placement handoff is the only
     host↔device traffic Pro-Prophet adds.

Two runtimes drive the same jitted step (``REPRO_ASYNC_PLAN`` /
``Trainer.async_plan`` select one; async is the default):

* **sync** — the serial baseline: dispatch step *j*, block on its loss,
  ingest its counts and plan inline, then dispatch *j+1*.  Host planning
  sits fully on the critical path.
* **async** — the pipelined runtime: dispatch step *j* with the
  placements the planner finished by dispatch time, hand step *j*'s
  in-flight count array to a background planner thread
  (:class:`repro.train.runtime.PlanPipeline` — the per-layer searches
  fan out as futures on a small pool), and consume step *j−1*'s loss
  only after dispatching *j* (deferred ``device_get``).  Plan overlaps
  the device's backward half; the placement upload happens only when a
  placement actually changed (:class:`~repro.train.runtime.PlacementCache`).

Planning is one-step-delayed by design (the locality property), so both
runtimes compute *identical* losses and placements — the async mode only
changes when the host work happens.  ``tests/test_async_runtime.py``
asserts bit-identical histories.

With dynamic expert migration enabled (``EngineConfig.enable_migration``
/ ``REPRO_MIGRATION``), the planner may re-home persistently hot experts
instead of shadowing them.  The resulting relocation executes as an
infrequent jitted weight/optimizer exchange (``repro.train.relocate``)
on the dispatch path, exactly when the placement version carrying the
new ``expert_slot`` arrays is first dispatched — in the async runtime
this lands between ``wait()`` and ``submit()``, preserving the
one-step-delayed contract.

Both runtimes also dispatch the device-side chunked a2a↔FEC pipeline
(repro.models.moe): per step the engine's scheduler timeline picks the
chunk count K from the profiled stats (``Trainer._chunks_for_dispatch``;
``REPRO_A2A_CHUNKS`` overrides), the jitted step is specialized on K
(static arg, quantized to a few candidates), and the modeled a2a bytes /
hidden-comm fraction surface in :class:`~repro.train.runtime.StepStats`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags
from repro.configs.base import ModelConfig
from repro.core import EngineConfig, HardwareSpec, ProProphetEngine
from repro.models import model as model_lib
from repro.optim import adamw
from repro.optim.adamw import AdamW, AdamWState, apply_updates
from repro.parallel import ParallelCtx
from repro.train import relocate, sanitize
from repro.train.runtime import (OverlapTelemetry, PlacementCache, PlanEvent,
                                 PlanPipeline, StepStats, run_plan)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(cfg: ModelConfig, ctx: ParallelCtx, optimizer: AdamW,
                    *, attn_impl: str = "auto", remat: bool = True,
                    donate: bool = True) -> Callable:
    """Build the jitted train step.  ``placements`` may be None (plain EP)
    or the engine's stacked arrays; ``a2a_chunks`` is the static MoE
    a2a↔FEC chunk count — each (placements-shape, K) choice compiles
    once, and K is quantized to a few candidates by the engine so the
    jit cache stays small."""

    def step(state: TrainState, batch, placements=None, a2a_chunks=1):
        def lf(params):
            return model_lib.loss_fn(params, batch, cfg, ctx,
                                     placements=placements,
                                     attn_impl=attn_impl, remat=remat,
                                     a2a_chunks=a2a_chunks)
        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(state.params)
        updates, opt = optimizer.update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss}
        if aux.get("counts") is not None:
            metrics["counts"] = aux["counts"]
        return TrainState(params, opt), metrics

    # prophetlint: bounded(a2a_chunks): {1, 2, 4, 8} —
    #   EngineConfig.a2a_chunk_candidates; _chunks_for_dispatch quantizes
    #   every dispatch's K to this set so the jit cache stays small
    return jax.jit(step, donate_argnums=(0,) if donate else (),
                   static_argnames=("a2a_chunks",))


@dataclasses.dataclass
class RelocOutcome:
    """What one ``_maybe_relocate`` call did: experts re-homed, exchanges
    rolled back, rollbacks scheduled for a retry, and rollbacks declared
    persistent (migration cancelled, device back to the home layout)."""

    moved: int = 0
    failures: int = 0
    retries: int = 0
    persistent: int = 0


@dataclasses.dataclass
class _Pending:
    """A dispatched step whose metrics have not been consumed yet."""

    step: int
    metrics: Dict[str, Any]
    t_dispatch: float
    upload_time: float
    version: int
    fingerprint: str
    plan: Optional[PlanEvent] = None
    a2a_chunks: int = 1
    chunk_stats: Optional[Dict[str, float]] = None
    relocations: int = 0         # experts re-homed at this dispatch
    relocation_failures: int = 0 # exchanges rolled back at this dispatch
    relocation_retries: int = 0  # rollbacks scheduled for a retry
    relocation_persistent: int = 0  # rollbacks declared persistent
    health_state: str = "healthy"   # fleet health label at dispatch
    degraded_devices: int = 0
    lost_devices: int = 0


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    ctx: ParallelCtx
    optimizer: AdamW
    attn_impl: str = "auto"
    remat: bool = True
    # Pro-Prophet wiring (None ⇒ plain EP / dense model).
    engine: Optional[ProProphetEngine] = None
    # None ⇒ flags.async_plan() (REPRO_ASYNC_PLAN, default on).
    async_plan: Optional[bool] = None
    # Prefetched relocation: stage the weight/optimizer exchange one step
    # ahead (behind the in-flight step) and commit the pre-staged slabs at
    # the version swap instead of running the exchange on the dispatch
    # path.  None ⇒ flags.reloc_prefetch() (REPRO_RELOC_PREFETCH,
    # default off).
    reloc_prefetch: Optional[bool] = None

    def __post_init__(self):
        self._step_fn = make_train_step(self.cfg, self.ctx, self.optimizer,
                                        attn_impl=self.attn_impl,
                                        remat=self.remat)
        self._relocate_fn = None     # jitted lazily on first migration
        self._relocate_tx_fn = None  # non-donating twin (transactional)
        pf = flags.reloc_prefetch()
        self._prefetch = bool(self.reloc_prefetch if pf is None else pf)
        self._staged = None          # in-flight StagedRelocation, if any
        self._want_stage = None      # gather to stage after the dispatch
        self._reloc_hold = False     # dispatch on the held (old) arrays
        self._reloc_attempts = 0     # consecutive failed exchanges
        self._reloc_cooldown = 0     # dispatches to hold before a retry
        self._t_last_dispatch = None  # previous dispatch instant (health)
        if self.engine is not None:
            # The engine's device width is the single source of truth the
            # packed placement arrays are shaped with; it must match the
            # mesh's EP axis or the traced step mis-indexes shadow_devs.
            ep = max(self.ctx.ep_size, 1)
            assert self.engine.cfg.num_devices == ep, (
                f"engine planned for {self.engine.cfg.num_devices} devices "
                f"but the mesh EP axis has {ep}")

    def init_state(self, key, dtype=jnp.float32) -> TrainState:
        params = model_lib.init_params(key, self.cfg, dtype)
        return TrainState(params, self.optimizer.init(params))

    # ------------------------------------------------------------------
    def run(self, state: TrainState, batches, num_steps: int,
            log_every: int = 10, log_fn=print,
            stats_sink: Optional[List[StepStats]] = None,
            telemetry: Optional[OverlapTelemetry] = None,
            ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
            ckpt_keep: int = 3) -> tuple:
        """Train for ``num_steps``; returns ``(state, history)`` where
        ``history`` is the per-step float loss — identical between the
        sync and async runtimes.  ``stats_sink``/``telemetry`` collect the
        per-step :class:`StepStats` / aggregate overlap telemetry.

        ``ckpt_dir``/``ckpt_every``: save an atomic retained checkpoint
        (``repro.checkpoint.save_checkpoint``) every ``ckpt_every``
        completed steps, keeping the last ``ckpt_keep``.  Saves land in
        the planner-idle window and always in the home expert layout
        (``restore_home_layout`` first), so a restored run can bind a
        fresh engine."""
        sanitize.arm()
        use_async = (self.async_plan if self.async_plan is not None
                     else flags.async_plan())
        runner = self._run_async if use_async else self._run_sync
        return runner(state, iter(batches), num_steps, log_every, log_fn,
                      stats_sink, telemetry, ckpt_dir, ckpt_every, ckpt_keep)

    # -- shared pieces ---------------------------------------------------
    def _emit(self, stats: StepStats, history, t0, log_every, log_fn,
              stats_sink, telemetry) -> None:
        history.append(stats.loss)
        if stats_sink is not None:
            stats_sink.append(stats)
        if telemetry is not None:
            telemetry.record_stats(stats)
        if log_every and stats.step % log_every == 0:
            avg = (time.perf_counter() - t0) / (stats.step + 1)
            log_fn(stats.log_line(avg))

    def _observe_inline(self, counts_device) -> PlanEvent:
        """Sync-mode Plan: fetch counts and plan on the dispatch path."""
        event = run_plan(self.engine, counts_device)
        event.exposed = event.plan_time      # serial: fully exposed
        return event

    def _maybe_checkpoint(self, state: TrainState, step: int,
                          ckpt_dir: Optional[str], ckpt_every: int,
                          ckpt_keep: int) -> TrainState:
        """Cadenced atomic checkpoint after ``step`` completed steps.
        Runs on the dispatch path (async: in the planner-idle window) and
        returns the state in the home expert layout — the next dispatch
        simply re-executes any still-planned relocation."""
        if not ckpt_dir or not ckpt_every or step <= 0 \
                or step % ckpt_every != 0:
            return state
        from repro.checkpoint import ckpt as _ckpt
        state = self.restore_home_layout(state)
        extra: Dict[str, Any] = {"expert_layout": "home"}
        if self.engine is not None:
            extra["placements_version"] = int(self.engine.placements_version)
        _ckpt.save_checkpoint(state, ckpt_dir, step=step, keep=ckpt_keep,
                              extra=extra)
        return state

    def _observe_timings(self, now: float) -> None:
        """Feed the engine's device health tracker the step-time proxy
        for the interval since the previous dispatch — broadcast to every
        EP rank (a uniform vector can never trip the relative-ratio
        classifier, so noise-free runs stay exactly healthy) and then
        perturbed per-device by any installed fault injector
        (``device_timings``: straggler / degraded_throughput /
        device_loss).  Runs in the planner-idle window before
        ``_maybe_relocate`` so a health transition's forced replan and
        evacuation land at this step's plan."""
        if self.engine is None or not getattr(self.engine,
                                              "health_enabled", False):
            return
        if self._t_last_dispatch is None:
            return
        dt = max(now - self._t_last_dispatch, 1e-9)
        times = np.full(self.engine.cfg.num_devices, dt, dtype=np.float64)
        from repro.testing import faults as _faults
        inj = _faults.active()
        if inj is not None:
            times = inj.device_timings(times)
        self.engine.observe_timings(times)

    def _health_snapshot(self) -> tuple:
        """(label, #degraded, #lost) for the step's telemetry."""
        if self.engine is None:
            return "healthy", 0, 0
        summary = getattr(self.engine, "health_summary", None)
        if summary is None:
            return "healthy", 0, 0
        return (summary(), len(self.engine.degraded_devices()),
                len(self.engine.lost_devices()))

    def _maybe_relocate(self, state: TrainState) -> tuple:
        """Execute a pending owner re-layout before the dependent
        dispatch, transactionally: fingerprint the touched expert slabs,
        run a non-donating exchange, and commit only when the fingerprint
        round-trip verifies.  With prefetch on, the exchange was already
        staged behind the previous step (``relocate.stage_relocation``)
        and only the verify/commit runs here; otherwise the synchronous
        ``relocate.apply_relocation_transactional`` path runs inline.

        Retry policy: a first rollback is treated as transient — the
        dispatch holds the old placement arrays for one step
        (``_reloc_hold``) and the exchange is re-attempted at the next
        dispatch.  A second consecutive rollback is persistent: the
        device returns to the home layout and the engine's planned
        migrations are cancelled (``engine.cancel_migrations`` — the
        planner may re-propose later).  Must run before
        ``arrays_for_dispatch`` so any cancel's version bump is picked up
        by the same dispatch, and — in the async runtime — between
        ``wait()`` and ``submit()``, where the planner worker is idle.
        Returns ``(state, RelocOutcome)``."""
        out = RelocOutcome()
        if self.engine is None or not getattr(self.engine,
                                              "migration_enabled", False):
            return state, out
        gather = self.engine.pending_relocation()
        if gather is None:
            # Nothing pending: drop any stale stage/hold bookkeeping (a
            # watchdog rollback or cancel may have retired the plan).
            self._staged = None
            self._want_stage = None
            self._reloc_hold = False
            self._reloc_attempts = 0
            self._reloc_cooldown = 0
            return state, out
        if self._reloc_cooldown > 0:
            # Degraded-mode backoff: an exchange attributed to a sick
            # device failed recently — keep dispatching on the held (old)
            # arrays until the cooldown elapses, then retry.
            self._reloc_cooldown -= 1
            self._reloc_hold = True
            return state, out
        if self._prefetch:
            return self._relocate_prefetched(state, gather, out)
        moved = len(self.engine.relocations())
        if self._relocate_tx_fn is None:
            self._relocate_tx_fn = relocate.make_relocate_fn(self.cfg,
                                                             donate=False)
        state, ok = relocate.apply_relocation_transactional(
            state, self.cfg, gather, relocate_fn=self._relocate_tx_fn)
        if ok:
            self.engine.mark_relocated()
            self._reloc_hold = False
            self._reloc_attempts = 0
            out.moved = moved
            return state, out
        return self._reloc_failure(state, out)

    def _relocate_prefetched(self, state: TrainState, gather,
                             out: RelocOutcome) -> tuple:
        """Commit a pre-staged exchange, or request one.  A valid stage
        (same source state, same gather) commits here — the heavy
        exchange already ran behind the previous step, only the tiny
        fingerprint round-trip blocks.  Without one (first sighting of
        this relocation, or a stale stage after the plan changed) the
        dispatch holds the old arrays for one more step and the exchange
        is staged right after it, off the dispatch path."""
        st, self._staged = self._staged, None
        # prophetlint: allow(host-sync): ``gather`` is the engine's
        #   host-side relocation plan (numpy already) — no device fetch.
        if (st is not None and st.src_state is state
                and np.array_equal(st.gather, np.asarray(gather))):
            moved = len(self.engine.relocations())
            new_state, ok = relocate.commit_staged(st)
            if ok:
                self.engine.mark_relocated()
                self._want_stage = None
                self._reloc_hold = False
                self._reloc_attempts = 0
                out.moved = moved
                return new_state, out
            state, out = self._reloc_failure(state, out)
            if out.retries:
                # Re-stage behind the upcoming (held) dispatch so the
                # retry commits at the very next one.
                # prophetlint: allow(host-sync): host-side plan copy.
                self._want_stage = np.asarray(gather).copy()
            return state, out
        # prophetlint: allow(host-sync): host-side plan copy.
        self._want_stage = np.asarray(gather).copy()
        self._reloc_hold = True
        return state, out

    def _maybe_stage(self, state: TrainState) -> None:
        """Issue the requested relocation exchange *after* a dispatch so
        all of it — gather collective and fingerprint reductions — queues
        behind the in-flight step (under its backward pass).  Nothing
        here blocks the host or touches the engine."""
        if self._want_stage is None:
            return
        gather, self._want_stage = self._want_stage, None
        if self._relocate_tx_fn is None:
            self._relocate_tx_fn = relocate.make_relocate_fn(self.cfg,
                                                             donate=False)
        try:
            self._staged = relocate.stage_relocation(
                state, self.cfg, gather, relocate_fn=self._relocate_tx_fn)
        except Exception:
            self._staged = None

    def _reloc_suspect(self) -> bool:
        """True when the pending relocation touches a degraded/lost
        device — the failure is then attributed to the sick endpoint
        rather than the exchange itself, and the bounded retry/backoff
        policy applies instead of retry-once."""
        if self.engine is None or not getattr(self.engine,
                                              "health_enabled", False):
            return False
        suspect = set(self.engine.degraded_devices())
        suspect.update(self.engine.lost_devices())
        if not suspect:
            return False
        return any(src in suspect or dst in suspect
                   for _, _, src, dst in self.engine.relocations())

    def _reloc_failure(self, state: TrainState, out: RelocOutcome) -> tuple:
        """Handle one rolled-back exchange under the retry policy: a
        healthy fleet gets the legacy retry-once; a failure attributed to
        a degraded/lost device gets up to ``REPRO_RELOC_RETRY_MAX``
        attempts with ``REPRO_RELOC_BACKOFF``-step exponential backoff
        (the sick endpoint may come back, and evacuation *needs* the
        exchange to eventually land)."""
        out.failures = 1
        self._reloc_attempts += 1
        limit = flags.reloc_retry_max() if self._reloc_suspect() else 1
        if self._reloc_attempts <= limit:
            # Transient: keep the plan, dispatch this step on the held
            # (old) arrays, re-attempt after the cooldown elapses.
            out.retries = 1
            self._reloc_hold = True
            if limit > 1:
                self._reloc_cooldown = (flags.reloc_backoff()
                                        * 2 ** (self._reloc_attempts - 1))
            return state, out
        # Persistent: the state is untouched (pre-exchange); bring the
        # device back to the home layout if an earlier migration had
        # moved it, and drop the plans demanding the failed move.
        out.persistent = 1
        self._reloc_attempts = 0
        self._reloc_hold = False
        self._reloc_cooldown = 0
        self._staged = None
        self._want_stage = None
        home = self.engine.reset_layout()
        if home is not None:
            if self._relocate_fn is None:
                self._relocate_fn = relocate.make_relocate_fn(self.cfg)
            state = relocate.apply_relocation(state, self.cfg, home,
                                              relocate_fn=self._relocate_fn)
        self.engine.cancel_migrations()
        return state, out

    def restore_home_layout(self, state: TrainState) -> TrainState:
        """Undo any owner re-layout: expert-stacked weights and moments
        back to the identity slot order.  Call before checkpointing — a
        restored run binds a fresh engine that assumes the home layout,
        so saving a migrated physical order would silently mis-route
        every migrated expert after restore.  (The next dispatch simply
        re-executes the pending relocation if training continues.)"""
        if self.engine is None or not getattr(self.engine,
                                              "migration_enabled", False):
            return state
        gather = self.engine.reset_layout()
        if gather is None:
            return state
        if self._relocate_fn is None:
            self._relocate_fn = relocate.make_relocate_fn(self.cfg)
        return relocate.apply_relocation(state, self.cfg, gather,
                                         relocate_fn=self._relocate_fn)

    @staticmethod
    def _stats_for(pending: _Pending, loss: float, t_next: float) -> StepStats:
        ev = pending.plan
        cs = pending.chunk_stats or {}
        failed = 1 if (ev is not None and not ev.ok) else 0
        return StepStats(
            step=pending.step, loss=loss,
            step_time=t_next - pending.t_dispatch,
            plan_time=ev.plan_time if ev else 0.0,
            exposed_plan_time=ev.exposed if ev else 0.0,
            upload_time=pending.upload_time,
            plan_speedup=ev.plan_speedup if ev else 1.0,
            num_shadowed=ev.num_shadowed if ev else 0,
            placements_version=pending.version,
            placements_fingerprint=pending.fingerprint,
            a2a_chunks=pending.a2a_chunks,
            a2a_gbytes=cs.get("a2a_gbytes", 0.0),
            comm_hidden_frac=cs.get("comm_hidden_frac", 0.0),
            relocations=pending.relocations,
            plan_failures=failed,
            fallbacks=failed,
            sanitized_counts=ev.sanitized_layers if ev else 0,
            relocation_failures=pending.relocation_failures,
            plan_failure_kind=ev.failure if ev else "",
            plans_skipped=ev.skipped_layers if ev else 0,
            stable_layers=ev.stable_layers if ev else 0,
            relocation_retries=pending.relocation_retries,
            relocation_persistent=pending.relocation_persistent,
            health_state=pending.health_state,
            degraded_devices=pending.degraded_devices,
            lost_devices=pending.lost_devices,
            evacuations=ev.evacuations if ev else 0,
        )

    def _chunks_for_dispatch(self) -> tuple:
        """(K, modeled chunk stats) for the next dispatch.  The engine's
        per-layer scheduler choice is collapsed to one K (layers share a
        single scanned trace — repro.models.blocks.stage_apply) by
        majority, smallest on ties; ``REPRO_A2A_CHUNKS`` overrides via
        ``chunk_plan``.  Must run on the dispatch path *after* the
        pipeline's ``wait()`` — it reads engine state."""
        if self.engine is None:
            k = flags.a2a_chunks() or 1
            return k, None
        plan = self.engine.chunk_plan()
        k = max(sorted(set(plan)), key=plan.count) if plan else 1
        return k, self.engine.chunk_stats([k] * len(plan))

    # -- serial baseline -------------------------------------------------
    def _run_sync(self, state, it, num_steps, log_every, log_fn,
                  stats_sink, telemetry, ckpt_dir=None, ckpt_every=0,
                  ckpt_keep=3) -> tuple:
        history: List[float] = []
        cache = PlacementCache(self.engine)
        t0 = time.perf_counter()
        for step in range(num_steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            state = self._maybe_checkpoint(state, step, ckpt_dir,
                                           ckpt_every, ckpt_keep)
            # Relocation (and a failed exchange's migration-cancel version
            # bump) must land before arrays_for_dispatch so the dispatch
            # runs with weights matching its expert_slot arrays.  A held
            # relocation pins the old arrays instead — the staged
            # exchange commits at the next dispatch.  Health first: a
            # transition forces the plan below to evacuate/rebalance.
            self._observe_timings(time.perf_counter())
            state, reloc = self._maybe_relocate(state)
            health, n_deg, n_lost = self._health_snapshot()
            placements = cache.arrays_for_dispatch(hold=self._reloc_hold)
            chunks, chunk_stats = self._chunks_for_dispatch()
            t_dispatch = time.perf_counter()
            self._t_last_dispatch = t_dispatch
            # prophetlint: bounded(a2a_chunks): quantized to
            #   EngineConfig.a2a_chunk_candidates by _chunks_for_dispatch
            with sanitize.dispatch_guard():
                state, metrics = self._step_fn(state, batch, placements,
                                               a2a_chunks=chunks)
            self._maybe_stage(state)
            # prophetlint: allow(host-sync): serial baseline blocks on the
            #   device loss by design — this runtime IS the exposed-latency
            #   comparison point for the async pipeline.
            loss = float(metrics["loss"])          # blocks on the device
            plan = None
            if self.engine is not None and "counts" in metrics:
                plan = self._observe_inline(metrics["counts"])
            pending = _Pending(step, metrics, t_dispatch,
                               cache.last_upload_time, cache.version,
                               cache.fingerprint, plan, chunks, chunk_stats,
                               reloc.moved, reloc.failures, reloc.retries,
                               reloc.persistent, health, n_deg, n_lost)
            self._emit(self._stats_for(pending, loss, time.perf_counter()),
                       history, t0, log_every, log_fn, stats_sink, telemetry)
        return state, history

    # -- pipelined runtime -----------------------------------------------
    def _run_async(self, state, it, num_steps, log_every, log_fn,
                   stats_sink, telemetry, ckpt_dir=None, ckpt_every=0,
                   ckpt_keep=3) -> tuple:
        history: List[float] = []
        cache = PlacementCache(self.engine)
        pipeline = (PlanPipeline(self.engine)
                    if self.engine is not None else None)
        pending: Optional[_Pending] = None
        t0 = time.perf_counter()
        try:
            for step in range(num_steps):
                batch = {k: jnp.asarray(v) for k, v in next(it).items()}
                # Join the plan derived from the previous step's counts —
                # the dependent dispatch below must see its placements.
                event = pipeline.wait() if pipeline is not None else None
                if pending is not None:
                    pending.plan = event
                # The planner worker is idle between wait() and the
                # submit() below — the window every engine-mutating host
                # action must land in: the cadenced checkpoint, the
                # relocation exchange (so the dispatch runs with weights
                # matching expert_slot — including a failed exchange's
                # migration-cancel version bump, which is why relocation
                # precedes arrays_for_dispatch), and the chunk choice.
                state = self._maybe_checkpoint(state, step, ckpt_dir,
                                               ckpt_every, ckpt_keep)
                self._observe_timings(time.perf_counter())
                state, reloc = self._maybe_relocate(state)
                health, n_deg, n_lost = self._health_snapshot()
                placements = cache.arrays_for_dispatch(hold=self._reloc_hold)
                chunks, chunk_stats = self._chunks_for_dispatch()
                t_dispatch = time.perf_counter()
                self._t_last_dispatch = t_dispatch
                # prophetlint: bounded(a2a_chunks): quantized to
                #   EngineConfig.a2a_chunk_candidates by _chunks_for_dispatch
                with sanitize.dispatch_guard():
                    state, metrics = self._step_fn(state, batch, placements,
                                                   a2a_chunks=chunks)
                if pipeline is not None and "counts" in metrics:
                    pipeline.submit(metrics["counts"])
                # Stage any requested relocation exchange now — it queues
                # on the device behind the step just dispatched (under
                # its backward pass) and commits at the next
                # _maybe_relocate, in the planner-idle window.
                self._maybe_stage(state)
                # Consume the *previous* step's loss only now — the device
                # already has this step queued, so the host never blocks
                # the dispatch path on a device_get.
                if pending is not None:
                    # prophetlint: allow(host-sync): deferred consumption of
                    #   the *previous* step's loss — the device already has
                    #   this step queued, so nothing serializes.
                    loss = float(pending.metrics["loss"])
                    self._emit(self._stats_for(pending, loss, t_dispatch),
                               history, t0, log_every, log_fn, stats_sink,
                               telemetry)
                pending = _Pending(step, metrics, t_dispatch,
                                   cache.last_upload_time, cache.version,
                                   cache.fingerprint,
                                   a2a_chunks=chunks,
                                   chunk_stats=chunk_stats,
                                   relocations=reloc.moved,
                                   relocation_failures=reloc.failures,
                                   relocation_retries=reloc.retries,
                                   relocation_persistent=reloc.persistent,
                                   health_state=health,
                                   degraded_devices=n_deg,
                                   lost_devices=n_lost)
            # Drain: the final step's loss and its (now unused) plan.
            if pipeline is not None:
                final_event = pipeline.wait()
                if pending is not None:
                    pending.plan = final_event
            if pending is not None:
                # prophetlint: allow(host-sync): drain — the run is over,
                #   there is no dispatch left to serialize.
                loss = float(pending.metrics["loss"])
                self._emit(self._stats_for(pending, loss,
                                           time.perf_counter()),
                           history, t0, log_every, log_fn, stats_sink,
                           telemetry)
        finally:
            if pipeline is not None:
                pipeline.close()
        return state, history


def make_engine_for(cfg: ModelConfig, ctx: ParallelCtx, *,
                    policy: str = "pro_prophet",
                    replan_interval: int = 1,
                    bandwidth: float = 25e9,
                    flops_per_s: float = 70e12,
                    migration: bool = False) -> Optional[ProProphetEngine]:
    """Engine wired to a model config (None for non-MoE archs).
    ``migration`` enables dynamic expert migration (owner re-layout);
    ``REPRO_MIGRATION`` overrides either way."""
    if cfg.moe is None:
        return None
    nm = 3 if cfg.ffn_kind == "swiglu" else 2
    hw = HardwareSpec.from_model_dims(
        cfg.d_model, cfg.moe.d_expert, bandwidth=bandwidth,
        flops_per_s=flops_per_s, num_ffn_mats=nm)
    ec = EngineConfig(
        num_experts=cfg.moe.num_experts,
        num_devices=max(ctx.ep_size, 1),
        num_moe_layers=cfg.num_moe_layers,
        s_max=cfg.moe.s_max,
        replan_interval=replan_interval,
        policy=policy,
        enable_migration=migration,
        # permute-term pricing (PerfModel.t_dispatch/t_combine) mirrors
        # the layer's real dispatch geometry
        top_k=cfg.moe.top_k,
        capacity_factor=cfg.moe.capacity_factor,
    )
    return ProProphetEngine(ec, hw)
