"""Runtime sanitizer wiring (``REPRO_SANITIZE=1``).

prophetlint (tools/prophetlint, ``scripts/ci.sh --lint``) enforces the
hot-path invariants *statically*: no host syncs on the dispatch path, no
stray env reads, bounded jit caches, lock/version discipline on shared
planner state.  This module is the *dynamic* twin — cheap runtime traps
that catch what static analysis cannot see (a numpy array smuggled into
the jitted step through a config object, a NaN'd gate, a placement
re-pack racing a background version bump):

* :func:`dispatch_guard` — ``jax.transfer_guard("disallow")`` scoped to
  the trainer's step dispatch.  Any *implicit* host↔device transfer on
  the dispatch path (the classic silent serializer: a host numpy operand
  forcing a synchronous upload per step) raises instead of quietly
  costing a round trip.  The guard is context-scoped and thread-local,
  so the planner worker's intentional blocking fetch
  (``runtime.run_plan``) and the deferred loss consumption are
  unaffected.  Note: on the CPU backend device↔host is zero-copy and
  only the host-to-device direction can trip; on TPU/GPU both do.

* :func:`arm` — process-level debug lanes: ``jax_debug_nans`` and
  ``jax_debug_infs`` so a non-finite loss/gradient faults at the op that
  produced it rather than steps later in the forecaster's EMA.

* :class:`TornReadError` — raised by
  :class:`repro.train.runtime.PlacementCache` in sanitize mode when the
  engine's ``placements_version`` moves *while* the cache is re-packing
  placement arrays, or when dispatch-side reads migrate off the thread
  that first consumed them.  Either means the submit→wait ordering
  contract (the happens-before edge that makes torn placement reads
  impossible) was broken by a caller.

Everything here is a no-op unless ``REPRO_SANITIZE=1``
(:func:`repro.flags.sanitize`), so the production hot path carries zero
overhead.  ``tests/test_sanitize.py`` runs the trainer smoke lane with
the full sanitizer armed.
"""
from __future__ import annotations

import contextlib

from repro import flags


class TornReadError(AssertionError):
    """A shared placement structure was read while a concurrent writer
    was (or may have been) mid-update — the submit→wait ordering
    contract was violated by a caller."""


def dispatch_guard():
    """Context manager for the step-dispatch region: transfer guard in
    sanitize mode, free nullcontext otherwise."""
    if not flags.sanitize():
        return contextlib.nullcontext()
    import jax
    return jax.transfer_guard("disallow")


def arm() -> bool:
    """Enable the process-level debug lanes when sanitize mode is on
    (idempotent; returns whether the sanitizer is armed).  Called once
    per ``Trainer.run`` — jax.config updates are cheap and repeatable."""
    if not flags.sanitize():
        return False
    import jax
    jax.config.update("jax_debug_nans", True)
    jax.config.update("jax_debug_infs", True)
    return True
