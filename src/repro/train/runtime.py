"""Pipelined training runtime: overlap host planning with device execution.

The paper's execution engine (Fig. 5, §V.A) hides the *Plan* primitive
under device execution — the locality property makes planning one step
ahead sound.  This module supplies the host-side machinery the trainer
uses to realize that overlap on a JAX runtime:

* :class:`PlanPipeline` — a single background planner thread.  After the
  trainer dispatches step *j* it submits that step's (still in-flight)
  routing-count array; the worker blocks on the device transfer (the
  counts materialize once the forward pass finishes, well before the
  backward + optimizer half of the step), runs ``engine.observe`` — the
  per-layer :class:`~repro.core.planner.LocalityPlanner` searches fan out
  over a small thread pool — and leaves the engine holding the placements
  for step *j+1*.  The dispatch path only touches the future at the top
  of the next iteration, so Plan runs under the device's backward pass.

* :class:`PlacementCache` — double-buffered placement handoff.  The
  engine's ``step_arrays`` are re-packed and re-uploaded to the device
  only when a placement actually changed (the engine bumps
  ``placements_version``); at ``replan_interval > 1`` the upload
  disappears from the steady-state step entirely.

* :class:`StepStats` / :class:`OverlapTelemetry` — the overlap telemetry
  surface (plan latency, step latency, hidden fraction, host overhead)
  consumed by the trainer's logging and by ``benchmarks/cadence.py`` /
  ``benchmarks/end_to_end.py``.

Threading contract: the engine is mutated only by the planner worker
between ``submit()`` and the matching ``wait()``; the trainer reads
``step_arrays()`` / ``placements_version`` only after ``wait()``
returns.  ``wait()`` therefore also provides the happens-before edge
that makes torn placement reads impossible (unit-tested in
``tests/test_async_runtime.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import numpy as np

Array = np.ndarray


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepStats:
    """Per-step telemetry emitted by the runtime (replaces ad-hoc metric
    recomputation inside logging f-strings)."""

    step: int
    loss: float
    step_time: float                 # dispatch-to-dispatch wall time [s]
    plan_time: float = 0.0           # host Plan latency for this step's counts
    exposed_plan_time: float = 0.0   # part of plan_time on the dispatch path
    upload_time: float = 0.0         # placement host→device upload [s]
    plan_speedup: float = 1.0        # engine-predicted speedup vs plain EP
    num_shadowed: int = 0            # total shadow slots across MoE layers
    placements_version: int = 0      # engine version consumed at dispatch
    placements_fingerprint: str = "" # digest of the dispatched arrays
    # Chunked a2a↔FEC pipelining (repro.models.moe): the K this step was
    # dispatched with, modeled a2a traffic, and the timeline's modeled
    # fraction of a2a wire time hidden under the ragged expert compute.
    a2a_chunks: int = 1
    a2a_gbytes: float = 0.0
    comm_hidden_frac: float = 0.0
    # Dynamic expert migration: experts re-homed by the weight/optimizer
    # exchange that ran at this step's dispatch (0 on steady-state steps).
    relocations: int = 0
    # Self-healing runtime: plans rejected by the watchdog (and why),
    # fall-backs to the last-good placements, routing-count layers the
    # sanitizer repaired, and relocation exchanges rolled back by the
    # transactional fingerprint check.
    plan_failures: int = 0
    fallbacks: int = 0
    sanitized_counts: int = 0
    relocation_failures: int = 0
    plan_failure_kind: str = ""
    # Predictive planning: layers whose Plan primitive was skipped this
    # step by the forecast cadence backoff, and how many of them the
    # forecaster currently classifies as stable.
    plans_skipped: int = 0
    stable_layers: int = 0
    # Relocation retry policy: exchanges re-attempted after a transient
    # rollback, and rollbacks declared persistent (migration cancelled).
    relocation_retries: int = 0
    relocation_persistent: int = 0
    # Degraded-mode runtime: fleet health at this step's dispatch
    # ("healthy" or the tracker's compact degraded/lost label), device
    # counts per state, and experts force-evacuated off lost ranks by
    # this step's plan.
    health_state: str = "healthy"
    degraded_devices: int = 0
    lost_devices: int = 0
    evacuations: int = 0

    @property
    def hidden_frac(self) -> float:
        """Fraction of this step's Plan latency hidden under device
        execution (0 when there was nothing to plan or nothing hid)."""
        if self.plan_time <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.exposed_plan_time / self.plan_time)

    def log_line(self, avg_step: float) -> str:
        extra = ""
        if self.plan_time > 0.0:
            extra = (f" plan={self.plan_time * 1e3:.1f}ms"
                     f" hidden={self.hidden_frac:.0%}"
                     f" plan_speedup={self.plan_speedup:.2f}x"
                     f" shadows={self.num_shadowed}")
        if self.a2a_gbytes > 0.0:
            extra += (f" a2a={self.a2a_gbytes:.3g}GB"
                      f" chunks={self.a2a_chunks}"
                      f" comm_hidden={self.comm_hidden_frac:.0%}")
        if self.relocations:
            extra += f" relocated={self.relocations}"
        if self.plan_failures:
            kind = f":{self.plan_failure_kind}" if self.plan_failure_kind else ""
            extra += f" plan_fallback{kind}"
        if self.sanitized_counts:
            extra += f" sanitized={self.sanitized_counts}"
        if self.relocation_failures:
            extra += f" reloc_rollback={self.relocation_failures}"
        if self.relocation_retries:
            extra += f" reloc_retry={self.relocation_retries}"
        if self.relocation_persistent:
            extra += f" reloc_cancelled={self.relocation_persistent}"
        if self.plans_skipped:
            extra += (f" plan_skips={self.plans_skipped}"
                      f" stable={self.stable_layers}")
        if self.health_state != "healthy":
            extra += f" health={self.health_state.replace(' ', '+')}"
        if self.evacuations:
            extra += f" evacuated={self.evacuations}"
        return (f"step {self.step:5d} loss {self.loss:.4f} "
                f"({avg_step:.3f}s/it){extra}")


class OverlapTelemetry:
    """Accumulates plan/step/upload timings and summarizes the overlap.

    ``exposed`` is the portion of each step's plan latency that sat on
    the dispatch critical path: equal to ``plan`` for a serial runtime,
    ``max(0, plan - device_window)`` for a perfectly pipelined one.
    """

    def __init__(self) -> None:
        self.plan_times: List[float] = []
        self.step_times: List[float] = []
        self.exposed_times: List[float] = []
        self.upload_times: List[float] = []
        self.comm_hidden_fracs: List[float] = []
        self.a2a_gbytes: List[float] = []
        # Self-healing totals: watchdog rejections (by failure kind),
        # fall-backs to last-good placements, sanitized count layers, and
        # rolled-back relocation exchanges.
        self.plan_failures = 0
        self.fallbacks = 0
        self.sanitized_counts = 0
        self.relocation_failures = 0
        self.fault_fallbacks: Dict[str, int] = {}
        # Predictive planning / retry-policy totals.
        self.plans_skipped = 0
        self.stable_layers = 0
        self.relocation_retries = 0
        self.relocation_persistent = 0
        # Degraded-mode totals: steps dispatched with a non-healthy
        # fleet, and experts force-evacuated off lost ranks.
        self.degraded_steps = 0
        self.evacuations = 0

    def record(self, *, plan: float, step: float, exposed: float,
               upload: float = 0.0, comm_hidden: float = 0.0,
               a2a_gbytes: float = 0.0) -> None:
        self.plan_times.append(float(plan))
        self.step_times.append(float(step))
        self.exposed_times.append(float(exposed))
        self.upload_times.append(float(upload))
        self.comm_hidden_fracs.append(float(comm_hidden))
        self.a2a_gbytes.append(float(a2a_gbytes))

    def record_failure(self, kind: str) -> None:
        """Count one watchdog fall-back, bucketed by failure kind."""
        self.plan_failures += 1
        self.fallbacks += 1
        if kind:
            self.fault_fallbacks[kind] = self.fault_fallbacks.get(kind, 0) + 1

    def record_stats(self, stats: StepStats) -> None:
        self.record(plan=stats.plan_time, step=stats.step_time,
                    exposed=stats.exposed_plan_time,
                    upload=stats.upload_time,
                    comm_hidden=stats.comm_hidden_frac,
                    a2a_gbytes=stats.a2a_gbytes)
        if stats.plan_failures:
            self.plan_failures += stats.plan_failures
            self.fallbacks += stats.fallbacks or stats.plan_failures
            if stats.plan_failure_kind:
                k = stats.plan_failure_kind
                self.fault_fallbacks[k] = (self.fault_fallbacks.get(k, 0)
                                           + stats.plan_failures)
        self.sanitized_counts += stats.sanitized_counts
        if stats.relocation_failures:
            self.relocation_failures += stats.relocation_failures
            self.fallbacks += stats.relocation_failures
            k = "relocation"
            self.fault_fallbacks[k] = (self.fault_fallbacks.get(k, 0)
                                       + stats.relocation_failures)
        self.plans_skipped += stats.plans_skipped
        self.stable_layers += stats.stable_layers
        self.evacuations += stats.evacuations
        if stats.health_state != "healthy":
            self.degraded_steps += 1
        self.relocation_retries += stats.relocation_retries
        if stats.relocation_persistent:
            self.relocation_persistent += stats.relocation_persistent
            k = "relocation_persistent"
            self.fault_fallbacks[k] = (self.fault_fallbacks.get(k, 0)
                                       + stats.relocation_persistent)

    @property
    def hidden_frac(self) -> float:
        total = sum(self.plan_times)
        if total <= 0.0:
            return 0.0
        return max(0.0, 1.0 - sum(self.exposed_times) / total)

    def summary(self) -> Dict[str, float]:
        n = max(len(self.step_times), 1)
        plan = sum(self.plan_times)
        upload = sum(self.upload_times)
        exposed = sum(self.exposed_times)
        return {
            "steps": float(len(self.step_times)),
            "mean_step_s": sum(self.step_times) / n,
            "mean_plan_s": plan / n,
            "mean_upload_s": upload / n,
            "hidden_frac": self.hidden_frac,
            # Host-side per-step overhead on the dispatch path, vs what a
            # fully serial runtime would pay (plan + upload every step).
            "host_overhead_s": (exposed + upload) / n,
            "serial_overhead_s": (plan + upload) / n,
            # Device-side chunked-pipeline telemetry (modeled from the
            # scheduler timeline on the dispatched chunk plan).
            "comm_hidden_frac": sum(self.comm_hidden_fracs) / n,
            "mean_a2a_gbytes": sum(self.a2a_gbytes) / n,
            # Self-healing runtime: watchdog/transaction fall-back totals
            # (per-kind breakdown in ``fault_fallbacks``).
            "plan_failures": float(self.plan_failures),
            "fallbacks": float(self.fallbacks),
            "sanitized_counts": float(self.sanitized_counts),
            "relocation_failures": float(self.relocation_failures),
            # Predictive planning: per-layer Plan invocations the cadence
            # backoff skipped, and retry-policy outcomes.
            "plans_skipped": float(self.plans_skipped),
            "stable_layers": float(self.stable_layers),
            "relocation_retries": float(self.relocation_retries),
            "relocation_persistent": float(self.relocation_persistent),
            # Degraded-mode runtime totals.
            "degraded_steps": float(self.degraded_steps),
            "evacuations": float(self.evacuations),
        }


def fingerprint_arrays(arrays: Optional[Dict[str, Array]]) -> str:
    """Stable digest of a dict of numpy arrays (placement handoff id)."""
    if arrays is None:
        return ""
    h = hashlib.sha1()
    for k in sorted(arrays):
        # prophetlint: allow(host-sync): inputs are the engine's host-side
        #   numpy step_arrays copies — no device transfer happens here
        a = np.ascontiguousarray(np.asarray(arrays[k]))
        h.update(k.encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# Placement handoff (double-buffered, cadence-aware)
# ---------------------------------------------------------------------------

class PlacementCache:
    """Upload the engine's placement arrays only when they changed.

    The jitted step consumes the same device buffers across steps while
    the placements are stable; a version bump from the engine triggers a
    re-pack + re-upload (the double buffer: the device keeps executing
    from the old arrays until the next dispatch hands over the new ones).

    Threading: single-consumer.  Every field below is read/written only
    by the dispatch thread inside :meth:`arrays_for_dispatch` (and the
    :attr:`version` view of it); the engine side is only ever *read*
    here, ordered after the producing observe by ``PlanPipeline.wait``.
    In sanitize mode (``REPRO_SANITIZE=1``) that contract is asserted
    dynamically: a re-pack observing the engine version move under it,
    or a call from a second thread, raises
    :class:`repro.train.sanitize.TornReadError`.
    """

    # prophetlint: shared(_version, _arrays, fingerprint, last_upload_time,
    #   uploads, _consumer): owner=arrays_for_dispatch, version,
    #   _check_consumer

    def __init__(self, engine) -> None:
        from repro import flags
        self._engine = engine
        self._version = -1
        self._arrays = None
        self.fingerprint = ""
        self.last_upload_time = 0.0
        self.uploads = 0
        self._sanitize = flags.sanitize()
        self._consumer: Optional[int] = None   # dispatch thread id

    def _check_consumer(self) -> None:
        """Sanitize mode: all dispatch-side reads must stay on the one
        thread whose ordering ``PlanPipeline.wait`` guarantees."""
        import threading
        me = threading.get_ident()
        if self._consumer is None:
            self._consumer = me
        elif self._consumer != me:
            from repro.train.sanitize import TornReadError
            raise TornReadError(
                f"PlacementCache consumed from thread {me} after thread "
                f"{self._consumer} — placement reads are only ordered on "
                f"the dispatch thread (PlanPipeline.wait happens-before)")

    @property
    def version(self) -> int:
        """Version of the arrays handed out by the last
        ``arrays_for_dispatch`` (NOT the live engine version, which a
        background planner may already have bumped past it)."""
        return self._version

    def arrays_for_dispatch(self, *, hold: bool = False):
        """Device placement arrays for the next dispatch (None ⇒ no MoE
        engine).  Sets ``last_upload_time`` to the upload cost actually
        paid this step (0.0 on the cached path).

        ``hold=True`` pins the previously dispatched arrays even if the
        engine has bumped past them — the relocation prefetch path uses
        it to dispatch one more step on the *old* layout while the
        exchange for the new one is staged behind the in-flight step
        (placements must match the physical slot contents, so the upload
        is deferred together with the commit)."""
        if self._engine is None:
            self.last_upload_time = 0.0
            return None
        if self._sanitize:
            self._check_consumer()
        if hold and self._arrays is not None:
            self.last_upload_time = 0.0
            return self._arrays
        import jax.numpy as jnp
        v = self._engine.placements_version
        if self._arrays is None or v != self._version:
            t0 = time.perf_counter()
            host = self._engine.step_arrays()
            self.fingerprint = fingerprint_arrays(host)
            self._arrays = {k: jnp.asarray(a) for k, a in host.items()}
            self._version = v
            self.uploads += 1
            self.last_upload_time = time.perf_counter() - t0
            if self._sanitize and self._engine.placements_version != v:
                # The planner bumped the version *while* we were packing:
                # step_arrays may mix layers from two plans — exactly the
                # torn read the submit→wait alternation is meant to rule
                # out.  Fail loudly instead of dispatching it.
                from repro.train.sanitize import TornReadError
                raise TornReadError(
                    f"engine placements_version moved {v} → "
                    f"{self._engine.placements_version} during the "
                    f"placement re-pack — a planner ran concurrently "
                    f"with arrays_for_dispatch (broken submit→wait "
                    f"ordering)")
        else:
            self.last_upload_time = 0.0
        return self._arrays


# ---------------------------------------------------------------------------
# Background planner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanEvent:
    """Timing + outcome of one ``engine.observe`` call."""

    plan_time: float          # observe + telemetry, after counts were ready
    fetch_time: float         # worker time blocked on the device transfer
    counts_ready: float       # perf_counter() when the counts materialized
    done: float               # perf_counter() when observe finished
    plan_speedup: float
    num_shadowed: int
    version: int              # engine placements_version after observe
    exposed: float = 0.0      # filled in by wait(): plan time the dispatch
                              # path actually waited for
    # Watchdog outcome: ``ok`` is False when the plan was rejected and the
    # engine rolled back to the last-good placements.  ``failure`` names
    # why (planner_exception | invariant | deadline | bad_counts |
    # worker_crash); ``sanitized_layers`` counts routing-count layers the
    # sanitizer had to repair before observe, ``uniform_layers`` the
    # subset that had no clean fallback and planned from the uniform
    # prior (the first-observation path).
    ok: bool = True
    failure: str = ""
    sanitized_layers: int = 0
    uniform_layers: int = 0
    # Predictive planning: how the forecast cadence backoff split this
    # observe across layers (planned + skipped = num_moe_layers for
    # engines with the forecast surface; all zero for stubs).
    planned_layers: int = 0
    skipped_layers: int = 0
    stable_layers: int = 0
    # Degraded-mode runtime: fleet health label after this observe and
    # experts force-evacuated off lost ranks by it (stubs: defaults).
    health_state: str = "healthy"
    evacuations: int = 0


def counts_to_layers(counts: Array) -> List[Array]:
    """Split the stacked ``[L, D, E]`` device counts into the per-layer
    float64 routing matrices the engine ingests."""
    # prophetlint: allow(host-sync): planner-side ingestion — runs on the
    #   worker thread (or the serial baseline), never the dispatch path
    counts = np.asarray(counts)
    if counts.ndim != 3:
        from repro.core.guard import CountsError
        raise CountsError(f"stacked routing counts must be [L, D, E], got "
                          f"shape {counts.shape}")
    return [counts[i].astype(np.float64) for i in range(counts.shape[0])]


def run_plan(engine, counts_device, layer_pool=None) -> PlanEvent:
    """Execute one Plan primitive under the watchdog: fetch the (possibly
    in-flight) device counts, sanitize them, snapshot the engine, run
    ``engine.observe`` (per-layer searches on ``layer_pool`` when given),
    validate the planner output against the placement invariants, and
    collect the telemetry.  Shared by the background worker and the
    serial runtime so both report identical numbers.

    Failure semantics: a planner exception, an invariant violation
    (:mod:`repro.core.guard`), or a deadline overrun
    (``REPRO_PLAN_DEADLINE_MS``) rolls the engine back to its pre-plan
    snapshot — training continues on the last-good placements, the event
    records ``ok=False`` and the failure kind, and nothing propagates to
    the dispatch path.  Placements only decide *where* compute happens,
    so a rejected plan costs balance, not loss bits.

    Engines without the watchdog surface (test stubs implementing only
    ``observe``/``predicted_times``) are driven best-effort: no snapshot
    means no rollback, but sanitization and failure capture still apply.
    """
    from repro import flags
    from repro.core import guard
    from repro.testing import faults as _faults

    t0 = time.perf_counter()
    inj = _faults.active()
    sanitized = uniform = 0
    failure = ""
    try:
        # prophetlint: allow(host-sync): intentional — this is the Plan
        #   primitive's designed blocking fetch of the in-flight counts; it
        #   blocks the planner *worker* thread under the device's backward
        #   pass, not the dispatch path (serial runtime: fully exposed by
        #   design and reported as such)
        counts = np.asarray(counts_device)   # blocks the *calling thread*
    except Exception:                        # torn transfer: nothing to plan
        t1 = time.perf_counter()
        return PlanEvent(plan_time=0.0, fetch_time=t1 - t0, counts_ready=t1,
                         done=t1, plan_speedup=1.0, num_shadowed=0,
                         version=getattr(engine, "placements_version", 0),
                         ok=False, failure="bad_counts")
    t1 = time.perf_counter()             # until the device fwd pass is done

    if inj is not None:
        counts = inj.corrupt_counts(counts)
    last_good = getattr(engine, "last_counts", lambda: None)()
    try:
        layers, report = guard.sanitize_counts(counts, fallback=last_good)
        sanitized = report.num_sanitized
        uniform = len(report.uniform)
    except guard.CountsError:
        t2 = time.perf_counter()
        return PlanEvent(plan_time=t2 - t1, fetch_time=t1 - t0,
                         counts_ready=t1, done=t2, plan_speedup=1.0,
                         num_shadowed=0,
                         version=getattr(engine, "placements_version", 0),
                         ok=False, failure="bad_counts")

    snap = getattr(engine, "snapshot", lambda: None)()

    def _rollback() -> None:
        if snap is not None:
            engine.restore(snap)

    try:
        if inj is not None:
            inj.planner_fault()
            delay = inj.plan_delay()
            if delay > 0.0:
                time.sleep(delay)
        engine.observe(layers, pool=layer_pool)
        if snap is not None:   # full engines expose the invariant surface
            guard.validate_engine(engine)
    except guard.PlanDeadlineError:
        # Cooperative cancellation: the greedy search aborted itself
        # mid-move-loop (REPRO_PLAN_DEADLINE_MS) — same rollback as the
        # post-hoc deadline below, but the worker is already unstuck.
        _rollback()
        failure = "deadline"
    except guard.PlacementInvariantError:
        _rollback()
        failure = "invariant"
    except Exception:
        _rollback()
        failure = "planner_exception"

    t2 = time.perf_counter()
    deadline_ms = flags.plan_deadline_ms()
    if not failure and deadline_ms > 0.0 and (t2 - t1) * 1e3 > deadline_ms:
        _rollback()
        failure = "deadline"

    pt = engine.predicted_times()
    shadows = sum(p.num_shadowed for p in engine.placements)
    info = getattr(engine, "last_plan_info", None) or {}
    return PlanEvent(plan_time=t2 - t1, fetch_time=t1 - t0,
                     counts_ready=t1, done=t2,
                     plan_speedup=pt["speedup"], num_shadowed=shadows,
                     version=engine.placements_version,
                     ok=not failure, failure=failure,
                     sanitized_layers=sanitized,
                     uniform_layers=uniform,
                     planned_layers=int(info.get("planned", 0)),
                     skipped_layers=int(info.get("skipped", 0)),
                     stable_layers=int(info.get("stable", 0)),
                     health_state=getattr(engine, "health_summary",
                                          lambda: "healthy")(),
                     evacuations=int(info.get("evacuated", 0)))


class PlanPipeline:
    """One in-flight Plan at a time, off the dispatch path.

    ``submit(counts)`` hands the (possibly still device-resident) routing
    counts of the just-dispatched step to the worker; ``wait()`` joins the
    worker before the next dependent dispatch and reports how much of the
    plan latency was exposed.  The strict submit→wait alternation is
    asserted — it is what rules out torn placement reads.

    Shared-state discipline (checked statically by prophetlint R4): the
    pipeline bookkeeping below is dispatch-thread-only — the worker runs
    ``_job`` and touches none of it.  Any new method touching these
    fields must be added to the registry (a conscious concurrency
    decision) or carry an ``allow(shared-state)`` annotation.
    """

    # prophetlint: shared(_future, _closed, _exec, worker_restarts):
    #   owner=submit, wait, close, _restart_worker

    def __init__(self, engine, *, layer_workers: Optional[int] = None):
        self._engine = engine
        self._exec = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="repro-plan")
        n_layers = int(engine.cfg.num_moe_layers)
        if layer_workers is None:
            layer_workers = min(4, n_layers)
        self._layer_pool = (ThreadPoolExecutor(
            max_workers=layer_workers, thread_name_prefix="repro-plan-layer")
            if layer_workers > 1 and n_layers > 1 else None)
        self._future: Optional[Future] = None
        self._closed = False
        self.worker_restarts = 0

    # -- worker side ----------------------------------------------------
    def _job(self, counts_device) -> PlanEvent:
        return run_plan(self._engine, counts_device, self._layer_pool)

    def _restart_worker(self) -> None:
        """Replace the planner thread after a failed plan: a worker that
        just crashed (or sat past the deadline) may be wedged on foreign
        state; a fresh thread guarantees the next submit starts clean."""
        old = self._exec
        self._exec = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="repro-plan")
        self.worker_restarts += 1
        old.shutdown(wait=False, cancel_futures=True)

    # -- dispatch side ---------------------------------------------------
    def submit(self, counts_device) -> None:
        if self._closed:
            raise RuntimeError("PlanPipeline is closed")
        assert self._future is None, "previous plan was never consumed"
        self._future = self._exec.submit(self._job, counts_device)

    def wait(self) -> Optional[PlanEvent]:
        """Join the in-flight plan (no-op if none).  Must run before any
        dispatch that depends on the planned placements.

        Never raises on plan failure: ``run_plan`` converts planner
        faults into ``ok=False`` events (engine already rolled back), and
        a crash of the pipeline machinery itself is converted into a
        synthetic ``failure="worker_crash"`` event.  After any failed
        event the planner thread is replaced so the next submit starts on
        a clean worker."""
        if self._future is None:
            return None
        t_wait = time.perf_counter()
        f, self._future = self._future, None
        try:
            event = f.result()
        except Exception:
            now = time.perf_counter()
            event = PlanEvent(
                plan_time=0.0, fetch_time=0.0, counts_ready=now, done=now,
                plan_speedup=1.0, num_shadowed=0,
                version=getattr(self._engine, "placements_version", 0),
                ok=False, failure="worker_crash")
        # Plan time the dispatch path spent waiting: overlap of
        # [t_wait, now] with the worker's [counts_ready, done] window.
        event.exposed = max(0.0, event.done - max(t_wait, event.counts_ready))
        if not event.ok:
            self._restart_worker()
        return event

    def close(self) -> None:
        """Idempotent shutdown: cancel the pending plan if it has not
        started, else drain it with a bounded join (a wedged worker must
        not block interpreter exit) — its result/exception is discarded
        either way."""
        if self._closed:
            return
        self._closed = True
        f, self._future = self._future, None
        drained = True
        if f is not None and not f.cancel():
            try:
                f.result(timeout=5.0)
            except Exception:
                drained = f.done()
        self._exec.shutdown(wait=drained, cancel_futures=True)
        if self._layer_pool is not None:
            self._layer_pool.shutdown(wait=drained, cancel_futures=True)

    def __enter__(self) -> "PlanPipeline":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
