"""Serving: batched autoregressive decode with KV/SSM caches.

``make_serve_step`` builds the jitted single-token step used both by the
serving example and by the decode-shape dry-runs (decode_32k / long_500k
lower exactly this function).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.parallel import ParallelCtx


def make_serve_step(cfg: ModelConfig, ctx: ParallelCtx) -> Callable:
    def step(params, caches, token, cache_index, placements=None):
        logits, caches = model_lib.decode_step(
            params, caches, token, cache_index, cfg, ctx,
            placements=placements)
        return logits, caches
    return jax.jit(step, donate_argnums=(1,))


def prefill(params, caches, tokens, cfg: ModelConfig, ctx: ParallelCtx,
            serve_step=None):
    """Feed a prompt through the decode path token-by-token (cache fill).

    A fused prefill kernel is a §Perf item; this sequential fill is the
    correctness baseline the fused path must match."""
    serve_step = serve_step or make_serve_step(cfg, ctx)
    B, S = tokens.shape
    logits = None
    for t in range(S):
        logits, caches = serve_step(params, caches, tokens[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
    return logits, caches


def decode_tokens(params, caches, last_logits, start_index: int,
                  num_tokens: int, cfg: ModelConfig, ctx: ParallelCtx,
                  *, temperature: float = 0.0, key=None, serve_step=None):
    """Greedy (or sampled) generation of ``num_tokens`` continuations."""
    serve_step = serve_step or make_serve_step(cfg, ctx)
    B = last_logits.shape[0]
    out = []
    logits = last_logits
    for i in range(num_tokens):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1] / temperature)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        out.append(nxt)
        logits, caches = serve_step(params, caches, nxt,
                                    jnp.asarray(start_index + i, jnp.int32))
    return jnp.concatenate(out, axis=1), caches
