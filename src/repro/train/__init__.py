from .runtime import (OverlapTelemetry, PlacementCache, PlanEvent,
                      PlanPipeline, StepStats)
from .trainer import TrainState, Trainer, make_train_step
from .serve import decode_tokens, make_serve_step, prefill

__all__ = ["TrainState", "Trainer", "make_train_step", "decode_tokens",
           "make_serve_step", "prefill", "OverlapTelemetry",
           "PlacementCache", "PlanEvent", "PlanPipeline", "StepStats"]
