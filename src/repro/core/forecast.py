"""Per-layer load forecasting: EMA prediction + drift/stability phases.

"Prediction Is All MoE Needs" (PAPERS.md) observes that expert load
distributions move from *fluctuating* to *stabilizing* as training
progresses — exactly the regime split Pro-Prophet's locality property
already exploits implicitly.  This module makes the signal explicit: a
:class:`LoadForecaster` per MoE layer maintains an EMA over the observed
routing matrices, scores each new observation by its **prediction
error** (relative L1 distance between the observation and the forecast
that would have been used for it), and classifies the layer into one of
three phases:

* ``fluctuating`` — prediction error above ``drift_threshold`` (or no
  history yet).  The forecast is untrustworthy; the planner should run
  every step and the cadence backoff resets.
* ``drifting``    — error between the thresholds: loads are moving but
  slowly enough that the EMA tracks them.  Plan at the base cadence.
* ``stable``      — error below ``stable_threshold`` for ``patience``
  consecutive observations.  The cached plan stays near-optimal; the
  engine backs the replan cadence off exponentially
  (``EngineConfig.plan_cadence_max`` / ``REPRO_PLAN_CADENCE_MAX``).

The engine plans from :meth:`predict` — the forecast for step *j+1* —
instead of step *j−1*'s raw counts, and the EMA's smoothing also damps
the multinomial sampling noise that makes last-value planning churn.

Invariants the property tests pin (``tests/test_forecast.py``):
constant loads are an exact EMA fixed point (the update uses the
``ema + (1−decay)·(g − ema)`` form, so ``g == ema`` leaves the EMA
bitwise unchanged for any decay) with drift exactly 0.0; an injected
step change re-flags the layer ``fluctuating`` within one update.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

Array = np.ndarray

PHASES = ("fluctuating", "drifting", "stable")


class LoadForecaster:
    """EMA forecast of one layer's routing matrix + phase detector.

    ``decay`` is the weight kept on history (0 ⇒ last-value predictor,
    1 ⇒ frozen first observation); thresholds are on the *relative* L1
    prediction error ``|g − forecast|₁ / |g|₁`` so they are invariant to
    token count; ``patience`` is the number of consecutive calm
    observations required before the layer is declared ``stable``.
    """

    def __init__(self, num_devices: int, num_experts: int, *,
                 decay: float = 0.5, stable_threshold: float = 0.15,
                 drift_threshold: float = 0.4, patience: int = 3):
        assert 0.0 <= decay < 1.0, decay
        assert 0.0 < stable_threshold <= drift_threshold, (
            stable_threshold, drift_threshold)
        self.D, self.E = int(num_devices), int(num_experts)
        self.decay = float(decay)
        self.stable_threshold = float(stable_threshold)
        self.drift_threshold = float(drift_threshold)
        self.patience = max(1, int(patience))
        self._ema: Optional[Array] = None
        self.phase: str = "fluctuating"   # cold start: nothing to trust
        self.drift: float = float("inf")  # last prediction error
        self._calm = 0                    # consecutive sub-stable errors

    def update(self, g: Array) -> str:
        """Ingest one observed routing matrix; returns the new phase.

        The drift metric is computed against the *pre-update* EMA — the
        forecast a consumer would actually have planned step ``j`` with —
        then the EMA absorbs the observation.
        """
        g = np.asarray(g, dtype=np.float64)
        assert g.shape == (self.D, self.E), (g.shape, (self.D, self.E))
        if self._ema is None:
            self._ema = g.copy()
            self.phase = "fluctuating"
            self.drift = float("inf")
            self._calm = 0
            return self.phase
        total = float(np.abs(g).sum())
        self.drift = float(np.abs(g - self._ema).sum()) / max(total, 1.0)
        # g == ema keeps the EMA bitwise fixed for any decay (the
        # correction term is exactly zero) — the fixed-point property.
        self._ema = self._ema + (1.0 - self.decay) * (g - self._ema)
        if self.drift > self.drift_threshold:
            self.phase, self._calm = "fluctuating", 0
        elif self.drift > self.stable_threshold:
            self.phase, self._calm = "drifting", 0
        else:
            self._calm += 1
            self.phase = "stable" if self._calm >= self.patience \
                else "drifting"
        return self.phase

    def predict(self) -> Optional[Array]:
        """Forecast routing matrix for the next step (None before any
        observation).  A copy — safe to hand to the greedy search."""
        return None if self._ema is None else self._ema.copy()

    def snapshot(self) -> Tuple:
        """State capture for watchdog rollback (``ProProphetEngine
        .snapshot``): a rejected plan must not leave the phase detector
        advanced past the placements it was rolled back with."""
        return (None if self._ema is None else self._ema.copy(),
                self.phase, self.drift, self._calm)

    def restore(self, snap: Tuple) -> None:
        ema, phase, drift, calm = snap
        self._ema = None if ema is None else ema.copy()
        self.phase = phase
        self.drift = drift
        self._calm = calm
