"""Per-device health tracking: EMA step timings + deviation classification.

Pro-Prophet balances *token* skew across homogeneous devices; the
degraded-mode runtime also has to survive *hardware* skew — stragglers,
thermally throttled chips, and outright device loss.  FlexMoE (PAPERS.md)
frames placement as continuously adjusted resource allocation, under
which a degraded device is simply a device whose effective throughput
dropped — so the existing planner/relocation machinery is the natural
evacuation engine, it just needs a health signal.

This module is that signal.  A :class:`DeviceHealthTracker` ingests one
per-device timing vector per training step (seconds for the device's
slice of the step; ``NaN``/``inf`` = missed heartbeat), smooths each
device with the same EMA form as :class:`repro.core.forecast
.LoadForecaster`, and scores each device by its **deviation ratio** —
smoothed time over the fleet median.  Classification mirrors the
forecaster's patience-gated phase detection:

* ``healthy``  — ratio below ``degraded_threshold``.
* ``degraded`` — ratio ≥ ``degraded_threshold`` for ``patience``
  consecutive steps.  Carries a throughput ``factor`` = median/ema in
  (0, 1): the device runs at that fraction of fleet speed.  The perf
  model prices its work accordingly and the planner drains hot experts
  away from it.
* ``lost``     — ratio ≥ ``lost_threshold`` for ``patience`` steps, or
  ``patience`` consecutive missed heartbeats (non-finite timings).  The
  planner treats its capacity as zero and force-evacuates its experts.

Recovery is symmetric: ``recovery_patience`` consecutive calm, finite
observations return a degraded or lost device to ``healthy`` — a
transient straggle must not permanently shrink the fleet.

``snapshot``/``restore`` capture the full per-device state as a plain
tuple (forecaster style) so the PR 6 watchdog can roll the tracker back
together with the placements it classified for.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

Array = np.ndarray

HEALTH_STATES = ("healthy", "degraded", "lost")

# Lost devices report factor 0.0; consumers that need finite modeled
# times (PerfModel) clamp to this floor instead.
FACTOR_FLOOR = 1e-3


class DeviceHealthTracker:
    """EMA over per-device step timings + patience-gated health states.

    ``decay`` is the weight kept on history (same convention as the load
    forecaster); thresholds are on the *ratio* of a device's smoothed
    timing to the fleet median, so they are invariant to the absolute
    step time; ``patience`` gates demotion (healthy→degraded→lost) and
    ``recovery_patience`` gates promotion back to healthy.
    """

    def __init__(self, num_devices: int, *, decay: float = 0.5,
                 degraded_threshold: float = 1.5,
                 lost_threshold: float = 4.0,
                 patience: int = 3, recovery_patience: int = 3):
        assert 0.0 <= decay < 1.0, decay
        assert 1.0 < degraded_threshold <= lost_threshold, (
            degraded_threshold, lost_threshold)
        self.D = int(num_devices)
        self.decay = float(decay)
        self.degraded_threshold = float(degraded_threshold)
        self.lost_threshold = float(lost_threshold)
        self.patience = max(1, int(patience))
        self.recovery_patience = max(1, int(recovery_patience))
        self._ema: Optional[Array] = None       # smoothed per-device time
        self._state: List[str] = ["healthy"] * self.D
        self._factor = np.ones(self.D)          # relative speed in (0, 1]
        self._hot = np.zeros(self.D, dtype=np.int64)     # consecutive slow
        self._very_hot = np.zeros(self.D, dtype=np.int64)  # consecutive lost-grade
        self._calm = np.zeros(self.D, dtype=np.int64)    # consecutive calm
        self._missed = np.zeros(self.D, dtype=np.int64)  # consecutive NaN
        self.updates = 0

    # -- ingestion -------------------------------------------------------
    def update(self, times: Array) -> Tuple[str, ...]:
        """Ingest one per-device step-timing vector; returns the states.

        Non-finite entries are missed heartbeats: the device's EMA is
        left untouched and its miss streak advances (``patience``
        consecutive misses ⇒ ``lost``).  Finite entries reset the miss
        streak and update the EMA with the forecaster's fixed-point form
        ``ema + (1 − decay)·(t − ema)``.
        """
        t = np.asarray(times, dtype=np.float64)
        assert t.shape == (self.D,), (t.shape, self.D)
        self.updates += 1
        finite = np.isfinite(t)
        if self._ema is None:
            self._ema = np.where(finite, t, np.nan)
        else:
            ema = self._ema
            self._ema = np.where(
                finite & np.isfinite(ema),
                ema + (1.0 - self.decay) * (t - ema),
                np.where(finite, t, ema))
        self._missed = np.where(finite, 0, self._missed + 1)

        # Fleet reference: median smoothed time over devices that are
        # reporting (finite EMA) — a dead device must not drag the
        # reference toward its own pathology.
        ok = np.isfinite(self._ema)
        ref = float(np.median(self._ema[ok])) if ok.any() else 0.0
        for d in range(self.D):
            self._step_device(d, ref, bool(finite[d]))
        return self.states()

    def _step_device(self, d: int, ref: float, finite: bool) -> None:
        if self._missed[d] >= self.patience:
            self._state[d] = "lost"
            self._factor[d] = 0.0
            self._hot[d] = self._very_hot[d] = self._calm[d] = 0
            return
        if not finite:
            return  # missed beat below the loss patience: hold state
        ema = float(self._ema[d])
        ratio = ema / ref if (ref > 0.0 and np.isfinite(ema)) else 1.0
        if ratio >= self.degraded_threshold:
            self._hot[d] += 1
            self._very_hot[d] = (self._very_hot[d] + 1
                                 if ratio >= self.lost_threshold else 0)
            self._calm[d] = 0
            if self._very_hot[d] >= self.patience:
                self._state[d] = "lost"
                self._factor[d] = 0.0
            elif self._hot[d] >= self.patience:
                if self._state[d] != "lost":
                    self._state[d] = "degraded"
                    self._factor[d] = min(1.0, 1.0 / ratio)
            elif self._state[d] == "degraded":
                # already degraded: track the factor while it stays hot
                self._factor[d] = min(1.0, 1.0 / ratio)
        else:
            self._hot[d] = self._very_hot[d] = 0
            if self._state[d] == "healthy":
                self._calm[d] = 0
                self._factor[d] = 1.0
            else:
                self._calm[d] += 1
                if self._calm[d] >= self.recovery_patience:
                    self._state[d] = "healthy"
                    self._factor[d] = 1.0
                    self._calm[d] = 0

    def mark_lost(self, device: int) -> None:
        """Out-of-band loss signal (e.g. a failed collective): classify
        immediately instead of waiting out the heartbeat patience."""
        d = int(device)
        assert 0 <= d < self.D, d
        self._state[d] = "lost"
        self._factor[d] = 0.0
        self._missed[d] = self.patience
        self._hot[d] = self._very_hot[d] = self._calm[d] = 0

    # -- queries ---------------------------------------------------------
    def states(self) -> Tuple[str, ...]:
        return tuple(self._state)

    def state_of(self, device: int) -> str:
        return self._state[int(device)]

    def factors(self) -> Array:
        """Per-device relative throughput in [0, 1]: 1 healthy, the
        measured fraction for degraded, 0 for lost.  A copy — safe to
        hand to the perf model."""
        return self._factor.copy()

    def degraded(self) -> List[int]:
        return [d for d in range(self.D) if self._state[d] == "degraded"]

    def lost(self) -> List[int]:
        return [d for d in range(self.D) if self._state[d] == "lost"]

    def healthy(self) -> List[int]:
        return [d for d in range(self.D) if self._state[d] == "healthy"]

    @property
    def all_healthy(self) -> bool:
        return all(s == "healthy" for s in self._state)

    def summary(self) -> str:
        """Compact ``healthy`` / ``degraded:1,3`` / ``lost:2`` label for
        telemetry lines."""
        if self.all_healthy:
            return "healthy"
        parts = []
        deg, lost = self.degraded(), self.lost()
        if deg:
            parts.append("degraded:" + ",".join(str(d) for d in deg))
        if lost:
            parts.append("lost:" + ",".join(str(d) for d in lost))
        return " ".join(parts)

    # -- watchdog rollback ----------------------------------------------
    def snapshot(self) -> Tuple:
        """Full-state capture for ``ProProphetEngine.snapshot``: a
        rejected plan must not leave health classifications advanced past
        the placements they were computed for."""
        return (None if self._ema is None else self._ema.copy(),
                tuple(self._state), self._factor.copy(),
                self._hot.copy(), self._very_hot.copy(),
                self._calm.copy(), self._missed.copy(), self.updates)

    def restore(self, snap: Tuple) -> None:
        ema, state, factor, hot, very_hot, calm, missed, updates = snap
        self._ema = None if ema is None else ema.copy()
        self._state = list(state)
        self._factor = factor.copy()
        self._hot = hot.copy()
        self._very_hot = very_hot.copy()
        self._calm = calm.copy()
        self._missed = missed.copy()
        self.updates = updates
