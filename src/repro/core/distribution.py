"""Input-distribution profiling and the locality observation (paper §II.B).

The paper's key empirical property: the per-expert input distribution of a
MoE layer changes only slightly between adjacent iterations ("locality",
Fig. 4).  Everything here is host-side numpy — it runs between device steps
and its cost must stay negligible next to a training step.

The central object is the *routing matrix* ``G``: ``G[d, e]`` is the number
of tokens resident on device ``d`` that the gate routed to expert ``e``.
The per-expert distribution is ``G.sum(0)``; the per-device load depends on
the expert placement and is computed in :mod:`repro.core.placement`.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Optional, Sequence

import numpy as np

Array = np.ndarray


def routing_matrix_from_assignments(
    expert_assignment: Array, device_of_token: Array, num_experts: int, num_devices: int
) -> Array:
    """Build ``G[d, e]`` from flat per-token assignments.

    ``expert_assignment``: int array ``[N, k]`` or ``[N]`` of expert ids.
    ``device_of_token``: int array ``[N]`` of source device ids.
    """
    ea = np.asarray(expert_assignment)
    if ea.ndim == 1:
        ea = ea[:, None]
    dev = np.asarray(device_of_token)
    g = np.zeros((num_devices, num_experts), dtype=np.int64)
    for k in range(ea.shape[1]):
        np.add.at(g, (dev, ea[:, k]), 1)
    return g


def balance_degree(counts: Array) -> float:
    """Paper §VI.C: the balance degree is the *standard deviation* of the
    input-distribution tensor (per-expert token counts). Lower is better."""
    return float(np.std(np.asarray(counts, dtype=np.float64)))


def imbalance_ratio(counts: Array) -> float:
    """max/mean — 1.0 is perfectly balanced."""
    c = np.asarray(counts, dtype=np.float64)
    m = c.mean()
    return float(c.max() / m) if m > 0 else 1.0


def rb_ratio(before: Array, after: Array) -> float:
    """RB (paper Fig. 16): ratio of balance degree before/after a
    load-balancing solution is applied.  >1 means the solution balanced."""
    b, a = balance_degree(before), balance_degree(after)
    if a == 0.0:
        return np.inf if b > 0 else 1.0
    return b / a


def distribution_similarity(prev: Array, cur: Array) -> float:
    """Cosine similarity between two per-expert distributions (locality
    metric; ≈1.0 across adjacent iterations per the paper's Fig. 4)."""
    p = np.asarray(prev, dtype=np.float64).ravel()
    c = np.asarray(cur, dtype=np.float64).ravel()
    np_, nc = np.linalg.norm(p), np.linalg.norm(c)
    if np_ == 0 or nc == 0:
        return 1.0 if np_ == nc else 0.0
    return float(np.dot(p, c) / (np_ * nc))


@dataclasses.dataclass
class LocalityStats:
    """Summary of observed locality for one MoE layer."""

    mean_similarity: float
    min_similarity: float
    mean_l1_drift: float  # mean |Δcounts| / total, adjacent iterations


class LocalityTracker:
    """Per-layer history of routing matrices + next-iteration predictor.

    The paper predicts iteration ``j+1``'s distribution from iteration
    ``j``'s (the latest is required "for higher estimation accuracy",
    §V.A).  We support plain last-value prediction (the paper's choice) and
    an EMA refinement; both are evaluated in the locality benchmark.
    """

    def __init__(self, num_devices: int, num_experts: int, history: int = 8,
                 ema_decay: float = 0.5):
        self.num_devices = num_devices
        self.num_experts = num_experts
        self._hist: Deque[Array] = deque(maxlen=history)
        self._ema: Optional[Array] = None
        self.ema_decay = ema_decay

    def update(self, g: Array) -> None:
        g = np.asarray(g, dtype=np.float64)
        assert g.shape == (self.num_devices, self.num_experts), (
            g.shape, (self.num_devices, self.num_experts))
        self._hist.append(g)
        if self._ema is None:
            self._ema = g.copy()
        else:
            self._ema = self.ema_decay * self._ema + (1.0 - self.ema_decay) * g

    @property
    def latest(self) -> Optional[Array]:
        return self._hist[-1] if self._hist else None

    def predict_next(self, mode: str = "last") -> Optional[Array]:
        """Predicted routing matrix for the upcoming iteration."""
        if not self._hist:
            return None
        if mode == "last":
            return self._hist[-1]
        if mode == "ema":
            return self._ema
        raise ValueError(f"unknown predictor mode: {mode}")

    def locality_stats(self) -> LocalityStats:
        if len(self._hist) < 2:
            return LocalityStats(1.0, 1.0, 0.0)
        sims, drifts = [], []
        hist = list(self._hist)
        for prev, cur in zip(hist, hist[1:]):
            pc, cc = prev.sum(0), cur.sum(0)
            sims.append(distribution_similarity(pc, cc))
            tot = max(cc.sum(), 1.0)
            drifts.append(float(np.abs(cc - pc).sum()) / tot)
        return LocalityStats(float(np.mean(sims)), float(np.min(sims)),
                             float(np.mean(drifts)))


class ModelLocalityTracker:
    """One :class:`LocalityTracker` per MoE layer of a model."""

    def __init__(self, num_layers: int, num_devices: int, num_experts: int,
                 history: int = 8):
        self.layers = [LocalityTracker(num_devices, num_experts, history)
                       for _ in range(num_layers)]

    def update(self, per_layer_g: Sequence[Array]) -> None:
        assert len(per_layer_g) == len(self.layers)
        for tracker, g in zip(self.layers, per_layer_g):
            tracker.update(g)

    def predict_next(self, mode: str = "last"):
        return [t.predict_next(mode) for t in self.layers]
