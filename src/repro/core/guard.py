"""Placement-invariant and routing-count guards (the plan watchdog's teeth).

Pro-Prophet mutates live training state every few steps: the background
planner rewrites placements, relocations permute optimizer slabs, and the
routing counts driving it all come straight off the device.  Any of those
can go wrong — a planner bug, a NaN'd gate, a torn transfer — and without
validation the damage surfaces steps later as silent mis-routing.  This
module centralizes the checks the runtime watchdog
(:func:`repro.train.runtime.run_plan`) applies at the two ingestion
boundaries:

* **counts in** — :func:`sanitize_counts` cleans the observed routing
  matrices before the engine ingests them (NaN/inf/negative entries fall
  back to the last-good layer, or a uniform distribution when there is no
  history yet).  :func:`check_counts` is the strict variant
  ``ProProphetEngine.observe`` applies as a backstop: garbage that slips
  past sanitization raises instead of poisoning the planner.

* **placements out** — :func:`validate_engine` checks every planner
  output against the placement invariants the traced step relies on:
  ``slot_of`` is a valid permutation, per-device slot counts stay static,
  shadow sets name real devices/experts and exclude the owner, the
  placement's device width matches the engine's EP axis, and the modeled
  times are finite.  A violation raises
  :class:`PlacementInvariantError`, which the watchdog converts into a
  fall-back to the last-good placement version — training continues on
  stale placements, never on corrupt ones.

Failures here degrade throughput, not correctness: placements only decide
*where* compute happens, so rejecting a plan costs balance, not loss bits.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

Array = np.ndarray


class GuardError(ValueError):
    """Base class for ingestion/invariant guard failures."""


class CountsError(GuardError):
    """Routing counts failed the ingestion guard (shape/finiteness)."""


class PlacementInvariantError(GuardError):
    """A planner output violated the placement invariants."""


class PlanDeadlineError(GuardError):
    """The greedy search hit its cooperative deadline and aborted
    mid-move-loop (``REPRO_PLAN_DEADLINE_MS``).  Unlike the watchdog's
    post-hoc check — which can only *reject* an overrunning plan after
    it completes — this unsticks the planner worker itself: the search
    checks the deadline token every candidate move and bails."""


# ---------------------------------------------------------------------------
# Routing-count ingestion
# ---------------------------------------------------------------------------

def check_counts(g: Array, shape: Tuple[int, int], *, layer: int = -1) -> None:
    """Strict ingestion guard for one layer's routing matrix: exact
    ``(D, E)`` shape, all entries finite and non-negative.  Raises
    :class:`CountsError` naming the layer and offense — the backstop
    ``engine.observe`` applies so garbage can never poison the planner
    (the watchdog path sanitizes *before* observe, so a trip here means a
    caller bypassed :func:`sanitize_counts`)."""
    g = np.asarray(g)
    where = f" (layer {layer})" if layer >= 0 else ""
    if g.shape != tuple(shape):
        raise CountsError(
            f"routing counts{where} have shape {g.shape}, expected {shape}")
    if not np.issubdtype(g.dtype, np.number):
        raise CountsError(
            f"routing counts{where} have non-numeric dtype {g.dtype}")
    if not np.isfinite(g).all():
        raise CountsError(
            f"routing counts{where} contain NaN/inf entries")
    if (g < 0).any():
        raise CountsError(
            f"routing counts{where} contain negative entries")


def _clean_layer(g: Array) -> bool:
    return bool(np.isfinite(g).all() and not (g < 0).any())


@dataclasses.dataclass
class SanitizeReport:
    """What :func:`sanitize_counts` repaired: ``repaired`` lists every
    layer index that was replaced, ``uniform`` the subset that had no
    clean fallback and fell back to the all-ones prior — the
    first-observation path (no last-good counts yet) lands every dirty
    layer there, and the watchdog's plan event surfaces the split so an
    operator can tell "repaired from history" apart from "planned
    blind"."""

    repaired: List[int] = dataclasses.field(default_factory=list)
    uniform: List[int] = dataclasses.field(default_factory=list)

    @property
    def num_sanitized(self) -> int:
        return len(self.repaired)

    def __bool__(self) -> bool:
        return bool(self.repaired)


def sanitize_counts(counts: Array,
                    fallback: Optional[Sequence[Optional[Array]]] = None
                    ) -> Tuple[List[Array], SanitizeReport]:
    """Split stacked ``[L, D, E]`` device counts into clean per-layer
    float64 routing matrices.

    A layer containing NaN/inf/negative entries is replaced wholesale by
    its ``fallback`` layer (the engine's last-good observation) when that
    is itself clean, else by a uniform all-ones matrix — planning from a
    flat distribution is a safe no-op-ish prior, planning from NaNs is
    corruption.  Returns ``(layers, report)`` where the
    :class:`SanitizeReport` names the repaired layers and which of them
    fell back to uniform.  A count array of the wrong rank cannot be
    per-layer repaired and raises :class:`CountsError` (the watchdog
    turns that into a plan fallback).
    """
    counts = np.asarray(counts)
    if counts.ndim != 3:
        raise CountsError(
            f"stacked routing counts must be [L, D, E], got shape "
            f"{counts.shape}")
    layers: List[Array] = []
    report = SanitizeReport()
    for li in range(counts.shape[0]):
        g = counts[li].astype(np.float64)
        if _clean_layer(g):
            layers.append(g)
            continue
        report.repaired.append(li)
        fb = None
        if fallback is not None and li < len(fallback):
            fb = fallback[li]
        if fb is not None and _clean_layer(np.asarray(fb)):
            layers.append(np.asarray(fb, dtype=np.float64).copy())
        else:
            layers.append(np.ones_like(g))
            report.uniform.append(li)
    return layers, report


# ---------------------------------------------------------------------------
# Placement invariants
# ---------------------------------------------------------------------------

def validate_placement(pl, *, num_experts: int, num_devices: int,
                       layer: int = -1) -> None:
    """Check one placement against the invariants the traced step
    assumes.  Raises :class:`PlacementInvariantError` naming the layer
    and violated invariant."""
    where = f"layer {layer}: " if layer >= 0 else ""
    E, D = num_experts, num_devices
    if getattr(pl, "num_experts", None) != E:
        raise PlacementInvariantError(
            f"{where}placement has {getattr(pl, 'num_experts', None)} "
            f"experts, engine expects {E}")
    if getattr(pl, "num_devices", None) != D:
        raise PlacementInvariantError(
            f"{where}placement is {getattr(pl, 'num_devices', None)} "
            f"devices wide, engine EP axis is {D} — the packed "
            f"shadow_devs arrays would mis-index")
    slots = np.asarray(pl.slots)
    if slots.shape != (E,) or not np.array_equal(np.sort(slots),
                                                 np.arange(E)):
        raise PlacementInvariantError(
            f"{where}slot_of is not a permutation of {E} slots")
    # Static per-device slot counts: every device must own exactly its
    # home share of physical slots regardless of which experts sit in
    # them (guaranteed for true permutations, but checked explicitly —
    # it is the invariant the static-shape relocation exchange needs).
    from .placement import default_owner
    if E >= D:
        per_dev = np.bincount(default_owner(E, D)[slots], minlength=D)
        if not (per_dev == per_dev[0]).all():
            raise PlacementInvariantError(
                f"{where}per-device slot counts are not static: {per_dev}")
    owner = pl.owner
    for e, devs in pl.shadows.items():
        if not (0 <= int(e) < E):
            raise PlacementInvariantError(
                f"{where}shadow entry names expert {e} outside [0, {E})")
        for d in devs:
            if not (0 <= int(d) < D):
                raise PlacementInvariantError(
                    f"{where}expert {e} shadows onto device {d} outside "
                    f"[0, {D})")
        if int(owner[int(e)]) in devs:
            raise PlacementInvariantError(
                f"{where}expert {e}'s shadow set contains its owner "
                f"{int(owner[int(e)])}")


def validate_engine(engine) -> None:
    """Post-plan invariant sweep the watchdog runs after every
    ``engine.observe``: every layer's placement is structurally valid for
    this engine's geometry and the modeled times are finite.  Raises
    :class:`PlacementInvariantError` on the first violation."""
    cfg = engine.cfg
    for li, pl in enumerate(engine.placements):
        validate_placement(pl, num_experts=cfg.num_experts,
                           num_devices=cfg.num_devices, layer=li)
    pt = engine.predicted_times()
    for k, v in pt.items():
        if not np.isfinite(v):
            raise PlacementInvariantError(
                f"modeled time '{k}' is not finite: {v}")
    validate_forecast(engine)
    validate_health(engine)


def validate_health(engine) -> None:
    """Device-health state invariants: every tracked state is a known
    label and the throughput factors are finite in [0, 1] — a corrupted
    tracker would otherwise mis-price every heterogeneity-aware plan.
    Engines without the health surface (test stubs) are skipped."""
    tracker = getattr(engine, "health", None)
    if tracker is None:
        return
    from .health import HEALTH_STATES
    for d, s in enumerate(tracker.states()):
        if s not in HEALTH_STATES:
            raise PlacementInvariantError(
                f"device {d}: unknown health state {s!r}")
    f = tracker.factors()
    if not (np.isfinite(f).all() and (f >= 0.0).all() and (f <= 1.0).all()):
        raise PlacementInvariantError(
            f"device health factors outside [0, 1]: {f}")


def validate_forecast(engine) -> None:
    """Predictive-planning state invariants: every layer's forecast EMA
    is finite, its phase is a known label, and the cadence backoff sits
    in ``[1, cadence_max]`` — corrupt counts that slip into the
    forecaster would otherwise poison every future predicted-load plan.
    Engines without the forecast surface (test stubs) are skipped."""
    fcs = getattr(engine, "forecasters", None)
    if not fcs:
        return
    from .forecast import PHASES
    for li, fc in enumerate(fcs):
        ema = fc.predict()
        if ema is not None and not np.isfinite(ema).all():
            raise PlacementInvariantError(
                f"layer {li}: forecast EMA contains NaN/inf entries")
        if fc.phase not in PHASES:
            raise PlacementInvariantError(
                f"layer {li}: unknown forecast phase {fc.phase!r}")
    cap = max(int(getattr(engine, "cadence_max", 1)),
              int(getattr(engine.cfg, "replan_interval", 1)), 1)
    for li, iv in enumerate(getattr(engine, "_plan_interval", [])):
        if not (1 <= int(iv) <= cap):
            raise PlacementInvariantError(
                f"layer {li}: plan cadence interval {iv} outside "
                f"[1, {cap}]")
