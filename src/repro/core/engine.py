"""Execution engine: planner × scheduler → a per-iteration step plan.

The engine is the piece the trainer talks to.  Per iteration it

  1. ingests the routing matrices observed on-device last step (one per MoE
     layer — cheap host transfers of ``[D, E]`` int32),
  2. lets each layer's :class:`LocalityPlanner` (re)plan at its cadence,
  3. packs the placements into the static-shape arrays the jitted train
     step consumes (``shadow_idx`` / ``shadow_valid`` / ``shadow_devs``
     stacked over MoE layers),
  4. exposes predicted timings (eq. 6 / eq. 8) for logging and benchmarks.

This is the paper's Fig. 5 "execution engine" realized for a JAX runtime:
the *Plan* primitive runs here on host, overlapped with device execution of
the current step (the locality property is what makes planning one step
ahead sound).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .perfmodel import HardwareSpec, PerfModel
from .placement import ExpertPlacement, traditional
from .planner import GreedyPlanner, LocalityPlanner, PlanResult

Array = np.ndarray


@dataclasses.dataclass
class EngineConfig:
    num_experts: int
    num_devices: int
    num_moe_layers: int
    s_max: int = 8
    n: int = 0                    # paper's n (devices NOT sent to)
    alpha: float = 0.25           # eq. 7 balance tolerance
    replan_interval: int = 1      # locality cadence
    predictor: str = "last"
    scheduled: bool = True        # plan against eq. 8 (planner×scheduler)
    trans_mode: str = "ring"      # TPU adaptation; "p2p" = paper-faithful
    policy: str = "pro_prophet"   # pro_prophet | fastermoe | top2 | top3 | none


class ProProphetEngine:
    def __init__(self, cfg: EngineConfig, hw: HardwareSpec):
        self.cfg = cfg
        self.perf = PerfModel(hw, cfg.num_devices, trans_mode=cfg.trans_mode)
        greedy = GreedyPlanner(self.perf, n=cfg.n, alpha=cfg.alpha,
                               s_max=cfg.s_max, scheduled=cfg.scheduled)
        self.planners: List[LocalityPlanner] = [
            LocalityPlanner(greedy, cfg.num_devices, cfg.num_experts,
                            replan_interval=cfg.replan_interval,
                            predictor=cfg.predictor)
            for _ in range(cfg.num_moe_layers)
        ]
        self._placements: List[ExpertPlacement] = [
            traditional(cfg.num_experts, cfg.num_devices)
            for _ in range(cfg.num_moe_layers)
        ]
        self.last_results: List[Optional[PlanResult]] = [None] * cfg.num_moe_layers

    # ------------------------------------------------------------------
    def observe(self, per_layer_g: Sequence[Array]) -> None:
        """Feed routing matrices observed in the step that just finished;
        plans the placements to use next step."""
        assert len(per_layer_g) == self.cfg.num_moe_layers
        if self.cfg.policy == "none":
            return
        from .baselines import fastermoe_plan, topk_policy
        for li, g in enumerate(per_layer_g):
            if self.cfg.policy == "pro_prophet":
                res = self.planners[li].maybe_plan(g)
                self._placements[li] = res.placement
                self.last_results[li] = res
            elif self.cfg.policy == "fastermoe":
                res = fastermoe_plan(self.perf, g, max_shadows=self.cfg.s_max)
                self._placements[li] = res.placement
                self.last_results[li] = res
            elif self.cfg.policy in ("top2", "top3"):
                k = int(self.cfg.policy[-1])
                self._placements[li] = topk_policy(g, min(k, self.cfg.s_max))
            else:
                raise ValueError(f"unknown policy {self.cfg.policy}")

    @property
    def placements(self) -> List[ExpertPlacement]:
        return list(self._placements)

    def step_arrays(self) -> Dict[str, Array]:
        """Stacked static-shape placement arrays for the jitted step."""
        cfg = self.cfg
        idx = np.zeros((cfg.num_moe_layers, cfg.s_max), dtype=np.int32)
        valid = np.zeros((cfg.num_moe_layers, cfg.s_max), dtype=np.float32)
        devs = np.zeros((cfg.num_moe_layers, cfg.s_max, cfg.num_devices),
                        dtype=np.float32)
        for li, pl in enumerate(self._placements):
            arrs = pl.to_device_arrays(cfg.s_max)
            idx[li] = arrs["shadow_idx"]
            valid[li] = arrs["shadow_valid"]
            devs[li] = arrs["shadow_devs"]
        return {"shadow_idx": idx, "shadow_valid": valid, "shadow_devs": devs}

    def predicted_times(self) -> Dict[str, float]:
        ts = [r.predicted_time for r in self.last_results if r is not None]
        bs = [r.baseline_time for r in self.last_results if r is not None]
        if not ts:
            return {"predicted": 0.0, "baseline": 0.0, "speedup": 1.0}
        return {"predicted": float(np.sum(ts)), "baseline": float(np.sum(bs)),
                "speedup": float(np.sum(bs) / max(np.sum(ts), 1e-12))}
