"""Execution engine: planner × scheduler → a per-iteration step plan.

The engine is the piece the trainer talks to.  Per iteration it

  1. ingests the routing matrices observed on-device last step (one per MoE
     layer — cheap host transfers of ``[D, E]`` int32),
  2. lets each layer's :class:`LocalityPlanner` (re)plan at its cadence,
  3. packs the placements into the static-shape arrays the jitted train
     step consumes (``shadow_idx`` / ``shadow_valid`` / ``shadow_devs`` /
     ``expert_slot`` stacked over MoE layers),
  4. exposes predicted timings (eq. 6 / eq. 8) for logging and benchmarks.

This is the paper's Fig. 5 "execution engine" realized for a JAX runtime:
the *Plan* primitive runs here on host, overlapped with device execution of
the current step (the locality property is what makes planning one step
ahead sound).  ``observe`` may fan the independent per-layer searches out
over a caller-supplied thread pool, and placements are *versioned*:
``placements_version`` bumps only when a placement actually changed, so the
trainer's :class:`~repro.train.runtime.PlacementCache` re-packs and
re-uploads the device arrays only on change (``step_arrays`` re-packs just
the layers that moved).

With dynamic expert migration enabled, the engine additionally tracks
the physical slot layout the device is currently at
(:meth:`pending_relocation` / :meth:`mark_relocated` /
:meth:`reset_layout`): the gap between the planned ``slot_of``
permutations and the device state is the relocation schedule the trainer
executes as a one-time EP-axis weight/optimizer exchange.

Threading contract: ``observe`` is the only mutator.  Callers running it on
a background thread (the async runtime) must order every ``step_arrays`` /
``placements_version`` / ``predicted_times`` read after the observe that
produced it — :meth:`repro.train.runtime.PlanPipeline.wait` provides that
edge.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import guard, scheduler
from .health import DeviceHealthTracker
from .perfmodel import HardwareSpec, PerfModel
from .placement import ExpertPlacement, default_owner, traditional
from .planner import GreedyPlanner, LocalityPlanner, PlanResult

Array = np.ndarray


@dataclasses.dataclass
class EngineConfig:
    num_experts: int
    num_devices: int
    num_moe_layers: int
    s_max: int = 8
    n: int = 0                    # paper's n (devices NOT sent to)
    alpha: float = 0.25           # eq. 7 balance tolerance
    replan_interval: int = 1      # locality cadence
    predictor: str = "last"
    scheduled: bool = True        # plan against eq. 8 (planner×scheduler)
    trans_mode: str = "ring"      # TPU adaptation; "p2p" = paper-faithful
    policy: str = "pro_prophet"   # pro_prophet | fastermoe | top2 | top3 | none
    # Dynamic expert migration (owner re-layout): when enabled the greedy
    # search scores migrate-vs-shadow per move (strategy "both") using the
    # amortized one-time weight-move cost over `migrate_window` steps (the
    # locality horizon; 0 ⇒ max(replan_interval, 50)).  Off by default —
    # the disabled path is bit-identical to the shadow-only planner.
    # REPRO_MIGRATION=0/1 overrides.
    enable_migration: bool = False
    migrate_window: float = 0.0
    migrate_state_factor: float = 3.0   # params + AdamW mu/nu
    # Churn control (modeled-win ≥ exchange-cost hysteresis): new owner
    # moves are adopted only when their steady-state win over the best
    # migration-free alternative is ≥ this multiple of the amortized
    # exchange cost.  Planning always starts from the *current* device
    # layout (already-executed moves are free), so 1.0 is exact
    # break-even; the default demands the win pay for the move twice.
    migrate_hysteresis: float = 2.0
    # Predictive load planning (core/forecast.py): an EMA forecaster per
    # layer classifies it fluctuating | drifting | stable, the planner
    # consumes the forecast for step j+1 instead of step j−1's counts,
    # and stable layers back their replan cadence off exponentially up
    # to `plan_cadence_max` observations (0 ⇒ REPRO_PLAN_CADENCE_MAX,
    # default 16), reset the moment the layer drifts.  Off by default —
    # the disabled path is bit-identical to the last-value planner.
    # REPRO_FORECAST=0/1 overrides.
    enable_forecast: bool = False
    forecast_decay: float = 0.5
    forecast_stable_threshold: float = 0.15
    forecast_drift_threshold: float = 0.4
    forecast_patience: int = 3
    plan_cadence_max: int = 0
    # Chunked a2a↔FEC pipelining (repro.models.moe): candidate chunk
    # counts the scheduler timeline picks from, and the modeled per-chunk
    # launch cost (collective setup + kernel dispatch) that keeps the
    # chooser at K=1 when the a2a is too small to be worth splitting.
    a2a_chunk_candidates: Tuple[int, ...] = (1, 2, 4, 8)
    a2a_chunk_overhead: float = 20e-6
    # Token-permutation pricing (repro.kernels.token_permute): the
    # HBM-bound dispatch/combine legs around the expert FFN, serial with
    # the chunked pipeline.  The chunk chooser's argmin is invariant to
    # them (they shift every K equally) but the telemetry makespans are
    # honest only when they are counted.  ``top_k`` and
    # ``capacity_factor`` mirror the layer config so the capacity-slot
    # count (G·C = top_k · capacity_factor · local tokens) matches what
    # the device allocates.
    top_k: int = 2
    capacity_factor: float = 1.25
    # Elastic degraded mode (core/health.py): a DeviceHealthTracker
    # classifies every EP rank healthy | degraded | lost from measured
    # per-step timings, the perf model prices work against the resulting
    # per-device throughput factors, and a lost rank's experts are
    # force-evacuated onto the survivors through the ordinary relocation
    # path.  Off by default — the disabled path never touches the
    # tracker, so pricing stays bit-identical to the homogeneous model.
    # REPRO_HEALTH=0/1 and REPRO_EVACUATE=0/1 override.
    enable_health: bool = False
    health_decay: float = 0.5
    degraded_threshold: float = 1.5
    lost_threshold: float = 4.0
    health_patience: int = 3
    health_recovery_patience: int = 3
    enable_evacuation: bool = True
    # Capacity-aware placement scoring: > 0 prices plans with per-device
    # buffer truncation at this capacity factor (dropped-token penalty);
    # 0 keeps the dense accounting bit-identical to prior planners.
    planner_capacity_factor: float = 0.0


class ProProphetEngine:
    """Planner state machine shared between the dispatch thread and the
    PlanPipeline worker.

    Shared-state discipline (checked statically by prophetlint R4): every
    engine mutation happens either on the worker thread inside
    ``observe`` (during the submit→wait window) or on the dispatch
    thread in the planner-idle window between ``wait()`` and
    ``submit()`` — the two never overlap, which is the happens-before
    edge that makes the registry below a plain owner list rather than a
    lock.  New methods touching these fields must be added to the
    registry or carry an ``allow(shared-state)`` annotation.
    """

    # prophetlint: shared(_placements, _version, _dirty, _cache, _last_g,
    #   _obs_count, _costs_cache, _device_slots, last_results,
    #   _plan_interval, _since_plan, plans_executed, plans_skipped,
    #   last_plan_info, health, _health_dirty, evacuations): owner=observe,
    #   _plan_layer, snapshot, restore,
    #   cancel_migrations, step_arrays, pending_relocation, relocations,
    #   mark_relocated, reset_layout, last_counts, _layer_costs,
    #   _all_layer_costs, chunk_plan, chunk_stats, predicted_times,
    #   placements, placements_version, _device_layout, observe_timings,
    #   health_summary, degraded_devices, lost_devices

    def __init__(self, cfg: EngineConfig, hw: HardwareSpec):
        from repro import flags
        self.cfg = cfg
        self.perf = PerfModel(hw, cfg.num_devices, trans_mode=cfg.trans_mode)
        flag = flags.migration()
        migration = cfg.enable_migration if flag is None else flag
        window = cfg.migrate_window or max(float(cfg.replan_interval), 50.0)
        hflag = flags.health()
        self.health_enabled = cfg.enable_health if hflag is None else hflag
        eflag = flags.evacuate()
        evacuate = cfg.enable_evacuation if eflag is None else eflag
        # Evacuation re-homes experts via slot swaps, which only take
        # effect through the relocation exchange — so the execution
        # machinery (pending_relocation tracking, plan-from-current
        # layout) must be live even when voluntary migration is off.
        # The greedy *strategy* still follows the migration policy: a
        # shadow-only planner stays shadow-only for voluntary moves.
        self.migration_enabled = migration or (self.health_enabled
                                               and evacuate)
        self.health = DeviceHealthTracker(
            cfg.num_devices, decay=cfg.health_decay,
            degraded_threshold=cfg.degraded_threshold,
            lost_threshold=cfg.lost_threshold,
            patience=cfg.health_patience,
            recovery_patience=cfg.health_recovery_patience)
        self._health_dirty = False
        self.evacuations = 0
        greedy = GreedyPlanner(
            self.perf, n=cfg.n, alpha=cfg.alpha, s_max=cfg.s_max,
            scheduled=cfg.scheduled,
            strategy="both" if migration else "shadow",
            migrate_window=window,
            migrate_state_factor=cfg.migrate_state_factor,
            migrate_hysteresis=cfg.migrate_hysteresis,
            capacity_factor=cfg.planner_capacity_factor,
            evacuate=evacuate)
        self.planners: List[LocalityPlanner] = [
            LocalityPlanner(greedy, cfg.num_devices, cfg.num_experts,
                            replan_interval=cfg.replan_interval,
                            predictor=cfg.predictor)
            for _ in range(cfg.num_moe_layers)
        ]
        self._placements: List[ExpertPlacement] = [
            traditional(cfg.num_experts, cfg.num_devices)
            for _ in range(cfg.num_moe_layers)
        ]
        self.last_results: List[Optional[PlanResult]] = [None] * cfg.num_moe_layers
        self._version = 0
        self._dirty = set(range(cfg.num_moe_layers))
        self._cache: Optional[Dict[str, Array]] = None
        # Last observed routing matrix per layer — the profiled stats the
        # chunk chooser (and the modeled overlap telemetry) run on.
        self._last_g: List[Optional[Array]] = [None] * cfg.num_moe_layers
        self._obs_count = 0
        self._costs_cache = None  # (token, [per-layer costs]) memo
        # Physical slot layout currently on the device (expert → slot, per
        # layer).  Updated only by mark_relocated() after the trainer
        # executes the weight/optimizer exchange — the gap between this
        # and the planned placements is the pending relocation schedule.
        self._device_slots: List[Array] = [
            np.arange(cfg.num_experts, dtype=np.int64)
            for _ in range(cfg.num_moe_layers)
        ]
        # Predictive load planning: per-layer forecaster + cadence
        # backoff state.  The forecaster only updates when enabled, so
        # the disabled path stays bit-identical to the last-value
        # planner; the plans_executed/skipped counters tick either way
        # (a cached-plan reuse at replan_interval > 1 is also a skip) —
        # the cadence-aware accounting the overlap telemetry reads.
        fflag = flags.forecast()
        self.forecast_enabled = (
            (cfg.enable_forecast if fflag is None else fflag)
            and cfg.policy == "pro_prophet")
        self.cadence_max = max(1, cfg.plan_cadence_max
                               or flags.plan_cadence_max())
        from .forecast import LoadForecaster
        self.forecasters: List[LoadForecaster] = [
            LoadForecaster(cfg.num_devices, cfg.num_experts,
                           decay=cfg.forecast_decay,
                           stable_threshold=cfg.forecast_stable_threshold,
                           drift_threshold=cfg.forecast_drift_threshold,
                           patience=cfg.forecast_patience)
            for _ in range(cfg.num_moe_layers)
        ]
        base = max(1, cfg.replan_interval)
        self._plan_interval: List[int] = [base] * cfg.num_moe_layers
        self._since_plan: List[int] = [0] * cfg.num_moe_layers
        self.plans_executed = 0
        self.plans_skipped = 0
        self.last_plan_info: Dict[str, int] = {
            "planned": 0, "skipped": 0, "stable": 0}

    # ------------------------------------------------------------------
    @property
    def placements_version(self) -> int:
        """Bumps exactly when some layer's placement changed — the
        trainer re-uploads device arrays only on a version change."""
        return self._version

    def _device_layout(self, li: int) -> ExpertPlacement:
        """The slot layout physically on the device for layer ``li`` —
        the base the planner plans *from* when migration is enabled, so
        already-executed owner moves are free and only new moves pay
        ``t_migrate``."""
        return ExpertPlacement(
            self.cfg.num_experts, self.cfg.num_devices, {},
            tuple(int(s) for s in self._device_slots[li]))

    def _plan_layer(self, li: int, g: Array,
                    deadline: Optional[float] = None):
        """One layer's planning step → (placement, PlanResult|None,
        planned?).  Layers are independent, so these may run on a thread
        pool (each call touches only its own layer's slots of the
        per-layer state lists).  ``deadline`` (absolute
        ``time.perf_counter()``) is threaded into the greedy search's
        cooperative cancellation checkpoints."""
        from .baselines import fastermoe_plan, topk_policy
        if self.cfg.policy == "pro_prophet":
            planner = self.planners[li]
            current = (self._device_layout(li) if self.migration_enabled
                       else None)
            if (self.health_enabled and current is not None
                    and self.perf.lost_devices()):
                # Plan from the last *planned* layout, not the executed
                # one: evacuation swaps land one dispatch later, and
                # re-deriving them from the stale device layout against
                # drifted counts would pick a new partner — one churned
                # relocation per layer per step, forever.  The planned
                # layout already contains the pending swaps, so the
                # evacuation pass is idempotent; the relocation delta is
                # still computed against the executed slots.
                current = self._placements[li]
            # A health transition (degraded/lost/recovered) re-prices the
            # perf model, so every layer must re-search immediately —
            # evacuation lands within one plan cadence of detection.
            force = True if self._health_dirty else None
            if not self.forecast_enabled:
                res, planned = planner.step(g, replan=force, current=current,
                                            deadline=deadline)
                return res.placement, res, planned
            fc = self.forecasters[li]
            phase = fc.update(g)
            base = max(1, self.cfg.replan_interval)
            if phase != "stable" or force:
                # Reset the backoff the moment the layer drifts (or the
                # fleet's health changes); a fluctuating layer
                # additionally replans immediately.
                self._plan_interval[li] = base
            self._since_plan[li] += 1
            due = (planner.current is None
                   or bool(force)
                   or phase == "fluctuating"
                   or self._since_plan[li] >= self._plan_interval[li])
            g_plan = fc.predict() if due else None
            res, planned = planner.step(g, replan=due, g_plan=g_plan,
                                        current=current, deadline=deadline)
            if planned:
                self._since_plan[li] = 0
                if phase == "stable" and not force:
                    self._plan_interval[li] = min(
                        self._plan_interval[li] * 2, self.cadence_max)
            return res.placement, res, planned
        if self.cfg.policy == "fastermoe":
            res = fastermoe_plan(self.perf, g, max_shadows=self.cfg.s_max)
            return res.placement, res, True
        if self.cfg.policy in ("top2", "top3"):
            k = int(self.cfg.policy[-1])
            return topk_policy(g, min(k, self.cfg.s_max)), None, True
        raise ValueError(f"unknown policy {self.cfg.policy}")

    def observe(self, per_layer_g: Sequence[Array], *, pool=None) -> None:
        """Feed routing matrices observed in the step that just finished;
        plans the placements to use next step.  ``pool`` (an optional
        ``ThreadPoolExecutor``) fans the per-layer searches out in
        parallel; results are merged in layer order either way, so the
        outcome is identical to the serial path.

        Ingestion guard: each layer's matrix must be exactly ``[D, E]``,
        finite, and non-negative (:func:`repro.core.guard.check_counts`)
        — the watchdog path sanitizes before calling here, so a trip
        means a caller fed garbage directly."""
        if len(per_layer_g) != self.cfg.num_moe_layers:
            raise guard.CountsError(
                f"observe got {len(per_layer_g)} layer matrices, engine "
                f"has {self.cfg.num_moe_layers} MoE layers")
        shape = (self.cfg.num_devices, self.cfg.num_experts)
        for li, g in enumerate(per_layer_g):
            guard.check_counts(g, shape, layer=li)
        self._last_g = [np.asarray(g, dtype=np.float64)
                        for g in per_layer_g]
        self._obs_count += 1
        if self.cfg.policy == "none":
            return
        from repro import flags
        dl_ms = flags.plan_deadline_ms()
        deadline = (time.perf_counter() + dl_ms / 1e3) if dl_ms > 0 else None
        if pool is not None:
            futures = [pool.submit(self._plan_layer, li, g, deadline)
                       for li, g in enumerate(per_layer_g)]
            # Drain every future before re-raising: rolling back while
            # sibling layers are still planning would race the restore.
            results, first_err = [], None
            for f in futures:
                try:
                    results.append(f.result())
                except Exception as e:  # noqa: BLE001 — re-raised below
                    if first_err is None:
                        first_err = e
            if first_err is not None:
                raise first_err
        else:
            results = [self._plan_layer(li, g, deadline)
                       for li, g in enumerate(per_layer_g)]
        changed = False
        planned = stable = evacuated = 0
        for li, (placement, res, ran) in enumerate(results):
            if res is not None:
                self.last_results[li] = res
            if ran:
                planned += 1
            if self.forecasters[li].phase == "stable":
                stable += 1
            if placement != self._placements[li]:
                self._placements[li] = placement
                self._dirty.add(li)
                changed = True
                if res is not None:
                    evacuated += int(getattr(res, "num_evacuated", 0))
        self.plans_executed += planned
        self.plans_skipped += len(results) - planned
        self.evacuations += evacuated
        self.last_plan_info = {"planned": planned,
                               "skipped": len(results) - planned,
                               "stable": stable,
                               "evacuated": evacuated}
        if changed:
            self._version += 1
        self._health_dirty = False

    @property
    def placements(self) -> List[ExpertPlacement]:
        return list(self._placements)

    # ------------------------------------------------------------------
    # Watchdog support: last-good rollback + fallback queries
    # ------------------------------------------------------------------
    def last_counts(self) -> List[Optional[Array]]:
        """Copies of the last-good per-layer routing matrices (None where
        no observation has landed yet) — the sanitizer's fallback source."""
        return [None if g is None else g.copy() for g in self._last_g]

    def snapshot(self) -> Dict[str, Any]:
        """Capture the full planning state so a failed/rejected plan can
        be rolled back exactly (:meth:`restore`).  Placements and routing
        matrices are immutable once stored (observe/replan replace, never
        mutate), so shallow references suffice; the mutable containers
        (_dirty, _device_slots, planner trackers) are copied."""
        return {
            "placements": list(self._placements),
            "last_results": list(self.last_results),
            "version": self._version,
            "dirty": set(self._dirty),
            "last_g": list(self._last_g),
            "obs_count": self._obs_count,
            "costs_cache": self._costs_cache,
            "device_slots": [ds.copy() for ds in self._device_slots],
            "planners": [p.snapshot() for p in self.planners],
            # Predictive planning: the phase detector and cadence backoff
            # advance inside observe, so a rejected plan must roll them
            # back with the placements — otherwise the backoff would keep
            # doubling past plans that never took effect.
            "forecasters": [f.snapshot() for f in self.forecasters],
            "plan_interval": list(self._plan_interval),
            "since_plan": list(self._since_plan),
            "plan_counters": (self.plans_executed, self.plans_skipped),
            "last_plan_info": dict(self.last_plan_info),
            # Degraded mode: tracker EMAs/states, the pending-replan
            # flag, and the perf model's raw factor vector all advance
            # with the plan they priced — a rejected plan rolls them
            # back together so retry re-prices identically.
            "health": self.health.snapshot(),
            "health_dirty": self._health_dirty,
            "perf_factors": self.perf.raw_factors(),
            "evacuations": self.evacuations,
        }

    def restore(self, snap: Dict[str, Any]) -> None:
        """Roll the planning state back to a :meth:`snapshot` — the
        watchdog's fall-back-to-last-good.  The packed array cache is kept
        (cache + restored dirty set were consistent at snapshot time and
        observe never touches the cache)."""
        self._placements = list(snap["placements"])
        self.last_results = list(snap["last_results"])
        self._version = snap["version"]
        self._dirty = set(snap["dirty"])
        self._last_g = list(snap["last_g"])
        self._obs_count = snap["obs_count"]
        self._costs_cache = snap["costs_cache"]
        self._device_slots = [ds.copy() for ds in snap["device_slots"]]
        for p, ps in zip(self.planners, snap["planners"]):
            p.restore(ps)
        for f, fs in zip(self.forecasters, snap["forecasters"]):
            f.restore(fs)
        self._plan_interval = list(snap["plan_interval"])
        self._since_plan = list(snap["since_plan"])
        self.plans_executed, self.plans_skipped = snap["plan_counters"]
        self.last_plan_info = dict(snap["last_plan_info"])
        self.health.restore(snap["health"])
        self._health_dirty = snap["health_dirty"]
        self.perf.set_device_factors(snap["perf_factors"])
        self.evacuations = snap["evacuations"]

    def cancel_migrations(self) -> int:
        """Drop every planned owner re-layout: rebuild each migrated
        placement at the identity slot order (shadows that would now sit
        on their own owner are pruned).  Used by the trainer after a
        failed relocation exchange — the device stays at (or returns to)
        the home layout, so the plans must stop demanding a move the
        exchange could not deliver.  The planner may re-propose the
        migration at its next replan, which retries the exchange.
        Returns the number of layers reset (version bumps once if > 0)."""
        E, D = self.cfg.num_experts, self.cfg.num_devices
        home = default_owner(E, D)
        reset = 0
        for li, pl in enumerate(self._placements):
            if pl.slot_of is None:
                continue
            shadows = {e: tuple(d for d in devs if d != int(home[e]))
                       for e, devs in pl.shadows.items()}
            shadows = {e: devs for e, devs in shadows.items() if devs}
            self._placements[li] = ExpertPlacement(E, D, shadows, None)
            self._dirty.add(li)
            reset += 1
        if reset:
            self._version += 1
        return reset

    # ------------------------------------------------------------------
    # Device health: elastic degraded mode
    # ------------------------------------------------------------------
    def observe_timings(self, times: Array) -> None:
        """Feed the per-device step-time vector measured for the last
        step (seconds; NaN = missed heartbeat).  Dispatch-thread mutator:
        call only in the planner-idle window between ``wait()`` and
        ``submit()`` — the same slot ``cancel_migrations`` uses.

        On a health-state transition the perf model is re-priced with the
        tracker's throughput factors and ``_health_dirty`` forces every
        layer to replan at its next observe, so evacuation/rebalancing
        lands within one plan cadence of detection.  No-op unless health
        tracking is enabled (``enable_health`` / ``REPRO_HEALTH``)."""
        if not self.health_enabled:
            return
        before = self.health.states()
        self.health.update(np.asarray(times, dtype=np.float64))
        after = self.health.states()
        if not self.health.all_healthy:
            # Degraded factors track the measured ratio continuously, so
            # re-price every update while any device is off nominal.
            self.perf.set_device_factors(self.health.factors())
        elif after != before:
            # Full recovery: clear the factors entirely so pricing
            # returns to the exact homogeneous fast path.
            self.perf.set_device_factors(None)
        if after != before:
            self._health_dirty = True

    def health_summary(self) -> str:
        """Compact fleet health string for logging: ``"healthy"`` or
        e.g. ``"degraded:1,3 lost:2"``."""
        return self.health.summary()

    def degraded_devices(self) -> List[int]:
        return self.health.degraded()

    def lost_devices(self) -> List[int]:
        return self.health.lost()

    def step_arrays(self) -> Dict[str, Array]:
        """Stacked static-shape placement arrays for the jitted step.

        Incremental: only layers whose placement changed since the last
        call are re-packed; the returned arrays are copies, safe to hand
        to ``jnp.asarray`` while the engine keeps replanning."""
        cfg = self.cfg
        if self._cache is None:
            self._cache = {
                "shadow_idx": np.zeros((cfg.num_moe_layers, cfg.s_max),
                                       dtype=np.int32),
                "shadow_valid": np.zeros((cfg.num_moe_layers, cfg.s_max),
                                         dtype=np.float32),
                "shadow_devs": np.zeros(
                    (cfg.num_moe_layers, cfg.s_max, cfg.num_devices),
                    dtype=np.float32),
                "expert_slot": np.tile(
                    np.arange(cfg.num_experts, dtype=np.int32),
                    (cfg.num_moe_layers, 1)),
            }
            self._dirty = set(range(cfg.num_moe_layers))
        for li in sorted(self._dirty):
            arrs = self._placements[li].to_device_arrays(cfg.s_max)
            self._cache["shadow_idx"][li] = arrs["shadow_idx"]
            self._cache["shadow_valid"][li] = arrs["shadow_valid"]
            self._cache["shadow_devs"][li] = arrs["shadow_devs"]
            self._cache["expert_slot"][li] = arrs["expert_slot"]
        self._dirty.clear()
        return {k: v.copy() for k, v in self._cache.items()}

    # ------------------------------------------------------------------
    # Dynamic expert migration: relocation schedule
    # ------------------------------------------------------------------
    def pending_relocation(self) -> Optional[Array]:
        """Slot gather realizing the planned owner re-layout, or None when
        the device already matches.  int32 ``[L, E]``:
        ``new_weights[li, s] = old_weights[li, gather[li, s]]`` applied to
        every expert-stacked param/optimizer leaf (the EP-axis exchange —
        cross-device entries gather from the peer's slot range).  Same
        threading contract as :meth:`step_arrays`: read only after the
        observe that produced it."""
        E, D = self.cfg.num_experts, self.cfg.num_devices
        gather = np.tile(np.arange(E, dtype=np.int32),
                         (self.cfg.num_moe_layers, 1))
        changed = False
        for li, pl in enumerate(self._placements):
            dev = self._device_slots[li]
            if np.array_equal(pl.slots, dev):
                continue
            dev_pl = ExpertPlacement(E, D, {}, tuple(int(s) for s in dev))
            gather[li] = pl.relocation_gather(dev_pl)
            changed = True
        return gather if changed else None

    def relocations(self) -> List[Tuple[int, int, int, int]]:
        """Pending owner moves vs the device layout, for logging:
        ``[(layer, expert, src_dev, dst_dev), ...]``."""
        from .placement import default_owner
        base = default_owner(self.cfg.num_experts, self.cfg.num_devices)
        out = []
        for li, pl in enumerate(self._placements):
            dev_owner = base[self._device_slots[li]]
            new_owner = pl.owner
            for e in np.where(dev_owner != new_owner)[0]:
                out.append((li, int(e), int(dev_owner[e]), int(new_owner[e])))
        return out

    def mark_relocated(self) -> None:
        """The trainer executed the pending exchange: the device layout
        now matches the planned placements."""
        self._device_slots = [pl.slots.copy() for pl in self._placements]

    def reset_layout(self) -> Optional[Array]:
        """Gather returning the device to the identity (home) layout, or
        None if already there; resets the tracked device slots.  Use
        before checkpointing: saved params must be in home order so a
        restored run can bind a fresh engine (which assumes identity)
        without inheriting the permuted physical layout."""
        E = self.cfg.num_experts
        if all(np.array_equal(ds, np.arange(E)) for ds in self._device_slots):
            return None
        # device slot ds[e] holds expert e ⇒ home order gathers ds itself.
        gather = np.stack([ds.astype(np.int32)
                           for ds in self._device_slots])
        self._device_slots = [np.arange(E, dtype=np.int64)
                              for _ in range(self.cfg.num_moe_layers)]
        return gather

    # ------------------------------------------------------------------
    # Chunked a2a↔FEC pipelining (§V realized on-device)
    # ------------------------------------------------------------------
    def _layer_costs(self, li: int
                     ) -> Optional[Tuple[float, float, float, float, float]]:
        """(t_a2a, t_fec, received_tokens, t_dispatch, t_combine) of
        layer ``li`` under its current placement and last observed
        routing stats, or None before any observe.  One
        ``compute_loads`` serves the chunk chooser and the telemetry —
        this runs on the dispatch path.  The permute legs price
        whichever path REPRO_DISPATCH_PALLAS selects on this process
        (the Pallas kernels by default on TPU, the jnp scatter/gather
        when forced off) on the profiled per-device token count."""
        from repro import flags
        g = self._last_g[li]
        if g is None:
            return None
        H, R = self._placements[li].compute_loads(g)
        n_loc = float(np.sum(g)) / max(self.cfg.num_devices, 1) \
            / max(self.cfg.top_k, 1)                  # tokens per device
        slots = self.cfg.top_k * self.cfg.capacity_factor * n_loc   # G·C
        pallas = flags.dispatch_pallas()
        t_disp = self.perf.t_dispatch(n_loc, slots, top_k=self.cfg.top_k,
                                      pallas=pallas)
        t_comb = self.perf.t_combine(n_loc, slots, top_k=self.cfg.top_k,
                                     pallas=pallas)
        return (self.perf.t_a2a(R), self.perf.t_fec(H), float(np.sum(R)),
                t_disp, t_comb)

    def _all_layer_costs(
            self) -> List[Optional[Tuple[float, float, float, float, float]]]:
        """Per-layer costs, memoized until the next observe/replan (the
        trainer calls chunk_plan and chunk_stats back to back on the
        dispatch path; one compute_loads per layer serves both)."""
        token = (self._version, self._obs_count)
        if self._costs_cache is None or self._costs_cache[0] != token:
            costs = [self._layer_costs(li)
                     for li in range(self.cfg.num_moe_layers)]
            self._costs_cache = (token, costs)
        return self._costs_cache[1]

    def chunk_plan(self) -> List[int]:
        """Per-layer a2a↔FEC chunk count K, chosen by the scheduler's
        analytical timeline (:func:`repro.core.scheduler.choose_chunks`)
        on each layer's profiled stats.  Layers with no stats yet get the
        bit-identical K=1 path.  ``REPRO_A2A_CHUNKS`` overrides."""
        from repro import flags
        override = flags.a2a_chunks()
        if override is not None:
            return [override] * self.cfg.num_moe_layers
        plan = []
        for costs in self._all_layer_costs():
            if costs is None:
                plan.append(1)
                continue
            t_a2a, t_fec, _, t_disp, t_comb = costs
            plan.append(scheduler.choose_chunks(
                t_a2a, t_fec, candidates=self.cfg.a2a_chunk_candidates,
                chunk_overhead=self.cfg.a2a_chunk_overhead,
                t_dispatch=t_disp, t_combine=t_comb))
        return plan

    def chunk_stats(self, plan: Optional[Sequence[int]] = None
                    ) -> Dict[str, float]:
        """Modeled chunked-overlap telemetry for the given per-layer plan
        (default: :meth:`chunk_plan`), summed over MoE layers:

        ``serial_s`` / ``chunked_s`` — K=1 vs chunked timeline makespan of
        the forward expert paths, both including the serial HBM-bound
        dispatch/combine permute legs (``PerfModel.t_dispatch`` /
        ``t_combine`` — they cancel in the hidden-comm numerator but
        keep the makespans honest); ``comm_hidden_frac`` — fraction of a2a
        wire time hidden under the ragged FEC (structural overlap of the
        timeline; the per-chunk launch overhead only steers the chooser);
        ``a2a_gbytes`` — modeled bytes all four a2as move per step (fwd
        send/return, ×2 for bwd).
        """
        if plan is None:
            plan = self.chunk_plan()
        serial = chunked = a2a_time = 0.0
        gbytes = 0.0
        for k, costs in zip(plan, self._all_layer_costs()):
            if costs is None:
                continue
            t_a2a, t_fec, recv_tokens, t_disp, t_comb = costs
            serial += scheduler.chunked_makespan_closed(
                t_a2a, t_fec, 1, t_dispatch=t_disp, t_combine=t_comb)
            chunked += scheduler.chunked_makespan_closed(
                t_a2a, t_fec, k, t_dispatch=t_disp, t_combine=t_comb)
            a2a_time += 2.0 * t_a2a
            gbytes += 4.0 * recv_tokens * self.perf.hw.input_bytes / 1e9
        frac = max(0.0, min(1.0, (serial - chunked) / a2a_time)) \
            if a2a_time > 0 else 0.0
        return {"serial_s": serial, "chunked_s": chunked,
                "comm_hidden_frac": frac, "a2a_gbytes": gbytes,
                "mean_chunks": float(np.mean(plan)) if len(plan) else 1.0}

    def predicted_times(self) -> Dict[str, float]:
        ts = [r.predicted_time for r in self.last_results if r is not None]
        bs = [r.baseline_time for r in self.last_results if r is not None]
        if not ts:
            return {"predicted": 0.0, "baseline": 0.0, "speedup": 1.0}
        return {"predicted": float(np.sum(ts)), "baseline": float(np.sum(bs)),
                "speedup": float(np.sum(bs) / max(np.sum(ts), 1e-12))}
