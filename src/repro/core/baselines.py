"""Baseline load-balancing policies the paper compares against (§VI).

* ``vanilla_ep``      — DeepSpeed-MoE-style plain expert parallelism.
* ``fastermoe_plan``  — FasterMoE's *dynamic shadowing*: greedily replicate
  the globally heaviest experts onto **all** devices while its cost model
  predicts an improvement.  Coarse-grained (whole-device-set) and executed
  blocked (no overlap), per the paper's characterization.
* ``topk_policy``     — the ablation's static policies (top2/top3): always
  replicate the k heaviest experts to all devices (§VI.C, Fig. 15).
"""
from __future__ import annotations

import numpy as np

from .perfmodel import PerfModel
from .placement import ExpertPlacement, shadow_to_all, traditional
from .planner import PlanResult

Array = np.ndarray


def vanilla_ep(num_experts: int, num_devices: int) -> ExpertPlacement:
    return traditional(num_experts, num_devices)


def topk_policy(g: Array, k: int) -> ExpertPlacement:
    """Replicate the k heaviest experts onto all devices."""
    g = np.asarray(g, dtype=np.float64)
    D, E = g.shape
    heavy = np.argsort(-g.sum(axis=0), kind="stable")[:k]
    return shadow_to_all(E, D, heavy)


def fastermoe_plan(perf: PerfModel, g: Array, *, max_shadows: int | None = None
                   ) -> PlanResult:
    """FasterMoE-style shadowing: replicate the heaviest expert to all
    devices while the performance model predicts a win.

    Unlike Pro-Prophet, the target set is always *all* devices (n = 0) and
    the evaluation never accounts for overlap (blocked execution)."""
    g = np.asarray(g, dtype=np.float64)
    D, E = g.shape
    max_shadows = E if max_shadows is None else max_shadows

    placement = traditional(E, D)
    H, R = placement.compute_loads(g)
    t_best = perf.layer_time(R, H, 0, 0)
    baseline = t_best
    tokens = g.sum(axis=0)
    order = list(np.argsort(-tokens, kind="stable"))
    steps = 0
    while order and placement.num_shadowed < max_shadows:
        e = int(order.pop(0))
        cand = placement.with_shadow(
            e, frozenset(range(D)) - {int(placement.owner[e])})
        Hc, Rc = cand.compute_loads(g)
        t = perf.layer_time(Rc, Hc, cand.num_shadowed, 0)
        steps += 1
        if t < t_best:
            t_best, placement, (H, R) = t, cand, (Hc, Rc)
        else:
            break
    total = float(g.sum())
    return PlanResult(placement=placement, predicted_time=t_best,
                      baseline_time=baseline, steps_examined=steps,
                      balanced=bool((H.max() - H.min()) < total / E))
