"""The planner's performance model (paper §IV.B, eqs. 1–6; §V.C, eq. 8).

Estimates the execution time of one MoE layer under a lightweight expert
placement.  All terms are straggler-bound maxima, matching the paper's
P2P-based a2a (eq. 1) and sequential per-device expert compute (eq. 2/3).

Two ``Trans``/``Agg`` cost variants are provided:

* ``"p2p"`` — the paper's eq. 4/5 (GPU point-to-multipoint):
  ``T = s·(D−n)·size / (D·B̄)``.
* ``"ring"`` — the TPU adaptation (DESIGN.md §3): shadow slots are
  materialized by a ring collective over the EP axis, so the wire time does
  not shrink with n: ``T = s·(D−1)·size / (D·B̄)``.  n still matters for
  *compute* balance via the placement's compute mask.

The scheduler coupling (eq. 8) replaces Trans/Agg by their unhidden
residuals: ``T_PTrans = max(0, T_Trans − T_FEC − T_FNEC)`` and
``T_PAgg = max(0, T_Agg − T_BEC − T_BNEC)``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Literal, Optional, Tuple

import numpy as np

from .health import FACTOR_FLOOR

Array = np.ndarray
TransMode = Literal["p2p", "ring"]

# TPU v5e constants (per chip), used for roofline + TPU-mode predictions.
V5E_PEAK_FLOPS = 197e12          # bf16 FLOP/s
V5E_HBM_BW = 819e9               # bytes/s
V5E_ICI_BW = 50e9                # bytes/s per link (≈per-device ring bw)


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Cluster constants feeding the performance model.

    bandwidth:   B̄, average per-device communication bandwidth [bytes/s]
    throughput:  t, per-device expert compute throughput [tokens/s]
    input_bytes: size(input) — one token's activation payload [bytes]
    expert_param_bytes: size(e.params) == size(e.grads) [bytes]
    t_fnec / t_bnec: measured fwd/bwd time of the *non*-MoE layer [s]
                     (static per model; used by eq. 8 and the sub-op split)
    hbm_bandwidth: per-device HBM bandwidth [bytes/s] — prices the
                   HBM-bound token-permutation legs (t_dispatch /
                   t_combine), which move memory, not wire bytes
    device_throughput: optional per-device throughput vector [tokens/s]
                   for heterogeneous clusters — entry d is device d's
                   expert compute throughput.  ``None`` (the default)
                   keeps the scalar homogeneous model bit-identical.
    """

    bandwidth: float
    throughput: float
    input_bytes: float
    expert_param_bytes: float
    t_fnec: float = 0.0
    t_bnec: float = 0.0
    hbm_bandwidth: float = V5E_HBM_BW
    device_throughput: Optional[Tuple[float, ...]] = None

    @staticmethod
    def from_model_dims(d_model: int, d_ff: int, *,
                        bandwidth: float, flops_per_s: float,
                        bytes_per_elem: int = 2,
                        t_fnec: float = 0.0, t_bnec: float = 0.0,
                        num_ffn_mats: int = 2) -> "HardwareSpec":
        """Derive token/expert sizes from layer dimensions.

        An expert FFN with ``num_ffn_mats`` matrices (2 for GeLU-MLP as in
        the paper's MoE-GPT, 3 for SwiGLU) has ``num_ffn_mats·d_model·d_ff``
        parameters and ``2·params`` FLOPs per token.
        """
        params = num_ffn_mats * d_model * d_ff
        flops_per_token = 2 * params
        return HardwareSpec(
            bandwidth=bandwidth,
            throughput=flops_per_s / flops_per_token,
            input_bytes=d_model * bytes_per_elem,
            expert_param_bytes=params * bytes_per_elem,
            t_fnec=t_fnec,
            t_bnec=t_bnec,
        )


class PerfModel:
    """Closed-form layer-time estimator (paper eqs. 1–6, 8).

    The homogeneous model is the paper's; two extensions make it
    heterogeneity-aware (ISSUE 10): ``HardwareSpec.device_throughput``
    prices each device's expert compute at its own speed, and
    :meth:`set_device_factors` overlays the health tracker's relative
    throughput multipliers (degraded devices run slower, ``lost`` ones
    are clamped to :data:`repro.core.health.FACTOR_FLOOR` so modeled
    times stay finite while :meth:`lost_devices` reports them for the
    planner's evacuation pass).  With neither in effect every term takes
    the original scalar path, so homogeneous plans stay bit-identical.
    """

    def __init__(self, hw: HardwareSpec, num_devices: int,
                 trans_mode: TransMode = "p2p"):
        self.hw = hw
        self.D = int(num_devices)
        self.trans_mode = trans_mode
        dt = hw.device_throughput
        if dt is not None:
            dt = np.asarray(dt, dtype=np.float64)
            assert dt.shape == (self.D,), (dt.shape, self.D)
            assert (dt > 0).all(), "device_throughput must be positive"
        self._base_speeds: Optional[Array] = dt
        self._factors: Optional[Array] = None      # clamped multipliers
        self._raw_factors: Optional[Array] = None  # as given (0 = lost)

    # -- device health / heterogeneity ------------------------------------
    def set_device_factors(self, factors: Optional[Array]) -> None:
        """Overlay per-device health multipliers in [0, 1] (``None``
        clears).  Factor 0 marks a *lost* device: its modeled speed is
        clamped to ``FACTOR_FLOOR`` (times must stay finite for the
        watchdog's invariant sweep) and it is reported by
        :meth:`lost_devices` so the planner zeroes its capacity."""
        if factors is None:
            self._factors = self._raw_factors = None
            return
        f = np.asarray(factors, dtype=np.float64)
        assert f.shape == (self.D,), (f.shape, self.D)
        self._raw_factors = f.copy()
        if (f >= 1.0).all():
            self._factors = None  # all healthy: exact homogeneous path
        else:
            self._factors = np.clip(f, FACTOR_FLOOR, 1.0)

    def raw_factors(self) -> Optional[Array]:
        """Copy of the unclipped health-factor vector as last set (None
        when homogeneous) — snapshot/restore currency: feeding it back
        through :meth:`set_device_factors` reproduces pricing exactly."""
        return None if self._raw_factors is None else self._raw_factors.copy()

    def lost_devices(self) -> List[int]:
        """Devices whose health factor is 0 (evacuation targets)."""
        if self._raw_factors is None:
            return []
        return [int(d) for d in np.where(self._raw_factors <= 0.0)[0]]

    @property
    def heterogeneous(self) -> bool:
        """True when per-device speeds differ (hardware vector or health
        factors) — the planner switches to weighted load balancing."""
        return self._base_speeds is not None or self._factors is not None

    def device_speeds(self) -> Array:
        """Effective per-device expert throughput ``[D]`` [tokens/s]."""
        base = (self._base_speeds if self._base_speeds is not None
                else np.full(self.D, self.hw.throughput))
        return base if self._factors is None else base * self._factors

    # -- eq. 1 ------------------------------------------------------------
    def t_a2a(self, R: Array) -> float:
        R = np.asarray(R, dtype=np.float64)
        if self._factors is None:
            return float(R.max()) * self.hw.input_bytes / self.hw.bandwidth
        # A degraded device also drains its a2a ingress slower: price
        # device d's receive leg at factor-scaled bandwidth.
        per = R * self.hw.input_bytes / (self.hw.bandwidth * self._factors)
        return float(per.max())

    # -- eq. 2 ------------------------------------------------------------
    def t_fec(self, H: Array) -> float:
        H = np.asarray(H, dtype=np.float64)
        if not self.heterogeneous:
            return float(H.max()) / self.hw.throughput
        # Straggler-bound over per-device speeds.  Division is monotone
        # and correctly rounded, so under uniform speeds this equals the
        # scalar path bit-for-bit.
        return float((H / self.device_speeds()).max())

    # -- eq. 3 ------------------------------------------------------------
    def t_bec(self, H: Array) -> float:
        return 2.0 * self.t_fec(H)

    # -- ragged vs dense FEC (beyond-paper; repro.kernels.ragged_gmm) -----
    # eq. 2 implicitly assumes the expert kernel's cost follows the actual
    # per-device load H — true for the ragged kernel, false for a dense
    # kernel over the [E, C, d] capacity buffer, which always computes
    # every slot.  The dense term makes that waste explicit so placements
    # can be scored against what the hardware would really run.
    def t_fec_dense(self, capacity_slots: float) -> float:
        """FEC of a dense (capacity-padded) kernel: ``capacity_slots`` =
        experts-per-device × per-expert capacity, load-independent."""
        return float(capacity_slots) / self.hw.throughput

    def fec_utilization(self, H: Array, capacity_slots: float) -> float:
        """Useful fraction of dense-kernel FLOPs — the straggler device's
        actual load over the capacity slots it computes.  The ragged
        kernel's win factor is 1 / utilization."""
        dense = self.t_fec_dense(capacity_slots)
        return self.t_fec(H) / dense if dense > 0 else 1.0

    # -- token permutation (beyond-paper; repro.kernels.token_permute) ----
    # The two data-dependent permutes around the expert FFN are
    # HBM-bound, not wire-bound: dispatch streams the local token panel
    # into the [G, C, d] capacity buffer and combine streams it back out
    # through the gate reduction.  The closed forms below are the
    # kernels' modeled-bytes table (token_permute.dispatch_modeled_bytes
    # / combine_modeled_bytes) over hbm_bandwidth — the agreement is
    # pinned to < 1e-12 in benchmarks/perfmodel_accuracy.py.  The jnp
    # variants price what the pre-kernel path really moved: the k×
    # activation repeat + scatter read-modify-write on dispatch, and the
    # [N, k, d] gather plus its f32 copy (the ``8·d·N·k``-byte term —
    # expressed via input_bytes and ``itemsize``) on combine.
    def t_dispatch(self, n_tokens: float, capacity_slots: float, *,
                   top_k: int = 1, pallas: bool = True) -> float:
        """HBM time of one capacity dispatch of ``n_tokens`` local rows
        into ``capacity_slots`` (= G·C) slots."""
        if pallas:
            units = n_tokens + capacity_slots
        else:
            units = n_tokens + 2.0 * n_tokens * top_k + 3.0 * capacity_slots
        return units * self.hw.input_bytes / self.hw.hbm_bandwidth

    def t_combine(self, n_tokens: float, capacity_slots: float, *,
                  top_k: int = 1, pallas: bool = True,
                  itemsize: int = 2) -> float:
        """HBM time of one gate-weighted combine back to ``n_tokens``
        rows.  ``itemsize`` sizes the jnp path's f32 blow-up relative to
        ``input_bytes`` (= d·itemsize); the Pallas path never upcasts."""
        if pallas:
            b = (capacity_slots + n_tokens) * self.hw.input_bytes
        else:
            b = ((2.0 * n_tokens * top_k + n_tokens) * self.hw.input_bytes
                 + 2.0 * n_tokens * top_k * 4.0
                 * (self.hw.input_bytes / itemsize))
        return b / self.hw.hbm_bandwidth

    # -- eqs. 4/5 ---------------------------------------------------------
    def _t_transfer(self, s: int, n: int, size: float) -> float:
        if s <= 0:
            return 0.0
        if self.trans_mode == "p2p":
            span = self.D - n
        else:  # ring collective: wire time independent of the subset size
            span = self.D - 1
        span = max(span, 0)
        return s * span * size / (self.D * self.hw.bandwidth)

    def t_trans(self, s: int, n: int) -> float:
        return self._t_transfer(s, n, self.hw.expert_param_bytes)

    def t_agg(self, s: int, n: int) -> float:
        return self._t_transfer(s, n, self.hw.expert_param_bytes)

    # -- migration (beyond-paper: FlexMoE/LAER-MoE-style owner re-layout) --
    def t_exchange(self, m: int, *, state_factor: float = 3.0) -> float:
        """One-time (unamortized) cost of ``m`` expert migrations: each
        swaps one expert's home slot with a partner slot on the
        destination device, a bidirectional p2p exchange of the two
        experts' parameter + optimizer slabs (``state_factor`` ≈ 3 for
        AdamW: params + mu + nu).  This is the wall-clock a synchronous
        relocation blocks the dispatch for — and what the prefetched
        relocation hides under the previous step — as well as the cost
        the planner's hysteresis gate weighs a modeled win against."""
        if m <= 0:
            return 0.0
        return (m * 2.0 * state_factor * self.hw.expert_param_bytes
                / self.hw.bandwidth)

    def t_migrate(self, m: int, *, window: float,
                  state_factor: float = 3.0) -> float:
        """Amortized per-step cost of ``m`` expert migrations: the
        :meth:`t_exchange` one-time move spread over the ``window`` steps
        the locality property (§IV.B) keeps the placement valid.
        Contrast with :meth:`t_trans`, which shadowing pays EVERY step —
        migration dominates exactly when the skew is stable (window ≫ 1)
        and loses when it is transient (window → 1)."""
        if m <= 0:
            return 0.0
        return (self.t_exchange(m, state_factor=state_factor)
                / max(float(window), 1.0))

    # -- eq. 6: unscheduled layer time -------------------------------------
    def layer_time(self, R: Array, H: Array, s: int, n: int) -> float:
        return (4.0 * self.t_a2a(R)
                + 3.0 * self.t_fec(H)
                + self.t_trans(s, n)
                + self.t_agg(s, n))

    # -- eq. 8: with the scheduler's overlap ------------------------------
    def layer_time_scheduled(self, R: Array, H: Array, s: int, n: int) -> float:
        t_fec = self.t_fec(H)
        t_bec = self.t_bec(H)
        p_trans = max(0.0, self.t_trans(s, n) - t_fec - self.hw.t_fnec)
        p_agg = max(0.0, self.t_agg(s, n) - t_bec - self.hw.t_bnec)
        return 4.0 * self.t_a2a(R) + 3.0 * t_fec + p_trans + p_agg

    # -- chunked a2a↔FEC overlap (§V realized on-device; repro.models.moe)
    @staticmethod
    def chunked_path_time(t_a2a: float, t_comp: float, num_chunks: int, *,
                          chunk_overhead: float = 0.0,
                          t_dispatch: float = 0.0,
                          t_combine: float = 0.0) -> float:
        """Makespan of one K-chunk a2a→compute→a2a software pipeline:
        the closed form of the scheduler's sends-first list schedule
        (:func:`repro.core.scheduler.chunked_makespan_closed`; asserted
        equal to the graph timeline in ``benchmarks/perfmodel_accuracy``).
        K=1 degenerates to the serial chain ``2·t_a2a + t_comp``.
        ``t_dispatch``/``t_combine`` (the HBM-bound permute legs) front
        and tail the pipeline serially — see the scheduler docstring."""
        from . import scheduler
        return scheduler.chunked_makespan_closed(
            t_a2a, t_comp, num_chunks, chunk_overhead=chunk_overhead,
            t_dispatch=t_dispatch, t_combine=t_combine)

    def chunked_expert_time(self, R: Array, H: Array, num_chunks: int, *,
                            chunk_overhead: float = 0.0,
                            t_dispatch: float = 0.0,
                            t_combine: float = 0.0) -> float:
        """Forward expert path (dispatch → a2a → ragged FEC → a2a →
        combine) under K chunks."""
        return self.chunked_path_time(self.t_a2a(R), self.t_fec(H),
                                      num_chunks,
                                      chunk_overhead=chunk_overhead,
                                      t_dispatch=t_dispatch,
                                      t_combine=t_combine)

    def layer_time_chunked(self, R: Array, H: Array, s: int, n: int,
                           num_chunks: int, *,
                           chunk_overhead: float = 0.0,
                           t_dispatch: float = 0.0,
                           t_combine: float = 0.0) -> float:
        """eq. 8 with both expert paths replaced by their chunked-pipeline
        makespans (the backward pipeline computes BEC = 2·FEC per chunk
        and pays the transposed permute legs).
        ``num_chunks == 1`` (with zero permute terms) reproduces
        :meth:`layer_time_scheduled` exactly — the device path's
        bit-identity has a model analog."""
        t_a2a = self.t_a2a(R)
        t_fec = self.t_fec(H)
        fwd = self.chunked_path_time(t_a2a, t_fec, num_chunks,
                                     chunk_overhead=chunk_overhead,
                                     t_dispatch=t_dispatch,
                                     t_combine=t_combine)
        bwd = self.chunked_path_time(t_a2a, self.t_bec(H), num_chunks,
                                     chunk_overhead=chunk_overhead,
                                     t_dispatch=t_combine,
                                     t_combine=t_dispatch)
        p_trans = max(0.0, self.t_trans(s, n) - t_fec - self.hw.t_fnec)
        p_agg = max(0.0, self.t_agg(s, n) - self.t_bec(H) - self.hw.t_bnec)
        return fwd + bwd + p_trans + p_agg

    # -- convenience -------------------------------------------------------
    def effective_n(self, placement) -> int:
        """The paper's n (devices NOT transferred to) implied by a
        placement with possibly non-uniform shadow sets: the paper's n is
        uniform, so take the mean shadow-set size, rounded."""
        sizes = [len(d) for d in placement.shadows.values() if d]
        return int(round(self.D - 1 - float(np.mean(sizes)))) if sizes else 0

    def layer_time_for(self, placement, g: Array, *, scheduled: bool = False,
                       n: int | None = None) -> float:
        """Evaluate a placement on routing matrix ``G`` directly."""
        H, R = placement.compute_loads(g)
        s = placement.num_shadowed
        if n is None:
            n = self.effective_n(placement)
        fn = self.layer_time_scheduled if scheduled else self.layer_time
        return fn(R, H, s, n)

    def breakdown(self, placement, g: Array, *, scheduled: bool = False) -> dict:
        """Term-by-term dict — feeds the Table-I style benchmark."""
        H, R = placement.compute_loads(g)
        s = placement.num_shadowed
        n = self.effective_n(placement)
        t_a2a = self.t_a2a(R)
        t_fec = self.t_fec(H)
        t_trans = self.t_trans(s, n)
        t_agg = self.t_agg(s, n)
        if scheduled:
            t_trans = max(0.0, t_trans - t_fec - self.hw.t_fnec)
            t_agg = max(0.0, t_agg - 2 * t_fec - self.hw.t_bnec)
        return {
            "a2a": 4 * t_a2a, "fec": t_fec, "bec": 2 * t_fec,
            "trans": t_trans, "agg": t_agg,
            "total": 4 * t_a2a + 3 * t_fec + t_trans + t_agg,
        }
