"""Pro-Prophet core: the paper's contribution as a composable library.

Planner (§IV): lightweight expert placements, performance model, greedy
locality-based search.  Scheduler (§V): scheduling space + block-wise
sub-operator overlap.  Engine: per-iteration orchestration for the trainer.
"""
from .distribution import (LocalityTracker, ModelLocalityTracker,
                           balance_degree, distribution_similarity,
                           imbalance_ratio, rb_ratio,
                           routing_matrix_from_assignments)
from .engine import EngineConfig, ProProphetEngine
from .forecast import PHASES, LoadForecaster
from .health import HEALTH_STATES, DeviceHealthTracker
from .perfmodel import (V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS, HardwareSpec,
                        PerfModel)
from .placement import ExpertPlacement, default_owner, shadow_to_all, traditional
from .planner import GreedyPlanner, LocalityPlanner, PlanResult
from .scheduler import (BlockCosts, Timeline, build_graph, choose_chunks,
                        chunked_expert_graph, chunked_makespan,
                        hidden_comm_fraction, iteration_time, list_schedule,
                        simulate, split_trans)
from .synthetic import GatingTrace
from . import baselines

__all__ = [
    "LocalityTracker", "ModelLocalityTracker", "balance_degree",
    "distribution_similarity", "imbalance_ratio", "rb_ratio",
    "routing_matrix_from_assignments", "EngineConfig", "ProProphetEngine",
    "LoadForecaster", "PHASES", "DeviceHealthTracker", "HEALTH_STATES",
    "HardwareSpec", "PerfModel", "V5E_PEAK_FLOPS", "V5E_HBM_BW", "V5E_ICI_BW",
    "ExpertPlacement", "default_owner", "shadow_to_all", "traditional",
    "GreedyPlanner", "LocalityPlanner", "PlanResult", "BlockCosts",
    "Timeline", "build_graph", "choose_chunks", "chunked_expert_graph",
    "chunked_makespan", "hidden_comm_fraction", "iteration_time",
    "list_schedule", "simulate", "split_trans", "GatingTrace", "baselines",
]
