"""Lightweight expert placements (paper §IV.A).

A *lightweight expert placement* independently maps each (selected) expert
to a **subset** of devices.  Only parameters (``Trans``) and gradients
(``Agg``) travel, and only within the subset — optimizer states stay on the
owner device.  This module is the host-side representation; the traced /
device-side form (static shadow slots) is produced by
:meth:`ExpertPlacement.to_device_arrays`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Mapping, Tuple

import numpy as np

Array = np.ndarray


def default_owner(num_experts: int, num_devices: int) -> Array:
    """Contiguous expert→owner-device map (EP home layout).

    Experts are divided evenly; expert ``e`` lives on device
    ``e // (E / D)`` when ``E >= D`` and ``e % D`` when ``E < D``
    (the latter only matters for toy configs).
    """
    if num_experts >= num_devices:
        assert num_experts % num_devices == 0, (num_experts, num_devices)
        per = num_experts // num_devices
        return np.arange(num_experts) // per
    return np.arange(num_experts) % num_devices


@dataclasses.dataclass(frozen=True)
class ExpertPlacement:
    """Ownership layout + shadow sets for one MoE layer.

    ``shadows`` maps an expert id to the frozen set of *extra* devices that
    temporarily hold its parameters this iteration (never includes the
    owner).  The empty mapping is the traditional EP placement.
    """

    num_experts: int
    num_devices: int
    shadows: Mapping[int, FrozenSet[int]] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        owner = default_owner(self.num_experts, self.num_devices)
        for e, devs in self.shadows.items():
            assert 0 <= e < self.num_experts, e
            assert int(owner[e]) not in devs, (
                f"shadow set of expert {e} contains its owner {owner[e]}")
            assert all(0 <= d < self.num_devices for d in devs)

    # -- basic queries --------------------------------------------------
    @property
    def owner(self) -> Array:
        return default_owner(self.num_experts, self.num_devices)

    @property
    def num_shadowed(self) -> int:
        """s in the paper: number of experts whose params are transferred."""
        return sum(1 for devs in self.shadows.values() if devs)

    def placement_matrix(self) -> Array:
        """Boolean ``P[e, d]``: does device d hold expert e's params."""
        p = np.zeros((self.num_experts, self.num_devices), dtype=bool)
        p[np.arange(self.num_experts), self.owner] = True
        for e, devs in self.shadows.items():
            for d in devs:
                p[e, d] = True
        return p

    def with_shadow(self, expert: int, devices: FrozenSet[int]) -> "ExpertPlacement":
        owner = int(self.owner[expert])
        devices = frozenset(int(d) for d in devices) - {owner}
        new = dict(self.shadows)
        new[expert] = frozenset(new.get(expert, frozenset())) | devices
        return ExpertPlacement(self.num_experts, self.num_devices, new)

    # -- load computation (Replace_Inputs in Algorithm 1) ----------------
    def compute_loads(self, g: Array) -> Tuple[Array, Array]:
        """Given routing matrix ``G[d, e]``, return ``(H, R)``.

        ``H[i]``: tokens *computed* on device i.  ``R[i]``: tokens
        *received* by device i from other devices (the paper's a2a term).
        A token on source device d routed to expert e is computed locally
        iff d holds e's params under this placement; otherwise it is sent
        to e's owner.  (When an expert is shadowed, tokens on non-holder
        devices still go to the owner — the shadow only absorbs the load
        already resident on the shadow devices, paper Fig. 6b.)
        """
        g = np.asarray(g, dtype=np.float64)
        D, E = self.num_devices, self.num_experts
        assert g.shape == (D, E), (g.shape, (D, E))
        p = self.placement_matrix()  # [E, D]
        holds = p.T  # [D, E] — device d holds expert e
        local = g * holds  # tokens computed where they live
        remote = g * (~holds)  # tokens shipped to the owner
        H = local.sum(axis=1)
        H += np.bincount(self.owner, weights=remote.sum(axis=0), minlength=D)
        R = np.bincount(self.owner, weights=remote.sum(axis=0), minlength=D)
        return H, R

    # -- device-side (traced) form ---------------------------------------
    def to_device_arrays(self, s_max: int) -> Dict[str, Array]:
        """Static-shape form for the jitted step.

        Returns:
          ``shadow_idx``  int32 ``[s_max]``  — expert id per slot (0-padded),
          ``shadow_valid`` f32  ``[s_max]``  — 1.0 where the slot is live,
          ``shadow_devs`` f32  ``[s_max, D]`` — compute mask (owner excluded;
          the owner computes its tokens through the home path).
        """
        D = self.num_devices
        # Padding slots carry the sentinel expert id == num_experts so the
        # device-side lookup tables can never alias a real expert.
        idx = np.full((s_max,), self.num_experts, dtype=np.int32)
        valid = np.zeros((s_max,), dtype=np.float32)
        devs = np.zeros((s_max, D), dtype=np.float32)
        live = [(e, ds) for e, ds in sorted(self.shadows.items()) if ds]
        if len(live) > s_max:
            # Keep the largest shadow sets; the rest fall back to the a2a
            # path.  The planner respects s_max so this is a safety net.
            live.sort(key=lambda kv: -len(kv[1]))
            live = live[:s_max]
            live.sort()
        for slot, (e, ds) in enumerate(live):
            idx[slot] = e
            valid[slot] = 1.0
            for d in ds:
                devs[slot, d] = 1.0
        return {"shadow_idx": idx, "shadow_valid": valid, "shadow_devs": devs}


def traditional(num_experts: int, num_devices: int) -> ExpertPlacement:
    """Plain EP placement (DeepSpeed-MoE baseline)."""
    return ExpertPlacement(num_experts, num_devices, {})


def shadow_to_all(num_experts: int, num_devices: int, experts) -> ExpertPlacement:
    """FasterMoE-style: replicate the given experts onto *all* devices."""
    pl = traditional(num_experts, num_devices)
    all_devs = frozenset(range(num_devices))
    for e in experts:
        pl = pl.with_shadow(int(e), all_devs)
    return pl
