"""Lightweight expert placements (paper §IV.A) + dynamic owner re-layout.

A *lightweight expert placement* independently maps each (selected) expert
to a **subset** of devices.  Only parameters (``Trans``) and gradients
(``Agg``) travel, and only within the subset — optimizer states stay on the
owner device.  This module is the host-side representation; the traced /
device-side form (static shadow slots) is produced by
:meth:`ExpertPlacement.to_device_arrays`.

Beyond the paper's shadowing, a placement may also *migrate* experts:
``slot_of`` is a permutation of the ``E`` physical expert slots (slot
``s`` lives on device ``default_owner[s]``, so each device always holds
exactly its static share of slots).  :meth:`with_migration` swaps a hot
expert's slot with a partner slot on the destination device — a one-time
weight/optimizer move (FlexMoE / LAER-MoE style owner re-layout) instead
of a per-step parameter transfer.  :meth:`relocation_gather` emits the
slot gather that turns the previous physical layout into this one.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Mapping, Optional, Tuple

import numpy as np

Array = np.ndarray


def default_owner(num_experts: int, num_devices: int) -> Array:
    """Contiguous expert→owner-device map (EP home layout).

    Experts are divided evenly; expert ``e`` lives on device
    ``e // (E / D)`` when ``E >= D`` and ``e % D`` when ``E < D``
    (the latter only matters for toy configs).  With a slot permutation
    this same map gives the device of each *slot* — the physical layout
    never changes, only which expert occupies which slot.
    """
    if num_experts >= num_devices:
        assert num_experts % num_devices == 0, (num_experts, num_devices)
        per = num_experts // num_devices
        return np.arange(num_experts) // per
    return np.arange(num_experts) % num_devices


@dataclasses.dataclass(frozen=True)
class ExpertPlacement:
    """Ownership layout + shadow sets for one MoE layer.

    ``shadows`` maps an expert id to the frozen set of *extra* devices that
    temporarily hold its parameters this iteration (never includes the
    owner).  The empty mapping is the traditional EP placement.

    ``slot_of`` (expert → physical slot) is the owner re-layout
    permutation; ``None`` means identity (expert ``e`` in slot ``e``).  An
    identity tuple is normalized to ``None`` so migration-free placements
    compare equal regardless of how they were built.
    """

    num_experts: int
    num_devices: int
    shadows: Mapping[int, FrozenSet[int]] = dataclasses.field(default_factory=dict)
    slot_of: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.slot_of is not None:
            slots = tuple(int(s) for s in self.slot_of)
            assert len(slots) == self.num_experts, (
                f"slot_of has {len(slots)} entries for "
                f"{self.num_experts} experts")
            assert sorted(slots) == list(range(self.num_experts)), (
                "slot_of is not a permutation")
            if slots == tuple(range(self.num_experts)):
                slots = None
            object.__setattr__(self, "slot_of", slots)
        owner = self.owner
        for e, devs in self.shadows.items():
            assert 0 <= e < self.num_experts, e
            assert int(owner[e]) not in devs, (
                f"shadow set of expert {e} contains its owner {owner[e]}")
            assert all(0 <= d < self.num_devices for d in devs)

    # -- basic queries --------------------------------------------------
    @property
    def slots(self) -> Array:
        """expert → physical slot (identity when no migrations)."""
        if self.slot_of is None:
            return np.arange(self.num_experts)
        return np.asarray(self.slot_of, dtype=np.int64)

    @property
    def slot_expert(self) -> Array:
        """physical slot → expert (inverse of :attr:`slots`)."""
        inv = np.empty(self.num_experts, dtype=np.int64)
        inv[self.slots] = np.arange(self.num_experts)
        return inv

    @property
    def owner(self) -> Array:
        """expert → owner device, honoring the slot permutation."""
        return default_owner(self.num_experts, self.num_devices)[self.slots]

    @property
    def num_shadowed(self) -> int:
        """s in the paper: number of experts whose params are transferred."""
        return sum(1 for devs in self.shadows.values() if devs)

    @property
    def num_migrated(self) -> int:
        """Experts living away from their default home (owner re-layout)."""
        if self.slot_of is None:
            return 0
        base = default_owner(self.num_experts, self.num_devices)
        return int(np.sum(self.owner != base))

    def placement_matrix(self) -> Array:
        """Boolean ``P[e, d]``: does device d hold expert e's params."""
        p = np.zeros((self.num_experts, self.num_devices), dtype=bool)
        p[np.arange(self.num_experts), self.owner] = True
        for e, devs in self.shadows.items():
            for d in devs:
                p[e, d] = True
        return p

    def with_shadow(self, expert: int, devices: FrozenSet[int]) -> "ExpertPlacement":
        owner = int(self.owner[expert])
        devices = frozenset(int(d) for d in devices) - {owner}
        new = dict(self.shadows)
        new[expert] = frozenset(new.get(expert, frozenset())) | devices
        return ExpertPlacement(self.num_experts, self.num_devices, new,
                               self.slot_of)

    def with_migration(self, expert: int, dst: int,
                       partner: Optional[int] = None) -> "ExpertPlacement":
        """Move ``expert``'s home to device ``dst`` by swapping slots with
        ``partner`` (an expert currently owned by ``dst``; defaults to the
        lowest-numbered one).  The swap keeps every device's slot count
        static, so the traced step's shapes never change — only a one-time
        weight/optimizer exchange between the two devices is needed
        (:meth:`relocation_gather`).  Shadow sets are pruned so neither
        expert shadows onto its new owner.
        """
        expert, dst = int(expert), int(dst)
        assert 0 <= expert < self.num_experts, expert
        assert 0 <= dst < self.num_devices, dst
        owner = self.owner
        if int(owner[expert]) == dst:
            return self
        if partner is None:
            on_dst = np.where(owner == dst)[0]
            assert len(on_dst), f"device {dst} owns no experts"
            partner = int(on_dst[0])
        partner = int(partner)
        assert partner != expert
        assert int(owner[partner]) == dst, (
            f"partner {partner} is owned by {owner[partner]}, not {dst}")
        slots = self.slots.copy()
        slots[expert], slots[partner] = slots[partner], slots[expert]
        src = int(owner[expert])
        new_shadows = dict(self.shadows)
        for e, new_home in ((expert, dst), (partner, src)):
            if e in new_shadows:
                pruned = frozenset(new_shadows[e]) - {new_home}
                if pruned:
                    new_shadows[e] = pruned
                else:
                    del new_shadows[e]
        return ExpertPlacement(self.num_experts, self.num_devices,
                               new_shadows, tuple(int(s) for s in slots))

    # -- relocation schedule --------------------------------------------
    def diff(self, prev: "ExpertPlacement") -> List[Tuple[int, int, int]]:
        """Owner changes vs ``prev``: ``[(expert, src_dev, dst_dev), ...]``
        sorted by expert id — the relocation list a weight-exchange step
        must realize."""
        assert (prev.num_experts, prev.num_devices) == (
            self.num_experts, self.num_devices)
        po, no = prev.owner, self.owner
        return [(int(e), int(po[e]), int(no[e]))
                for e in np.where(po != no)[0]]

    def relocation_gather(self, prev: "ExpertPlacement") -> Array:
        """int32 ``[E]`` slot gather turning ``prev``'s physical layout
        into this one: ``new_weights[s] = old_weights[gather[s]]``.  The
        identity permutation means no data moves; off-diagonal entries on
        another device's slot range are the EP-axis exchange."""
        assert (prev.num_experts, prev.num_devices) == (
            self.num_experts, self.num_devices)
        # new slot s holds expert self.slot_expert[s], previously stored
        # at slot prev.slots[that expert].
        return prev.slots[self.slot_expert].astype(np.int32)

    # -- load computation (Replace_Inputs in Algorithm 1) ----------------
    def compute_loads(self, g: Array, *, capacity=None,
                      return_dropped: bool = False):
        """Given routing matrix ``G[d, e]``, return ``(H, R)``.

        ``H[i]``: tokens *computed* on device i.  ``R[i]``: tokens
        *received* by device i from other devices (the paper's a2a term).
        A token on source device d routed to expert e is computed locally
        iff d holds e's params under this placement; otherwise it is sent
        to e's owner — the *current* owner, i.e. migrations re-home the
        a2a destination.  (When an expert is shadowed, tokens on
        non-holder devices still go to the owner — the shadow only absorbs
        the load already resident on the shadow devices, paper Fig. 6b.)

        ``capacity`` (scalar or per-device ``[D]`` vector) enables
        capacity-aware accounting: each (computing device, expert)
        *bucket* — the unit the dispatch kernel's capacity buffer
        truncates at — is clamped to the device's cap and the overflow
        is **dropped**, matching what the hardware would actually
        compute.  ``H`` then sums the truncated buckets; ``R`` stays
        untruncated (the wire cost is paid before the buffer drops the
        token).  A per-device cap of 0 models an evacuated/lost rank
        that computes nothing.  With ``return_dropped`` the per-device
        dropped-token vector is returned as a third element; capacity
        ``None`` keeps the dense accounting bit-identical.
        """
        g = np.asarray(g, dtype=np.float64)
        D, E = self.num_devices, self.num_experts
        assert g.shape == (D, E), (g.shape, (D, E))
        p = self.placement_matrix()  # [E, D]
        holds = p.T  # [D, E] — device d holds expert e
        local = g * holds  # tokens computed where they live
        remote = g * (~holds)  # tokens shipped to the owner
        owner = self.owner
        remote_per_expert = remote.sum(axis=0)
        R = np.bincount(owner, weights=remote_per_expert, minlength=D)
        if capacity is None:
            H = local.sum(axis=1)
            H += np.bincount(owner, weights=remote_per_expert, minlength=D)
            if return_dropped:
                return H, R, np.zeros(D)
            return H, R
        cap = np.asarray(capacity, dtype=np.float64)
        if cap.ndim == 0:
            cap = np.full(D, float(cap))
        assert cap.shape == (D,), (cap.shape, D)
        # bucket[d, e]: tokens computed at device d for expert e — the
        # local holders' share plus, on the owner, everything remote.
        bucket = local.copy()
        bucket[owner, np.arange(E)] += remote_per_expert
        capped = np.minimum(bucket, cap[:, None])
        H = capped.sum(axis=1)
        if return_dropped:
            return H, R, (bucket - capped).sum(axis=1)
        return H, R

    # -- device-side (traced) form ---------------------------------------
    def to_device_arrays(self, s_max: int) -> Dict[str, Array]:
        """Static-shape form for the jitted step.

        Returns:
          ``shadow_idx``  int32 ``[s_max]``  — expert id per slot (0-padded),
          ``shadow_valid`` f32  ``[s_max]``  — 1.0 where the slot is live,
          ``shadow_devs`` f32  ``[s_max, D]`` — compute mask (owner excluded;
          the owner computes its tokens through the home path),
          ``expert_slot`` int32 ``[E]``      — expert → physical slot (the
          a2a destination bucket; identity when nothing migrated).
        """
        D = self.num_devices
        # Padding slots carry the sentinel expert id == num_experts so the
        # device-side lookup tables can never alias a real expert.
        idx = np.full((s_max,), self.num_experts, dtype=np.int32)
        valid = np.zeros((s_max,), dtype=np.float32)
        devs = np.zeros((s_max, D), dtype=np.float32)
        live = [(e, ds) for e, ds in sorted(self.shadows.items()) if ds]
        if len(live) > s_max:
            # Keep the largest shadow sets; the rest fall back to the a2a
            # path.  The planner respects s_max so this is a safety net.
            live.sort(key=lambda kv: -len(kv[1]))
            live = live[:s_max]
            live.sort()
        for slot, (e, ds) in enumerate(live):
            idx[slot] = e
            valid[slot] = 1.0
            for d in ds:
                devs[slot, d] = 1.0
        return {"shadow_idx": idx, "shadow_valid": valid, "shadow_devs": devs,
                "expert_slot": self.slots.astype(np.int32)}


def traditional(num_experts: int, num_devices: int) -> ExpertPlacement:
    """Plain EP placement (DeepSpeed-MoE baseline)."""
    return ExpertPlacement(num_experts, num_devices, {})


def shadow_to_all(num_experts: int, num_devices: int, experts) -> ExpertPlacement:
    """FasterMoE-style: replicate the given experts onto *all* devices."""
    pl = traditional(num_experts, num_devices)
    all_devs = frozenset(range(num_devices))
    for e in experts:
        pl = pl.with_shadow(int(e), all_devs)
    return pl
