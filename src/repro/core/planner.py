"""Pro-Prophet planner: the locality-based greedy search (paper §IV.C, Alg. 1).

The search space of lightweight expert placements is ``2^(E·D)``; the greedy
algorithm instead repeatedly

  1. finds the heaviest device,
  2. selects its heaviest resident expert (not yet selected),
  3. scores the candidate *moves* for that expert —
     **shadow** (paper): replicate it onto every device except the ``n``
     devices holding the fewest of its tokens (``BottomK``) and its owner;
     **migrate** (beyond-paper, FlexMoE/LAER-MoE-style): swap its home
     slot with a partner slot on the lightest device, paying a one-time
     amortized weight move (``PerfModel.t_migrate``) instead of a
     per-step ``Trans`` —
  4. takes the cheaper move, re-derives the loads (``Replace_Inputs``) and
     evaluates the placement with the performance model,

keeping the *prefix* of moves that achieved the minimum predicted time
(``cnt`` in the paper's listing).  Termination: the balance condition
``max(H) − min(H) < α·I/E`` (eq. 7), the heaviest device repeating, or the
move budget ``s_max`` being reached.

``strategy`` selects the search space: ``"shadow"`` (default — exactly the
paper's Algorithm 1, bit-identical to the pre-migration planner),
``"migrate"`` (owner re-layout only), or ``"both"``.  ``migrate_window``
is the expected number of steps the locality property keeps the placement
valid — the amortization horizon that decides migrate-vs-shadow: a
persistent skew (large window) favors the one-time move, a transient one
(window → 1) favors per-step shadowing.

The *locality-based* part: ``LocalityPlanner`` re-runs the search only every
``replan_interval`` iterations, planning from the **predicted** distribution
of the upcoming iteration (last observed, per the paper), and reuses the
placement in between.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .distribution import LocalityTracker
from .perfmodel import PerfModel
from .placement import ExpertPlacement, traditional

Array = np.ndarray


@dataclasses.dataclass
class PlanResult:
    placement: ExpertPlacement
    predicted_time: float        # performance-model time of `placement`
    baseline_time: float         # time of the traditional placement
    steps_examined: int          # greedy iterations executed
    balanced: bool               # eq. 7 satisfied at exit
    num_migrations: int = 0      # experts re-homed by this placement

    @property
    def predicted_speedup(self) -> float:
        return self.baseline_time / self.predicted_time if self.predicted_time else 1.0


class GreedyPlanner:
    """Algorithm 1 + owner re-layout.  ``n``: devices a selected expert is
    NOT sent to; ``alpha``: balance tolerance of eq. 7; ``s_max``:
    move budget (static shadow-slot capacity of the traced step, see
    DESIGN.md §3); ``strategy``/``migrate_window``/``migrate_state_factor``:
    migration search space (module docstring)."""

    STRATEGIES = ("shadow", "migrate", "both")

    def __init__(self, perf: PerfModel, *, n: int = 0, alpha: float = 0.25,
                 s_max: int = 8, scheduled: bool = False,
                 strategy: str = "shadow", migrate_window: float = 50.0,
                 migrate_state_factor: float = 3.0,
                 migrate_hysteresis: float = 1.0):
        self.perf = perf
        self.n = int(n)
        self.alpha = float(alpha)
        self.s_max = int(s_max)
        # When True the performance model evaluates eq. 8 (planner/scheduler
        # coupling, §V.C) so the search targets the *overlapped* time.
        self.scheduled = bool(scheduled)
        assert strategy in self.STRATEGIES, strategy
        self.strategy = strategy
        self.migrate_window = float(migrate_window)
        self.migrate_state_factor = float(migrate_state_factor)
        # Churn control: new migrations are adopted only when their
        # modeled steady-state win over the best migration-free prefix is
        # at least `migrate_hysteresis` × the amortized exchange cost.
        # 1.0 is the break-even the amortized scoring already enforces
        # (the gate is then vacuous); > 1 suppresses epsilon-win moves
        # that would churn the weights for negligible balance gain.
        self.migrate_hysteresis = float(migrate_hysteresis)

    def _balanced(self, H: Array, total_inputs: float, num_experts: int) -> bool:
        return (H.max() - H.min()) < self.alpha * total_inputs / num_experts

    def _migrate_candidate(self, cur: ExpertPlacement, e: int,
                           heavy_dev: int, H: Array,
                           tokens_per_expert: Array,
                           migrated: set) -> Optional[Tuple[int, int]]:
        """(dst, partner) for re-homing expert ``e``: the lightest device
        that owns a swappable partner (not ``e``, not already moved, not
        shadowed — its shadow set would need pruning), partner = its
        coldest expert.  None when no device qualifies."""
        owner = cur.owner
        for dst in (int(d) for d in np.argsort(H, kind="stable")):
            if dst == heavy_dev:
                continue
            partners = [int(p) for p in np.where(owner == dst)[0]
                        if int(p) != e and int(p) not in migrated
                        and int(p) not in cur.shadows]
            if partners:
                return dst, int(partners[int(np.argmin(
                    tokens_per_expert[partners]))])
        return None

    def plan(self, g: Array, *, current: Optional[ExpertPlacement] = None
             ) -> PlanResult:
        """Greedy search from ``current``'s slot layout (identity when
        None — the pre-migration behavior, bit-identical for the shadow
        strategy).  Migration moves are charged ``t_migrate`` only for
        *new* owner changes relative to ``current`` — moves the device
        already executed are free, which is what stops a replan from
        re-paying (and re-proposing) its own history every step.  Shadows
        are re-decided from scratch each plan."""
        g = np.asarray(g, dtype=np.float64)
        D, E = g.shape
        assert D == self.perf.D, (D, self.perf.D)
        total_inputs = float(g.sum())
        eval_time = (self.perf.layer_time_scheduled if self.scheduled
                     else self.perf.layer_time)
        shadow_on = self.strategy in ("shadow", "both")
        migrate_on = self.strategy in ("migrate", "both")

        def score(R, H, s, m):
            t = eval_time(R, H, s, self.n)
            if m:
                t += self.perf.t_migrate(
                    m, window=self.migrate_window,
                    state_factor=self.migrate_state_factor)
            return t

        base = traditional(E, D)
        if current is not None and current.slot_of is not None:
            base = ExpertPlacement(E, D, {}, current.slot_of)
        placement = base
        H, R = placement.compute_loads(g)
        t_best = score(R, H, 0, 0)
        if base.slot_of is None:
            baseline = t_best
        else:
            Ht, Rt = traditional(E, D).compute_loads(g)
            baseline = score(Rt, Ht, 0, 0)

        used_devices: set[int] = set()
        # ("shadow", e, devs) | ("migrate", e, dst, partner)
        moves: List[Tuple] = []
        cnt = 0  # best prefix length
        # Best *migration-free* prefix (only prefixes before the first
        # migrate move qualify) — the hysteresis gate's fallback.
        cnt_free, t_free = 0, t_best
        steps = 0
        n_shadow = n_mig = 0
        migrated: set[int] = set()
        tokens_per_expert = g.sum(axis=0)

        cur = placement
        while not self._balanced(H, total_inputs, E) and len(moves) < self.s_max:
            steps += 1
            heavy_dev = int(np.argmax(H))
            if heavy_dev in used_devices:
                break
            used_devices.add(heavy_dev)

            # Heaviest not-yet-moved expert resident on the heavy device
            # (owners honor earlier migrations in this search).
            owner = cur.owner
            resident = np.where(owner == heavy_dev)[0]
            resident = [e for e in resident
                        if e not in cur.shadows and e not in migrated]
            if not resident:
                break
            e = int(resident[int(np.argmax(tokens_per_expert[resident]))])

            cand = None  # (kind, placement, H, R, t, payload)
            if shadow_on:
                # BottomK: exclude the n devices holding the fewest of e's
                # tokens (never excluding the owner — it already has the
                # params).
                order = np.argsort(g[:, e], kind="stable")
                bottoms = [int(d) for d in order
                           if int(d) != heavy_dev][: self.n]
                shadow_devs = frozenset(range(D)) - {heavy_dev} - set(bottoms)
                # Replace_Inputs, incrementally: e was not previously
                # shadowed, so exactly the tokens g[d, e] for d in
                # shadow_devs move from remote-on-owner to local-on-d.
                # O(|shadow_devs|) instead of a full O(D·E) compute_loads.
                # With the "last" predictor g holds integral counts and the
                # running sums match a fresh recomputation bit-for-bit;
                # fractional g (the "ema" predictor) may drift by float
                # rounding in the last ulp, which only matters on exact
                # ties of the heuristic's comparisons.
                own = int(owner[e])
                sd = np.fromiter(shadow_devs, dtype=np.intp)
                moved = g[sd, e]
                H_sh, R_sh = H.copy(), R.copy()
                H_sh[sd] += moved
                tot = float(moved.sum())
                H_sh[own] -= tot
                R_sh[own] -= tot
                t_sh = score(R_sh, H_sh, n_shadow + 1, n_mig)
                cand = ("shadow", cur.with_shadow(e, shadow_devs),
                        H_sh, R_sh, t_sh, shadow_devs)
            if migrate_on:
                mg = self._migrate_candidate(cur, e, heavy_dev, H,
                                             tokens_per_expert, migrated)
                if mg is not None:
                    dst, partner = mg
                    pl_mg = cur.with_migration(e, dst, partner)
                    # Incremental Replace_Inputs for the swap: e and the
                    # partner are both unshadowed (the selection and
                    # _migrate_candidate guarantee it), so each expert's
                    # tokens are computed entirely at its owner and all
                    # but the owner's own tokens arrive remotely — O(1)
                    # per candidate instead of a full O(D·E)
                    # compute_loads (the same trick the shadow branch
                    # uses; validated against the recompute oracle in
                    # tests/test_migration.py).
                    tot_e = float(tokens_per_expert[e])
                    tot_p = float(tokens_per_expert[partner])
                    H_mg, R_mg = H.copy(), R.copy()
                    H_mg[heavy_dev] += tot_p - tot_e
                    H_mg[dst] += tot_e - tot_p
                    R_mg[heavy_dev] += ((tot_p - g[heavy_dev, partner])
                                        - (tot_e - g[heavy_dev, e]))
                    R_mg[dst] += ((tot_e - g[dst, e])
                                  - (tot_p - g[dst, partner]))
                    t_mg = score(R_mg, H_mg, pl_mg.num_shadowed, n_mig + 1)
                    if cand is None or t_mg < cand[4]:
                        cand = ("migrate", pl_mg, H_mg, R_mg, t_mg,
                                (dst, partner))
            if cand is None:
                break
            kind, cur, H, R, t, payload = cand
            if kind == "shadow":
                moves.append(("shadow", e, payload))
                n_shadow += 1
            else:
                dst, partner = payload
                moves.append(("migrate", e, dst, partner))
                migrated.update((e, partner))
                n_mig += 1
            if t < t_best:
                t_best = t
                cnt = len(moves)
            if n_mig == 0 and t < t_free:
                t_free = t
                cnt_free = len(moves)

        # Hysteresis gate: adopting new migrations must beat the best
        # migration-free prefix by ≥ hysteresis × the amortized exchange
        # cost (modeled-win ≥ exchange-cost).  The prefix scores already
        # charge the amortized t_migrate, so at hysteresis 1.0 the prefix
        # argmin enforces exactly break-even; > 1 demands real margin.
        m_new = sum(1 for mv in moves[:cnt] if mv[0] == "migrate")
        if m_new > 0:
            t_move = self.perf.t_migrate(
                m_new, window=self.migrate_window,
                state_factor=self.migrate_state_factor)
            win = t_free - (t_best - t_move)   # steady-state win
            if win < self.migrate_hysteresis * t_move:
                cnt, t_best = cnt_free, t_free

        # Keep only the best prefix (paper: PoE ← L[0:cnt]).
        best = base
        for mv in moves[:cnt]:
            if mv[0] == "shadow":
                best = best.with_shadow(mv[1], mv[2])
            else:
                best = best.with_migration(mv[1], mv[2], mv[3])
        Hb, _ = best.compute_loads(g)
        return PlanResult(
            placement=best,
            predicted_time=t_best,
            baseline_time=baseline,
            steps_examined=steps,
            balanced=self._balanced(Hb, total_inputs, E),
            num_migrations=best.num_migrated,
        )


class LocalityPlanner:
    """Locality-based wrapper: predicted-distribution planning at a reduced
    cadence (paper §IV.C last paragraph + §V.A).

    ``maybe_plan`` is called once per iteration with the routing matrix
    *observed* in that iteration; it returns the placement to use for the
    **next** iteration.  A fresh greedy search runs every
    ``replan_interval`` iterations; otherwise the cached placement is
    reused — valid precisely because of the locality property.
    """

    def __init__(self, greedy: GreedyPlanner, num_devices: int,
                 num_experts: int, *, replan_interval: int = 1,
                 predictor: str = "last"):
        self.greedy = greedy
        self.replan_interval = max(1, int(replan_interval))
        self.predictor = predictor
        self.tracker = LocalityTracker(num_devices, num_experts)
        self._cached: Optional[PlanResult] = None
        self._iteration = -1

    @property
    def current(self) -> Optional[PlanResult]:
        return self._cached

    def snapshot(self) -> Tuple:
        """Capture the replan cadence/tracker state for watchdog rollback.
        The tracker's stored matrices are never mutated in place, so
        shallow references suffice."""
        t = self.tracker
        return (list(t._hist), None if t._ema is None else t._ema.copy(),
                self._cached, self._iteration)

    def restore(self, snap: Tuple) -> None:
        """Roll back to a :meth:`snapshot` (see
        ``ProProphetEngine.restore``)."""
        hist, ema, cached, iteration = snap
        t = self.tracker
        t._hist.clear()
        t._hist.extend(hist)
        t._ema = ema
        self._cached = cached
        self._iteration = iteration

    def step(self, g_observed: Array, *, replan: Optional[bool] = None,
             g_plan: Optional[Array] = None,
             current: Optional[ExpertPlacement] = None
             ) -> Tuple[PlanResult, bool]:
        """One observation with externally-driven cadence: the caller
        (the engine's forecast backoff) decides whether this observation
        triggers a greedy search (``replan``; None ⇒ the internal
        ``replan_interval`` cadence) and may supply the distribution to
        plan from (``g_plan``, e.g. the layer forecast; None ⇒ the
        tracker's ``predictor``) and the layout to plan *from*
        (``current``, e.g. the device's slot layout so already-executed
        migrations are free).  Returns ``(result, planned)`` where
        ``planned`` says a fresh search actually ran — the
        plans-executed/skipped accounting the cadence-aware overlap
        telemetry needs."""
        self._iteration += 1
        self.tracker.update(np.asarray(g_observed, dtype=np.float64))
        due = bool(self._cached is None
                   or (replan if replan is not None
                       else self._iteration % self.replan_interval == 0))
        if due:
            g = (np.asarray(g_plan, dtype=np.float64) if g_plan is not None
                 else self.tracker.predict_next(self.predictor))
            self._cached = self.greedy.plan(g, current=current)
        return self._cached, due

    def maybe_plan(self, g_observed: Array, *,
                   current: Optional[ExpertPlacement] = None) -> PlanResult:
        return self.step(g_observed, current=current)[0]
