"""Pro-Prophet planner: the locality-based greedy search (paper §IV.C, Alg. 1).

The search space of lightweight expert placements is ``2^(E·D)``; the greedy
algorithm instead repeatedly

  1. finds the heaviest device,
  2. selects its heaviest resident expert (not yet selected),
  3. shadows that expert onto every device except the ``n`` devices holding
     the fewest of its tokens (``BottomK``) — and except its owner,
  4. re-derives the loads (``Replace_Inputs``) and evaluates the placement
     with the performance model,

keeping the *prefix* of moves that achieved the minimum predicted time
(``cnt`` in the paper's listing).  Termination: the balance condition
``max(H) − min(H) < α·I/E`` (eq. 7), the heaviest device repeating, or the
shadow budget ``s_max`` being reached.

The *locality-based* part: ``LocalityPlanner`` re-runs the search only every
``replan_interval`` iterations, planning from the **predicted** distribution
of the upcoming iteration (last observed, per the paper), and reuses the
placement in between.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .distribution import LocalityTracker
from .perfmodel import PerfModel
from .placement import ExpertPlacement, traditional

Array = np.ndarray


@dataclasses.dataclass
class PlanResult:
    placement: ExpertPlacement
    predicted_time: float        # performance-model time of `placement`
    baseline_time: float         # time of the traditional placement
    steps_examined: int          # greedy iterations executed
    balanced: bool               # eq. 7 satisfied at exit

    @property
    def predicted_speedup(self) -> float:
        return self.baseline_time / self.predicted_time if self.predicted_time else 1.0


class GreedyPlanner:
    """Algorithm 1.  ``n``: devices a selected expert is NOT sent to;
    ``alpha``: balance tolerance of eq. 7; ``s_max``: shadow-slot budget
    (static capacity of the traced step, see DESIGN.md §3)."""

    def __init__(self, perf: PerfModel, *, n: int = 0, alpha: float = 0.25,
                 s_max: int = 8, scheduled: bool = False):
        self.perf = perf
        self.n = int(n)
        self.alpha = float(alpha)
        self.s_max = int(s_max)
        # When True the performance model evaluates eq. 8 (planner/scheduler
        # coupling, §V.C) so the search targets the *overlapped* time.
        self.scheduled = bool(scheduled)

    def _balanced(self, H: Array, total_inputs: float, num_experts: int) -> bool:
        return (H.max() - H.min()) < self.alpha * total_inputs / num_experts

    def plan(self, g: Array) -> PlanResult:
        g = np.asarray(g, dtype=np.float64)
        D, E = g.shape
        assert D == self.perf.D, (D, self.perf.D)
        total_inputs = float(g.sum())
        eval_time = (self.perf.layer_time_scheduled if self.scheduled
                     else self.perf.layer_time)

        placement = traditional(E, D)
        H, R = placement.compute_loads(g)
        t_best = eval_time(R, H, 0, self.n)
        baseline = t_best

        used_devices: set[int] = set()
        moves: List[Tuple[int, frozenset]] = []
        cnt = 0  # best prefix length
        steps = 0
        owner = placement.owner
        tokens_per_expert = g.sum(axis=0)

        cur = placement
        while not self._balanced(H, total_inputs, E) and len(moves) < self.s_max:
            steps += 1
            heavy_dev = int(np.argmax(H))
            if heavy_dev in used_devices:
                break
            used_devices.add(heavy_dev)

            # Heaviest not-yet-shadowed expert resident on the heavy device.
            resident = np.where(owner == heavy_dev)[0]
            resident = [e for e in resident if e not in cur.shadows]
            if not resident:
                break
            e = int(resident[int(np.argmax(tokens_per_expert[resident]))])

            # BottomK: exclude the n devices holding the fewest of e's
            # tokens (never excluding the owner — it already has the params).
            order = np.argsort(g[:, e], kind="stable")
            bottoms = [int(d) for d in order if int(d) != heavy_dev][: self.n]
            shadow_devs = frozenset(range(D)) - {heavy_dev} - set(bottoms)

            cur = cur.with_shadow(e, shadow_devs)
            moves.append((e, shadow_devs))
            # Replace_Inputs, incrementally: e was not previously shadowed,
            # so exactly the tokens g[d, e] for d in shadow_devs move from
            # remote-on-owner to local-on-d.  O(|shadow_devs|) instead of a
            # full O(D·E) compute_loads.  With the "last" predictor g holds
            # integral counts and the running sums match a fresh
            # recomputation bit-for-bit; fractional g (the "ema" predictor)
            # may drift by float rounding in the last ulp, which only
            # matters on exact ties of the heuristic's comparisons.
            own = int(owner[e])
            sd = np.fromiter(shadow_devs, dtype=np.intp)
            moved = g[sd, e]
            H[sd] += moved
            tot = float(moved.sum())
            H[own] -= tot
            R[own] -= tot
            t = eval_time(R, H, len(moves), self.n)
            if t < t_best:
                t_best = t
                cnt = len(moves)

        # Keep only the best prefix (paper: PoE ← L[0:cnt]).
        best = traditional(E, D)
        for e, devs in moves[:cnt]:
            best = best.with_shadow(e, devs)
        Hb, _ = best.compute_loads(g)
        return PlanResult(
            placement=best,
            predicted_time=t_best,
            baseline_time=baseline,
            steps_examined=steps,
            balanced=self._balanced(Hb, total_inputs, E),
        )


class LocalityPlanner:
    """Locality-based wrapper: predicted-distribution planning at a reduced
    cadence (paper §IV.C last paragraph + §V.A).

    ``maybe_plan`` is called once per iteration with the routing matrix
    *observed* in that iteration; it returns the placement to use for the
    **next** iteration.  A fresh greedy search runs every
    ``replan_interval`` iterations; otherwise the cached placement is
    reused — valid precisely because of the locality property.
    """

    def __init__(self, greedy: GreedyPlanner, num_devices: int,
                 num_experts: int, *, replan_interval: int = 1,
                 predictor: str = "last"):
        self.greedy = greedy
        self.replan_interval = max(1, int(replan_interval))
        self.predictor = predictor
        self.tracker = LocalityTracker(num_devices, num_experts)
        self._cached: Optional[PlanResult] = None
        self._iteration = -1

    @property
    def current(self) -> Optional[PlanResult]:
        return self._cached

    def maybe_plan(self, g_observed: Array) -> PlanResult:
        self._iteration += 1
        self.tracker.update(np.asarray(g_observed, dtype=np.float64))
        if self._cached is None or self._iteration % self.replan_interval == 0:
            g_pred = self.tracker.predict_next(self.predictor)
            self._cached = self.greedy.plan(g_pred)
        return self._cached
