"""Pro-Prophet planner: the locality-based greedy search (paper §IV.C, Alg. 1).

The search space of lightweight expert placements is ``2^(E·D)``; the greedy
algorithm instead repeatedly

  1. finds the heaviest device,
  2. selects its heaviest resident expert (not yet selected),
  3. scores the candidate *moves* for that expert —
     **shadow** (paper): replicate it onto every device except the ``n``
     devices holding the fewest of its tokens (``BottomK``) and its owner;
     **migrate** (beyond-paper, FlexMoE/LAER-MoE-style): swap its home
     slot with a partner slot on the lightest device, paying a one-time
     amortized weight move (``PerfModel.t_migrate``) instead of a
     per-step ``Trans`` —
  4. takes the cheaper move, re-derives the loads (``Replace_Inputs``) and
     evaluates the placement with the performance model,

keeping the *prefix* of moves that achieved the minimum predicted time
(``cnt`` in the paper's listing).  Termination: the balance condition
``max(H) − min(H) < α·I/E`` (eq. 7), the heaviest device repeating, or the
move budget ``s_max`` being reached.

``strategy`` selects the search space: ``"shadow"`` (default — exactly the
paper's Algorithm 1, bit-identical to the pre-migration planner),
``"migrate"`` (owner re-layout only), or ``"both"``.  ``migrate_window``
is the expected number of steps the locality property keeps the placement
valid — the amortization horizon that decides migrate-vs-shadow: a
persistent skew (large window) favors the one-time move, a transient one
(window → 1) favors per-step shadowing.

The *locality-based* part: ``LocalityPlanner`` re-runs the search only every
``replan_interval`` iterations, planning from the **predicted** distribution
of the upcoming iteration (last observed, per the paper), and reuses the
placement in between.
"""
from __future__ import annotations

import dataclasses
import time
from typing import FrozenSet, List, Optional, Tuple

import numpy as np

from .distribution import LocalityTracker
from .guard import PlanDeadlineError
from .perfmodel import PerfModel
from .placement import ExpertPlacement, traditional

Array = np.ndarray


@dataclasses.dataclass
class PlanResult:
    placement: ExpertPlacement
    predicted_time: float        # performance-model time of `placement`
    baseline_time: float         # time of the traditional placement
    steps_examined: int          # greedy iterations executed
    balanced: bool               # eq. 7 satisfied at exit
    num_migrations: int = 0      # experts re-homed by this placement
    num_evacuated: int = 0       # experts force-moved off lost devices
    dropped_tokens: float = 0.0  # capacity-truncated tokens (scoring on)

    @property
    def predicted_speedup(self) -> float:
        return self.baseline_time / self.predicted_time if self.predicted_time else 1.0


class GreedyPlanner:
    """Algorithm 1 + owner re-layout.  ``n``: devices a selected expert is
    NOT sent to; ``alpha``: balance tolerance of eq. 7; ``s_max``:
    move budget (static shadow-slot capacity of the traced step, see
    DESIGN.md §3); ``strategy``/``migrate_window``/``migrate_state_factor``:
    migration search space (module docstring)."""

    STRATEGIES = ("shadow", "migrate", "both")

    def __init__(self, perf: PerfModel, *, n: int = 0, alpha: float = 0.25,
                 s_max: int = 8, scheduled: bool = False,
                 strategy: str = "shadow", migrate_window: float = 50.0,
                 migrate_state_factor: float = 3.0,
                 migrate_hysteresis: float = 1.0,
                 capacity_factor: float = 0.0,
                 evacuate: bool = True):
        self.perf = perf
        self.n = int(n)
        self.alpha = float(alpha)
        self.s_max = int(s_max)
        # When True the performance model evaluates eq. 8 (planner/scheduler
        # coupling, §V.C) so the search targets the *overlapped* time.
        self.scheduled = bool(scheduled)
        assert strategy in self.STRATEGIES, strategy
        self.strategy = strategy
        self.migrate_window = float(migrate_window)
        self.migrate_state_factor = float(migrate_state_factor)
        # Churn control: new migrations are adopted only when their
        # modeled steady-state win over the best migration-free prefix is
        # at least `migrate_hysteresis` × the amortized exchange cost.
        # 1.0 is the break-even the amortized scoring already enforces
        # (the gate is then vacuous); > 1 suppresses epsilon-win moves
        # that would churn the weights for negligible balance gain.
        self.migrate_hysteresis = float(migrate_hysteresis)
        # Capacity-aware scoring (ROADMAP carry-over): > 0 prices each
        # candidate by the *truncated* loads — per-bucket cap =
        # capacity_factor · I / E, zero on lost devices — plus a
        # dropped-token penalty, so the planner sees the drop it
        # actually creates.  0 keeps the dense scoring bit-identical.
        self.capacity_factor = float(capacity_factor)
        # Force-evacuate experts owned by lost devices (health tracker
        # → perf.set_device_factors → perf.lost_devices()).
        self.evacuate = bool(evacuate)

    def _balanced(self, H: Array, total_inputs: float, num_experts: int,
                  w: Optional[Array] = None,
                  alive: Optional[Array] = None) -> bool:
        """eq. 7, generalized: with per-device slowness weights ``w``
        the condition balances *time*, not tokens, and lost devices
        (``alive`` mask False) are excluded from the spread."""
        Hv = H if w is None else H * w
        if alive is not None:
            Hv = Hv[alive]
        if Hv.size == 0:  # every device lost — nothing left to balance
            return True
        return (Hv.max() - Hv.min()) < self.alpha * total_inputs / num_experts

    def _slowness(self) -> Optional[Array]:
        """Per-device time-per-token weight, normalized to mean 1 so the
        eq. 7 tolerance keeps its units; None on homogeneous fleets (the
        unweighted, bit-identical path).  The mean is taken over
        *surviving* devices only: a lost rank's ~1/FACTOR_FLOOR inverse
        speed would otherwise dominate the normalizer and dilute every
        healthy weight to ≈0, making the weighted balance condition
        vacuously true (the planner would stop balancing the survivors
        exactly when a loss makes balancing matter most)."""
        if not getattr(self.perf, "heterogeneous", False):
            return None
        speeds = self.perf.device_speeds()
        inv = 1.0 / speeds
        lost = getattr(self.perf, "lost_devices", lambda: [])()
        if lost:
            alive = np.ones(inv.shape[0], dtype=bool)
            alive[list(lost)] = False
            if alive.any():
                return inv / inv[alive].mean()
        return inv / inv.mean()

    def _migrate_candidate(self, cur: ExpertPlacement, e: int,
                           heavy_dev: int, H: Array,
                           tokens_per_expert: Array,
                           migrated: set,
                           lost: FrozenSet[int] = frozenset()
                           ) -> Optional[Tuple[int, int]]:
        """(dst, partner) for re-homing expert ``e``: the lightest device
        that owns a swappable partner (not ``e``, not already moved, not
        shadowed — its shadow set would need pruning), partner = its
        coldest expert.  ``H`` may be slowness-weighted so "lightest"
        means fastest-to-drain; ``lost`` devices never receive work.
        None when no device qualifies."""
        owner = cur.owner
        for dst in (int(d) for d in np.argsort(H, kind="stable")):
            if dst == heavy_dev or dst in lost:
                continue
            partners = [int(p) for p in np.where(owner == dst)[0]
                        if int(p) != e and int(p) not in migrated
                        and int(p) not in cur.shadows]
            if partners:
                return dst, int(partners[int(np.argmin(
                    tokens_per_expert[partners]))])
        return None

    def _evacuate(self, base: ExpertPlacement, g: Array,
                  lost: FrozenSet[int],
                  prev: Optional[ExpertPlacement] = None,
                  ) -> Tuple[ExpertPlacement, int, int]:
        """Force-evacuate every expert owned by a lost device.

        Per-device physical slot counts are static (the relocation
        exchange's shape invariant), so a lost rank can never be left
        with zero slots: each hot resident *swaps* with the globally
        coldest expert on a healthy device (an ordinary
        ``with_migration``, so it flows through the PR 7 prefetch path
        as a normal relocation), then every expert still homed on a
        lost rank — the swapped-in cold ones — is shadowed onto all
        healthy devices so no remote token ever lands there
        (``R[lost] == 0``; the shadow absorbs every non-resident
        source).  Returns ``(placement, num_evacuated,
        num_forced_shadows)``: ``num_evacuated`` counts residents
        *newly* drained this plan (swaps plus first-time forced
        shadows), so a settled replan reports zero while the first
        evacuating plan is never silently empty even when every
        resident is cold.
        """
        D, E = base.num_devices, base.num_experts
        tokens_per_expert = g.sum(axis=0)
        healthy = frozenset(range(D)) - lost
        owner = base.owner
        residents = sorted(
            (int(e) for e in np.where(np.isin(owner, list(lost)))[0]),
            key=lambda e: -tokens_per_expert[e])
        num_evac = 0
        used: set[int] = set(residents)
        # Only *hot* residents (above fleet-mean tokens) are worth a real
        # exchange — a cold resident is fully covered by the shadow pass
        # below (every source computes its tokens locally, so the lost
        # rank sees none of them either way).  Without this gate every
        # replan under drift re-swaps the cold experts the previous
        # evacuation parked on the lost rank against the step's new
        # coldest, churning one relocation per layer per step forever.
        hot_floor = float(tokens_per_expert.mean())
        prev_shadows = dict(prev.shadows) if prev is not None else {}
        for e in residents:
            if healthy <= prev_shadows.get(e, frozenset()):
                # Already evacuated by an earlier plan: the forced shadow
                # from that plan covers every healthy source, so the
                # resident is settled — re-swapping it against the
                # current step's coldest expert would churn a relocation
                # (and a placement change) on every replan under drift.
                continue
            if tokens_per_expert[e] <= hot_floor:
                continue          # cold: the shadow pass covers it
            owner_now = base.owner
            cands = [p for p in range(E)
                     if int(owner_now[p]) not in lost and p not in used]
            if not cands:
                break
            partner = int(min(cands, key=lambda p: (tokens_per_expert[p], p)))
            base = base.with_migration(e, int(owner_now[partner]), partner)
            used.add(partner)
            num_evac += 1
        # Shadow whatever still lives on lost ranks (hottest first, the
        # shadow-slot budget permitting) onto every healthy device.
        owner_now = base.owner
        stranded = sorted(
            (int(e) for e in np.where(np.isin(owner_now, list(lost)))[0]),
            key=lambda e: -tokens_per_expert[e])
        forced = 0
        for e in stranded[: self.s_max]:
            if not (healthy <= prev_shadows.get(e, frozenset())):
                num_evac += 1        # first time this resident is drained
            base = base.with_shadow(e, healthy)
            forced += 1
        return base, num_evac, forced

    def plan(self, g: Array, *, current: Optional[ExpertPlacement] = None,
             deadline: Optional[float] = None) -> PlanResult:
        """Greedy search from ``current``'s slot layout (identity when
        None — the pre-migration behavior, bit-identical for the shadow
        strategy).  Migration moves are charged ``t_migrate`` only for
        *new* owner changes relative to ``current`` — moves the device
        already executed are free, which is what stops a replan from
        re-paying (and re-proposing) its own history every step.  Shadows
        are re-decided from scratch each plan.

        Degraded-mode extensions: when the perf model reports *lost*
        devices their experts are force-evacuated before the voluntary
        search (:meth:`_evacuate`) and they are excluded from every move
        target; on heterogeneous fleets heavy-device selection and the
        eq. 7 balance condition run on slowness-weighted loads so hot
        experts drain toward fast ranks.  ``deadline`` is an absolute
        ``time.perf_counter()`` instant: the move loop checks it every
        candidate and raises :class:`~repro.core.guard.PlanDeadlineError`
        on overrun — cooperative cancellation, so a slow search unsticks
        itself instead of being rejected post-hoc by the watchdog."""
        g = np.asarray(g, dtype=np.float64)
        D, E = g.shape
        assert D == self.perf.D, (D, self.perf.D)
        total_inputs = float(g.sum())
        eval_time = (self.perf.layer_time_scheduled if self.scheduled
                     else self.perf.layer_time)
        shadow_on = self.strategy in ("shadow", "both")
        migrate_on = self.strategy in ("migrate", "both")
        lost = frozenset(getattr(self.perf, "lost_devices", lambda: [])())
        w = self._slowness()
        alive = None
        if lost:
            alive = np.ones(D, dtype=bool)
            alive[list(lost)] = False

        def check_deadline(steps: int) -> None:
            if deadline is not None and time.perf_counter() > deadline:
                raise PlanDeadlineError(
                    f"greedy search overran its cooperative deadline "
                    f"after {steps} candidate moves")

        check_deadline(0)

        def score(R, H, s, m):
            t = eval_time(R, H, s, self.n)
            if m:
                t += self.perf.t_migrate(
                    m, window=self.migrate_window,
                    state_factor=self.migrate_state_factor)
            return t

        cap_vec = None
        if self.capacity_factor > 0.0:
            cap_vec = np.full(D, self.capacity_factor * total_inputs / E)
            if lost:
                cap_vec[list(lost)] = 0.0
            speeds_fn = getattr(self.perf, "device_speeds", None)
            speed_mean = (float(np.mean(speeds_fn())) if speeds_fn is not None
                          else float(self.perf.hw.throughput))

        def eval_candidate(pl, R, H, s, m):
            """Score one candidate.  Dense scoring uses the caller's
            incrementally maintained loads; capacity scoring recomputes
            the truncated loads from the placement (incremental updates
            are invalid under per-bucket truncation) and charges each
            dropped token one fleet-mean compute quantum."""
            if cap_vec is None:
                return score(R, H, s, m)
            Hc, Rc, drop = pl.compute_loads(g, capacity=cap_vec,
                                            return_dropped=True)
            return score(Rc, Hc, s, m) + float(drop.sum()) / speed_mean

        base = traditional(E, D)
        if current is not None and current.slot_of is not None:
            base = ExpertPlacement(E, D, {}, current.slot_of)
        num_evac = forced_shadows = 0
        if lost and self.evacuate and len(lost) < D:
            base, num_evac, forced_shadows = self._evacuate(
                base, g, lost, prev=current)
        placement = base
        H, R = placement.compute_loads(g)
        t_best = eval_candidate(placement, R, H, placement.num_shadowed, 0)
        if base.slot_of is None and not base.shadows:
            baseline = t_best
        else:
            Ht, Rt = traditional(E, D).compute_loads(g)
            baseline = eval_candidate(traditional(E, D), Rt, Ht, 0, 0)

        used_devices: set[int] = set()
        # ("shadow", e, devs) | ("migrate", e, dst, partner)
        moves: List[Tuple] = []
        cnt = 0  # best prefix length
        # Best *migration-free* prefix (only prefixes before the first
        # migrate move qualify) — the hysteresis gate's fallback.
        cnt_free, t_free = 0, t_best
        steps = 0
        n_mig = 0
        migrated: set[int] = set()
        tokens_per_expert = g.sum(axis=0)
        # Forced evacuation shadows occupy slots of the same static
        # shadow budget the traced step packs (to_device_arrays), so the
        # voluntary search gets what remains.
        budget = max(0, self.s_max - forced_shadows)

        cur = placement
        while (len(lost) < D
               and not self._balanced(H, total_inputs, E, w, alive)
               and len(moves) < budget):
            steps += 1
            check_deadline(steps)
            if w is None and not lost:
                heavy_dev = int(np.argmax(H))
            else:
                Hsel = (H if w is None else H * w).copy()
                Hsel[list(lost)] = -np.inf
                heavy_dev = int(np.argmax(Hsel))
            if heavy_dev in used_devices:
                break
            used_devices.add(heavy_dev)

            # Heaviest not-yet-moved expert resident on the heavy device
            # (owners honor earlier migrations in this search).
            owner = cur.owner
            resident = np.where(owner == heavy_dev)[0]
            resident = [e for e in resident
                        if e not in cur.shadows and e not in migrated]
            if not resident:
                break
            e = int(resident[int(np.argmax(tokens_per_expert[resident]))])

            cand = None  # (kind, placement, H, R, t, payload)
            if shadow_on:
                # BottomK: exclude the n devices holding the fewest of e's
                # tokens (never excluding the owner — it already has the
                # params).  Lost devices never receive shadows.
                order = np.argsort(g[:, e], kind="stable")
                bottoms = [int(d) for d in order
                           if int(d) != heavy_dev][: self.n]
                shadow_devs = (frozenset(range(D)) - {heavy_dev}
                               - set(bottoms) - lost)
                if shadow_devs:
                    # Replace_Inputs, incrementally: e was not previously
                    # shadowed, so exactly the tokens g[d, e] for d in
                    # shadow_devs move from remote-on-owner to local-on-d.
                    # O(|shadow_devs|) instead of a full O(D·E)
                    # compute_loads.
                    # With the "last" predictor g holds integral counts
                    # and the running sums match a fresh recomputation
                    # bit-for-bit; fractional g (the "ema" predictor) may
                    # drift by float rounding in the last ulp, which only
                    # matters on exact ties of the heuristic's
                    # comparisons.
                    own = int(owner[e])
                    sd = np.fromiter(shadow_devs, dtype=np.intp)
                    moved = g[sd, e]
                    H_sh, R_sh = H.copy(), R.copy()
                    H_sh[sd] += moved
                    tot = float(moved.sum())
                    H_sh[own] -= tot
                    R_sh[own] -= tot
                    pl_sh = cur.with_shadow(e, shadow_devs)
                    t_sh = eval_candidate(pl_sh, R_sh, H_sh,
                                          cur.num_shadowed + 1, n_mig)
                    cand = ("shadow", pl_sh, H_sh, R_sh, t_sh, shadow_devs)
            if migrate_on:
                mg = self._migrate_candidate(cur, e, heavy_dev,
                                             H if w is None else H * w,
                                             tokens_per_expert, migrated,
                                             lost)
                if mg is not None:
                    dst, partner = mg
                    pl_mg = cur.with_migration(e, dst, partner)
                    # Incremental Replace_Inputs for the swap: e and the
                    # partner are both unshadowed (the selection and
                    # _migrate_candidate guarantee it), so each expert's
                    # tokens are computed entirely at its owner and all
                    # but the owner's own tokens arrive remotely — O(1)
                    # per candidate instead of a full O(D·E)
                    # compute_loads (the same trick the shadow branch
                    # uses; validated against the recompute oracle in
                    # tests/test_migration.py).
                    tot_e = float(tokens_per_expert[e])
                    tot_p = float(tokens_per_expert[partner])
                    H_mg, R_mg = H.copy(), R.copy()
                    H_mg[heavy_dev] += tot_p - tot_e
                    H_mg[dst] += tot_e - tot_p
                    R_mg[heavy_dev] += ((tot_p - g[heavy_dev, partner])
                                        - (tot_e - g[heavy_dev, e]))
                    R_mg[dst] += ((tot_e - g[dst, e])
                                  - (tot_p - g[dst, partner]))
                    t_mg = eval_candidate(pl_mg, R_mg, H_mg,
                                          pl_mg.num_shadowed, n_mig + 1)
                    if cand is None or t_mg < cand[4]:
                        cand = ("migrate", pl_mg, H_mg, R_mg, t_mg,
                                (dst, partner))
            if cand is None:
                break
            kind, cur, H, R, t, payload = cand
            if kind == "shadow":
                moves.append(("shadow", e, payload))
            else:
                dst, partner = payload
                moves.append(("migrate", e, dst, partner))
                migrated.update((e, partner))
                n_mig += 1
            if t < t_best:
                t_best = t
                cnt = len(moves)
            if n_mig == 0 and t < t_free:
                t_free = t
                cnt_free = len(moves)

        # Hysteresis gate: adopting new migrations must beat the best
        # migration-free prefix by ≥ hysteresis × the amortized exchange
        # cost (modeled-win ≥ exchange-cost).  The prefix scores already
        # charge the amortized t_migrate, so at hysteresis 1.0 the prefix
        # argmin enforces exactly break-even; > 1 demands real margin.
        m_new = sum(1 for mv in moves[:cnt] if mv[0] == "migrate")
        if m_new > 0:
            t_move = self.perf.t_migrate(
                m_new, window=self.migrate_window,
                state_factor=self.migrate_state_factor)
            win = t_free - (t_best - t_move)   # steady-state win
            if win < self.migrate_hysteresis * t_move:
                cnt, t_best = cnt_free, t_free

        # Keep only the best prefix (paper: PoE ← L[0:cnt]).
        best = base
        for mv in moves[:cnt]:
            if mv[0] == "shadow":
                best = best.with_shadow(mv[1], mv[2])
            else:
                best = best.with_migration(mv[1], mv[2], mv[3])
        if cap_vec is None:
            Hb, _ = best.compute_loads(g)
            dropped = 0.0
        else:
            Hb, _, dropb = best.compute_loads(g, capacity=cap_vec,
                                              return_dropped=True)
            dropped = float(dropb.sum())
        return PlanResult(
            placement=best,
            predicted_time=t_best,
            baseline_time=baseline,
            steps_examined=steps,
            balanced=self._balanced(Hb, total_inputs, E, w, alive),
            num_migrations=best.num_migrated,
            num_evacuated=num_evac,
            dropped_tokens=dropped,
        )


class LocalityPlanner:
    """Locality-based wrapper: predicted-distribution planning at a reduced
    cadence (paper §IV.C last paragraph + §V.A).

    ``maybe_plan`` is called once per iteration with the routing matrix
    *observed* in that iteration; it returns the placement to use for the
    **next** iteration.  A fresh greedy search runs every
    ``replan_interval`` iterations; otherwise the cached placement is
    reused — valid precisely because of the locality property.
    """

    def __init__(self, greedy: GreedyPlanner, num_devices: int,
                 num_experts: int, *, replan_interval: int = 1,
                 predictor: str = "last"):
        self.greedy = greedy
        self.replan_interval = max(1, int(replan_interval))
        self.predictor = predictor
        self.tracker = LocalityTracker(num_devices, num_experts)
        self._cached: Optional[PlanResult] = None
        self._iteration = -1

    @property
    def current(self) -> Optional[PlanResult]:
        return self._cached

    def snapshot(self) -> Tuple:
        """Capture the replan cadence/tracker state for watchdog rollback.
        The tracker's stored matrices are never mutated in place, so
        shallow references suffice."""
        t = self.tracker
        return (list(t._hist), None if t._ema is None else t._ema.copy(),
                self._cached, self._iteration)

    def restore(self, snap: Tuple) -> None:
        """Roll back to a :meth:`snapshot` (see
        ``ProProphetEngine.restore``)."""
        hist, ema, cached, iteration = snap
        t = self.tracker
        t._hist.clear()
        t._hist.extend(hist)
        t._ema = ema
        self._cached = cached
        self._iteration = iteration

    def step(self, g_observed: Array, *, replan: Optional[bool] = None,
             g_plan: Optional[Array] = None,
             current: Optional[ExpertPlacement] = None,
             deadline: Optional[float] = None
             ) -> Tuple[PlanResult, bool]:
        """One observation with externally-driven cadence: the caller
        (the engine's forecast backoff) decides whether this observation
        triggers a greedy search (``replan``; None ⇒ the internal
        ``replan_interval`` cadence) and may supply the distribution to
        plan from (``g_plan``, e.g. the layer forecast; None ⇒ the
        tracker's ``predictor``) and the layout to plan *from*
        (``current``, e.g. the device's slot layout so already-executed
        migrations are free).  Returns ``(result, planned)`` where
        ``planned`` says a fresh search actually ran — the
        plans-executed/skipped accounting the cadence-aware overlap
        telemetry needs."""
        self._iteration += 1
        self.tracker.update(np.asarray(g_observed, dtype=np.float64))
        due = bool(self._cached is None
                   or (replan if replan is not None
                       else self._iteration % self.replan_interval == 0))
        if due:
            g = (np.asarray(g_plan, dtype=np.float64) if g_plan is not None
                 else self.tracker.predict_next(self.predictor))
            self._cached = self.greedy.plan(g, current=current,
                                            deadline=deadline)
        return self._cached, due

    def maybe_plan(self, g_observed: Array, *,
                   current: Optional[ExpertPlacement] = None) -> PlanResult:
        return self.step(g_observed, current=current)[0]
