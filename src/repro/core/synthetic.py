"""Synthetic gating traces with the paper's locality property.

Benchmarks and property tests need routing matrices ``G[d, e]`` whose
per-expert distribution (a) is skewed the way Fig. 3 shows (a few experts
hold >50 % of tokens) and (b) drifts slowly across iterations (Fig. 4
locality).  We model expert popularity as a Dirichlet draw evolving by a
bounded multiplicative random walk, and source devices as near-uniform.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


class GatingTrace:
    """Iterator of routing matrices with controllable skew and drift.

    skew:  Dirichlet concentration (smaller ⇒ more imbalanced).
    drift: per-iteration log-popularity noise scale (0 ⇒ frozen
           distribution; ≈0.05 matches the paper's adjacent-iteration
           similarity; large ⇒ no locality).
    """

    def __init__(self, num_devices: int, num_experts: int,
                 tokens_per_device: int, *, skew: float = 0.3,
                 drift: float = 0.05, seed: int = 0):
        self.D, self.E = num_devices, num_experts
        self.tokens_per_device = tokens_per_device
        self.drift = drift
        self.rng = np.random.default_rng(seed)
        self.log_pop = np.log(self.rng.dirichlet(np.full(num_experts, skew))
                              + 1e-9)

    def _popularity(self) -> np.ndarray:
        p = np.exp(self.log_pop)
        return p / p.sum()

    def step(self) -> np.ndarray:
        """Advance one iteration; return ``G[d, e]`` (int64)."""
        self.log_pop += self.rng.normal(0.0, self.drift, size=self.E)
        pop = self._popularity()
        g = np.stack([
            self.rng.multinomial(self.tokens_per_device, pop)
            for _ in range(self.D)
        ])
        return g.astype(np.int64)

    def take(self, n: int) -> list[np.ndarray]:
        return [self.step() for _ in range(n)]

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            yield self.step()
