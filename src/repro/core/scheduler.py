"""Pro-Prophet scheduler (paper §V): scheduling space + block-wise strategy.

The model is a stack of *MoE blocks* (MoE layer + adjacent non-MoE layer).
Each op is ``comm`` or ``comp`` (Fig. 7):

  comp: Plan, FEC, FNEC, BEC, BNEC
  comm: Trans, Agg, A2A (×4 per block per iteration)

Scheduling space (Fig. 8), reproduced as dependency rewrites:

  * ``Plan_i^{j+1}`` may start as early as block i's a2a of iteration j
    (needs iteration j's distribution — the locality prediction).
  * ``Trans_{i+1}^j`` overlaps the forward computations of block i
    (within-iteration, for universality across optimizer-update styles).
  * ``Agg_{i+1}^j`` overlaps the backward computations of block i.

Block-wise sub-operator strategy (Alg. 2): Trans_{i+1} is *split* into
SubTrans1 ∥ FEC_i and SubTrans2 ∥ FNEC_i; Agg_{i+1} into SubAgg1 ∥ BNEC_i
and SubAgg2 ∥ BEC_i.  The split sizes come from the statically-known
non-MoE durations (paper: "the forward computation overhead of the non-MoE
layer and the transferring overhead of an expert's parameters are static").

Everything here is an analytical timeline over two serial resources per
device group — one comm stream, one comp stream — which is exactly the
abstraction the paper's figures use.  This module is what the planner's
eq. 8 coupling and the ablation/overlap benchmarks reason with.

Scheduler → runtime: this scheduling space is no longer only analytical.
The device-side hot path (:mod:`repro.models.moe`) realizes it directly —
the expert a2a→FEC→a2a path is split into K capacity-axis chunks whose
send/compute/return ops carry no cross-chunk dependencies, so XLA's async
collective scheduler overlaps a2a(chunk k+1) with the ragged FEC of chunk
k (forward and backward), and the shadow ``Trans`` psum is hoisted ahead
of the a2a path so it rides under the first chunk.  The chunk count K is
chosen *here*: :func:`choose_chunks` minimizes the list-scheduled makespan
of :func:`chunked_expert_graph` on the engine's profiled per-layer stats
(``REPRO_A2A_CHUNKS`` overrides; K=1 reproduces the serial path
bit-identically).  :meth:`repro.core.perfmodel.PerfModel.chunked_expert_time`
is the closed form of the same timeline (validated against it in
``benchmarks/perfmodel_accuracy.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Literal, Optional, Sequence

Strategy = Literal["sequential", "operator", "blockwise"]


@dataclasses.dataclass
class Op:
    name: str
    kind: Literal["comm", "comp"]
    duration: float
    deps: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Placed:
    name: str
    kind: str
    start: float
    end: float


@dataclasses.dataclass
class Timeline:
    ops: List[Placed]

    @property
    def makespan(self) -> float:
        return max((o.end for o in self.ops), default=0.0)

    def span(self, name: str) -> Placed:
        for o in self.ops:
            if o.name == name:
                return o
        raise KeyError(name)

    def validate(self, graph: Sequence[Op]) -> None:
        """Assert no dependency or resource-serialization violations."""
        by_name = {o.name: o for o in self.ops}
        for op in graph:
            for d in op.deps:
                assert by_name[d].end <= by_name[op.name].start + 1e-12, (
                    f"{op.name} starts before dep {d} ends")
        for kind in ("comm", "comp"):
            placed = sorted((o for o in self.ops if o.kind == kind),
                            key=lambda o: o.start)
            for a, b in zip(placed, placed[1:]):
                assert a.end <= b.start + 1e-12, (
                    f"resource overlap on {kind}: {a.name} vs {b.name}")


def list_schedule(graph: Sequence[Op]) -> Timeline:
    """ASAP list scheduling on two serial resources (comm / comp).

    Ops are considered in the given order (program order); each starts at
    ``max(deps end, resource free)``.  Program order ties are what the
    strategy builders below control.
    """
    end_of: Dict[str, float] = {}
    free = {"comm": 0.0, "comp": 0.0}
    placed: List[Placed] = []
    pending = list(graph)
    # Iterate until all placed; respect program order among ready ops.
    while pending:
        progressed = False
        for i, op in enumerate(pending):
            if all(d in end_of for d in op.deps):
                start = max([free[op.kind]] + [end_of[d] for d in op.deps])
                end = start + op.duration
                free[op.kind] = end
                end_of[op.name] = end
                placed.append(Placed(op.name, op.kind, start, end))
                pending.pop(i)
                progressed = True
                break
        if not progressed:
            raise ValueError("dependency cycle in op graph")
    return Timeline(placed)


@dataclasses.dataclass(frozen=True)
class BlockCosts:
    """Per-block op durations (seconds) feeding the timeline."""

    a2a: float       # one a2a (×2 fwd, ×2 bwd)
    fec: float
    bec: float
    fnec: float
    bnec: float
    trans: float
    agg: float
    plan: float = 0.0


def _block_costs(costs, i: int) -> BlockCosts:
    return costs[i] if isinstance(costs, (list, tuple)) else costs


def build_graph(num_blocks: int, costs, strategy: Strategy) -> List[Op]:
    """Emit the op graph of one iteration (fwd + bwd) under a strategy.

    * ``sequential`` — prior art's blocked execution: Plan→Trans→a2a→FEC→
      a2a→FNEC per block, then the backward mirror with Agg after BEC.
    * ``operator``   — whole-op scheduling: Trans_{i+1} ∥ FEC_i only
      (Fig. 9a); Agg_{i+1} ∥ BEC_i; Plan under a2a.
    * ``blockwise``  — Pro-Prophet (Alg. 2): sub-op splitting across both
      computations of the previous block.
    """
    ops: List[Op] = []
    prev = None  # name of the op that ends the previous program segment

    def add(name, kind, dur, deps):
        ops.append(Op(name, kind, dur, list(deps)))
        return name

    # ---------------- forward ----------------
    for i in range(num_blocks):
        c = _block_costs(costs, i)
        deps0 = [prev] if prev else []
        if strategy == "sequential":
            p = add(f"plan{i}", "comp", c.plan, deps0)
            t = add(f"trans{i}", "comm", c.trans, [p])
            a1 = add(f"a2a1_{i}", "comm", c.a2a, [t])
            f = add(f"fec{i}", "comp", c.fec, [a1])
            a2 = add(f"a2a2_{i}", "comm", c.a2a, [f])
            prev = add(f"fnec{i}", "comp", c.fnec, [a2])
        else:
            # Plan for the *next* iteration hides under this block's a2a —
            # zero-cost on the critical path; modeled as comp parallel op.
            a1 = add(f"a2a1_{i}", "comm", c.a2a, deps0)
            add(f"plan{i}", "comp", c.plan, deps0)
            f = add(f"fec{i}", "comp", c.fec, [a1])
            # Trans of block i+1 overlaps block i's computations.
            if i + 1 < num_blocks:
                cn = _block_costs(costs, i + 1)
                if strategy == "operator":
                    add(f"trans{i+1}", "comm", cn.trans, [a1])
                else:  # blockwise: split across FEC_i and FNEC_i windows
                    s1 = min(cn.trans, c.fec) if cn.trans > 0 else 0.0
                    s2 = cn.trans - s1
                    add(f"subtrans1_{i+1}", "comm", s1, [a1])
                    add(f"subtrans2_{i+1}", "comm", s2,
                        [f"subtrans1_{i+1}"])
            a2 = add(f"a2a2_{i}", "comm", c.a2a, [f])
            fn_deps = [a2]
            prev = add(f"fnec{i}", "comp", c.fnec, fn_deps)
        if i == 0 and strategy != "sequential":
            # Block 0's Trans cannot hide (no previous block): it fronts
            # the iteration, matching the paper's space (Fig. 8 starts
            # overlapping at block i+1).
            c0 = _block_costs(costs, 0)
            ops.insert(0, Op("trans0", "comm", c0.trans, []))
            for op in ops:
                if op.name == "a2a1_0":
                    op.deps.append("trans0")

    # ---------------- backward ----------------
    for bi in range(num_blocks - 1, -1, -1):
        c = _block_costs(costs, bi)
        if strategy == "sequential":
            bn = add(f"bnec{bi}", "comp", c.bnec, [prev])
            a3 = add(f"a2a3_{bi}", "comm", c.a2a, [bn])
            be = add(f"bec{bi}", "comp", c.bec, [a3])
            a4 = add(f"a2a4_{bi}", "comm", c.a2a, [be])
            prev = add(f"agg{bi}", "comm", c.agg, [a4])
        else:
            bn = add(f"bnec{bi}", "comp", c.bnec, [prev])
            a3 = add(f"a2a3_{bi}", "comm", c.a2a, [bn])
            be = add(f"bec{bi}", "comp", c.bec, [a3])
            prev = add(f"a2a4_{bi}", "comm", c.a2a, [be])
            # Agg of block bi+1 overlaps block bi's backward computations.
            if bi + 1 < num_blocks:
                cn = _block_costs(costs, bi + 1)
                if strategy == "operator":
                    add(f"agg{bi+1}", "comm", cn.agg, [f"a2a4_{bi+1}", bn])
                else:
                    s1 = min(cn.agg, c.bnec) if cn.agg > 0 else 0.0
                    s2 = cn.agg - s1
                    add(f"subagg1_{bi+1}", "comm", s1,
                        [f"a2a4_{bi+1}", bn])
                    add(f"subagg2_{bi+1}", "comm", s2, [f"subagg1_{bi+1}"])
    if strategy != "sequential":
        # Block 0's Agg tails the iteration (nothing left to hide under).
        c0 = _block_costs(costs, 0)
        if strategy == "operator":
            add("agg0", "comm", c0.agg, [prev])
        else:
            add("subagg1_0", "comm", c0.agg, [prev])
    return ops


def iteration_time(num_blocks: int, costs, strategy: Strategy) -> float:
    g = build_graph(num_blocks, costs, strategy)
    return list_schedule(g).makespan


def simulate(num_blocks: int, costs, strategy: Strategy) -> Timeline:
    g = build_graph(num_blocks, costs, strategy)
    tl = list_schedule(g)
    tl.validate(g)
    return tl


def split_trans(trans: float, fec: float, fnec: float) -> tuple[float, float]:
    """Static sub-op split (Alg. 2): fill the FEC window first, spill the
    remainder into the FNEC window.  Returns (subtrans1, subtrans2)."""
    s1 = min(trans, fec)
    return s1, trans - s1


# ---------------------------------------------------------------------------
# Chunked a2a↔FEC pipeline (the device-side realization's planning half)
# ---------------------------------------------------------------------------

def chunked_expert_graph(t_a2a: float, t_fec: float, num_chunks: int, *,
                         chunk_overhead: float = 0.0,
                         t_dispatch: float = 0.0,
                         t_combine: float = 0.0,
                         prefix: str = "") -> List[Op]:
    """Op graph of one chunked expert path: K send-a2a chunks, K FEC
    chunks, K return-a2a chunks on the (comm, comp) resources.

    ``t_a2a`` is ONE a2a of the full buffer (each chunk costs
    ``t_a2a/K + chunk_overhead``; likewise FEC).  Program order is
    sends-first — all send chunks are emitted before the fec/return
    pairs — which is the order the list scheduler arbitrates resource
    ties with, and the order the closed form in
    :meth:`repro.core.perfmodel.PerfModel.chunked_expert_time` models.

    ``t_dispatch``/``t_combine`` are the HBM-bound token-permutation
    legs (``PerfModel.t_dispatch``/``t_combine``): the dispatch scatter
    produces the capacity buffer every send chunk slices, so it fronts
    the pipeline on the comp stream; the gate combine consumes the full
    returned buffer, so it tails it.  Neither can overlap the chunks
    they serialize with — which is exactly why the device path moved
    them into the load-proportional kernels.
    """
    K = max(1, int(num_chunks))
    a = t_a2a / K + chunk_overhead
    f = t_fec / K + chunk_overhead
    # Zero-cost permute legs are elided so the zero-term graph (and its
    # op count) is exactly the pre-permute pipeline.
    ops = ([Op(f"{prefix}dispatch", "comp", t_dispatch, [])]
           if t_dispatch > 0.0 else [])
    send_deps = [f"{prefix}dispatch"] if t_dispatch > 0.0 else []
    ops += [Op(f"{prefix}a2a1_c{k}", "comm", a, list(send_deps))
            for k in range(K)]
    for k in range(K):
        ops.append(Op(f"{prefix}fec_c{k}", "comp", f,
                      [f"{prefix}a2a1_c{k}"]))
        ops.append(Op(f"{prefix}a2a2_c{k}", "comm", a,
                      [f"{prefix}fec_c{k}"]))
    if t_combine > 0.0:
        ops.append(Op(f"{prefix}combine", "comp", t_combine,
                      [f"{prefix}a2a2_c{k}" for k in range(K)]))
    return ops


def chunked_makespan(t_a2a: float, t_fec: float, num_chunks: int, *,
                     chunk_overhead: float = 0.0,
                     t_dispatch: float = 0.0,
                     t_combine: float = 0.0) -> float:
    """List-scheduled makespan of the K-chunk a2a→FEC→a2a pipeline
    (plus the serial dispatch/combine permute legs).  K=1 with zero
    permute terms degenerates to the serial chain ``2·t_a2a + t_fec``.
    This is the reference implementation (graph + validation); the
    per-step hot path uses :func:`chunked_makespan_closed`."""
    g = chunked_expert_graph(t_a2a, t_fec, num_chunks,
                             chunk_overhead=chunk_overhead,
                             t_dispatch=t_dispatch, t_combine=t_combine)
    tl = list_schedule(g)
    tl.validate(g)
    return tl.makespan


def chunked_makespan_closed(t_a2a: float, t_fec: float, num_chunks: int, *,
                            chunk_overhead: float = 0.0,
                            t_dispatch: float = 0.0,
                            t_combine: float = 0.0) -> float:
    """Closed form of :func:`chunked_makespan` — exact for the
    sends-first program order (asserted equal in tests/test_scheduler.py
    and benchmarks/perfmodel_accuracy.py).  With per-chunk costs
    ``a = t_a2a/K + h`` and ``f = t_fec/K + h`` the binding constraint
    is the serial comm stream (``2Ka``), the send-pipeline fill plus one
    compute chunk (``(K+1)a + f``), or the serial compute stream plus
    fill/drain a2a chunks (``Kf + 2a``).  The dispatch leg shifts the
    whole pipeline (every send depends on it; the comp stream is free
    again by the time the first FEC chunk is ready) and the combine leg
    appends after the last return, so both add linearly.  This is what
    the engine's per-dispatch chunk choice and telemetry evaluate."""
    K = max(1, int(num_chunks))
    a = t_a2a / K + chunk_overhead
    f = t_fec / K + chunk_overhead
    base = max(2.0 * K * a, (K + 1) * a + f, K * f + 2.0 * a)
    return t_dispatch + base + t_combine


def choose_chunks(t_a2a: float, t_fec: float, *,
                  candidates: Sequence[int] = (1, 2, 4, 8),
                  chunk_overhead: float = 0.0,
                  t_dispatch: float = 0.0,
                  t_combine: float = 0.0) -> int:
    """Chunk count minimizing the pipeline makespan (smallest K on ties,
    so zero-benefit loads — tiny a2a, or overhead-dominated chunking —
    keep the bit-identical K=1 path).  The serial permute legs shift
    every candidate equally, so they never flip the argmin — they are
    accepted so callers can score the same timeline they report."""
    best_k, best_t = 1, float("inf")
    for k in sorted(set(int(c) for c in candidates if c >= 1)):
        t = chunked_makespan_closed(t_a2a, t_fec, k,
                                    chunk_overhead=chunk_overhead,
                                    t_dispatch=t_dispatch,
                                    t_combine=t_combine)
        if t < best_t - 1e-15:
            best_k, best_t = k, t
    return best_k


def hidden_comm_fraction(t_a2a: float, t_fec: float, num_chunks: int, *,
                         chunk_overhead: float = 0.0) -> float:
    """Fraction of the path's a2a time (2·t_a2a) the K-chunk pipeline
    hides under expert compute, per the timeline: 0 at K=1, up to 1 when
    the ragged FEC fully covers the communication."""
    if t_a2a <= 0.0:
        return 0.0
    serial = chunked_makespan_closed(t_a2a, t_fec, 1)
    m = chunked_makespan_closed(t_a2a, t_fec, num_chunks,
                                chunk_overhead=chunk_overhead)
    return max(0.0, min(1.0, (serial - m) / (2.0 * t_a2a)))
