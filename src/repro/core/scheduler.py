"""Pro-Prophet scheduler (paper §V): scheduling space + block-wise strategy.

The model is a stack of *MoE blocks* (MoE layer + adjacent non-MoE layer).
Each op is ``comm`` or ``comp`` (Fig. 7):

  comp: Plan, FEC, FNEC, BEC, BNEC
  comm: Trans, Agg, A2A (×4 per block per iteration)

Scheduling space (Fig. 8), reproduced as dependency rewrites:

  * ``Plan_i^{j+1}`` may start as early as block i's a2a of iteration j
    (needs iteration j's distribution — the locality prediction).
  * ``Trans_{i+1}^j`` overlaps the forward computations of block i
    (within-iteration, for universality across optimizer-update styles).
  * ``Agg_{i+1}^j`` overlaps the backward computations of block i.

Block-wise sub-operator strategy (Alg. 2): Trans_{i+1} is *split* into
SubTrans1 ∥ FEC_i and SubTrans2 ∥ FNEC_i; Agg_{i+1} into SubAgg1 ∥ BNEC_i
and SubAgg2 ∥ BEC_i.  The split sizes come from the statically-known
non-MoE durations (paper: "the forward computation overhead of the non-MoE
layer and the transferring overhead of an expert's parameters are static").

Everything here is an analytical timeline over two serial resources per
device group — one comm stream, one comp stream — which is exactly the
abstraction the paper's figures use.  The TPU runtime realization of the
same idea (hoisting shadow collectives so XLA's async scheduler can overlap
them) lives in :mod:`repro.parallel.ep`; this module is what the planner's
eq. 8 coupling and the ablation/overlap benchmarks reason with.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Literal, Optional, Sequence

Strategy = Literal["sequential", "operator", "blockwise"]


@dataclasses.dataclass
class Op:
    name: str
    kind: Literal["comm", "comp"]
    duration: float
    deps: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Placed:
    name: str
    kind: str
    start: float
    end: float


@dataclasses.dataclass
class Timeline:
    ops: List[Placed]

    @property
    def makespan(self) -> float:
        return max((o.end for o in self.ops), default=0.0)

    def span(self, name: str) -> Placed:
        for o in self.ops:
            if o.name == name:
                return o
        raise KeyError(name)

    def validate(self, graph: Sequence[Op]) -> None:
        """Assert no dependency or resource-serialization violations."""
        by_name = {o.name: o for o in self.ops}
        for op in graph:
            for d in op.deps:
                assert by_name[d].end <= by_name[op.name].start + 1e-12, (
                    f"{op.name} starts before dep {d} ends")
        for kind in ("comm", "comp"):
            placed = sorted((o for o in self.ops if o.kind == kind),
                            key=lambda o: o.start)
            for a, b in zip(placed, placed[1:]):
                assert a.end <= b.start + 1e-12, (
                    f"resource overlap on {kind}: {a.name} vs {b.name}")


def list_schedule(graph: Sequence[Op]) -> Timeline:
    """ASAP list scheduling on two serial resources (comm / comp).

    Ops are considered in the given order (program order); each starts at
    ``max(deps end, resource free)``.  Program order ties are what the
    strategy builders below control.
    """
    end_of: Dict[str, float] = {}
    free = {"comm": 0.0, "comp": 0.0}
    placed: List[Placed] = []
    pending = list(graph)
    # Iterate until all placed; respect program order among ready ops.
    while pending:
        progressed = False
        for i, op in enumerate(pending):
            if all(d in end_of for d in op.deps):
                start = max([free[op.kind]] + [end_of[d] for d in op.deps])
                end = start + op.duration
                free[op.kind] = end
                end_of[op.name] = end
                placed.append(Placed(op.name, op.kind, start, end))
                pending.pop(i)
                progressed = True
                break
        if not progressed:
            raise ValueError("dependency cycle in op graph")
    return Timeline(placed)


@dataclasses.dataclass(frozen=True)
class BlockCosts:
    """Per-block op durations (seconds) feeding the timeline."""

    a2a: float       # one a2a (×2 fwd, ×2 bwd)
    fec: float
    bec: float
    fnec: float
    bnec: float
    trans: float
    agg: float
    plan: float = 0.0


def _block_costs(costs, i: int) -> BlockCosts:
    return costs[i] if isinstance(costs, (list, tuple)) else costs


def build_graph(num_blocks: int, costs, strategy: Strategy) -> List[Op]:
    """Emit the op graph of one iteration (fwd + bwd) under a strategy.

    * ``sequential`` — prior art's blocked execution: Plan→Trans→a2a→FEC→
      a2a→FNEC per block, then the backward mirror with Agg after BEC.
    * ``operator``   — whole-op scheduling: Trans_{i+1} ∥ FEC_i only
      (Fig. 9a); Agg_{i+1} ∥ BEC_i; Plan under a2a.
    * ``blockwise``  — Pro-Prophet (Alg. 2): sub-op splitting across both
      computations of the previous block.
    """
    ops: List[Op] = []
    prev = None  # name of the op that ends the previous program segment

    def add(name, kind, dur, deps):
        ops.append(Op(name, kind, dur, list(deps)))
        return name

    # ---------------- forward ----------------
    for i in range(num_blocks):
        c = _block_costs(costs, i)
        deps0 = [prev] if prev else []
        if strategy == "sequential":
            p = add(f"plan{i}", "comp", c.plan, deps0)
            t = add(f"trans{i}", "comm", c.trans, [p])
            a1 = add(f"a2a1_{i}", "comm", c.a2a, [t])
            f = add(f"fec{i}", "comp", c.fec, [a1])
            a2 = add(f"a2a2_{i}", "comm", c.a2a, [f])
            prev = add(f"fnec{i}", "comp", c.fnec, [a2])
        else:
            # Plan for the *next* iteration hides under this block's a2a —
            # zero-cost on the critical path; modeled as comp parallel op.
            a1 = add(f"a2a1_{i}", "comm", c.a2a, deps0)
            add(f"plan{i}", "comp", c.plan, deps0)
            f = add(f"fec{i}", "comp", c.fec, [a1])
            # Trans of block i+1 overlaps block i's computations.
            if i + 1 < num_blocks:
                cn = _block_costs(costs, i + 1)
                if strategy == "operator":
                    add(f"trans{i+1}", "comm", cn.trans, [a1])
                else:  # blockwise: split across FEC_i and FNEC_i windows
                    s1 = min(cn.trans, c.fec) if cn.trans > 0 else 0.0
                    s2 = cn.trans - s1
                    add(f"subtrans1_{i+1}", "comm", s1, [a1])
                    add(f"subtrans2_{i+1}", "comm", s2,
                        [f"subtrans1_{i+1}"])
            a2 = add(f"a2a2_{i}", "comm", c.a2a, [f])
            fn_deps = [a2]
            prev = add(f"fnec{i}", "comp", c.fnec, fn_deps)
        if i == 0 and strategy != "sequential":
            # Block 0's Trans cannot hide (no previous block): it fronts
            # the iteration, matching the paper's space (Fig. 8 starts
            # overlapping at block i+1).
            c0 = _block_costs(costs, 0)
            ops.insert(0, Op("trans0", "comm", c0.trans, []))
            for op in ops:
                if op.name == "a2a1_0":
                    op.deps.append("trans0")

    # ---------------- backward ----------------
    for bi in range(num_blocks - 1, -1, -1):
        c = _block_costs(costs, bi)
        if strategy == "sequential":
            bn = add(f"bnec{bi}", "comp", c.bnec, [prev])
            a3 = add(f"a2a3_{bi}", "comm", c.a2a, [bn])
            be = add(f"bec{bi}", "comp", c.bec, [a3])
            a4 = add(f"a2a4_{bi}", "comm", c.a2a, [be])
            prev = add(f"agg{bi}", "comm", c.agg, [a4])
        else:
            bn = add(f"bnec{bi}", "comp", c.bnec, [prev])
            a3 = add(f"a2a3_{bi}", "comm", c.a2a, [bn])
            be = add(f"bec{bi}", "comp", c.bec, [a3])
            prev = add(f"a2a4_{bi}", "comm", c.a2a, [be])
            # Agg of block bi+1 overlaps block bi's backward computations.
            if bi + 1 < num_blocks:
                cn = _block_costs(costs, bi + 1)
                if strategy == "operator":
                    add(f"agg{bi+1}", "comm", cn.agg, [f"a2a4_{bi+1}", bn])
                else:
                    s1 = min(cn.agg, c.bnec) if cn.agg > 0 else 0.0
                    s2 = cn.agg - s1
                    add(f"subagg1_{bi+1}", "comm", s1,
                        [f"a2a4_{bi+1}", bn])
                    add(f"subagg2_{bi+1}", "comm", s2, [f"subagg1_{bi+1}"])
    if strategy != "sequential":
        # Block 0's Agg tails the iteration (nothing left to hide under).
        c0 = _block_costs(costs, 0)
        if strategy == "operator":
            add("agg0", "comm", c0.agg, [prev])
        else:
            add("subagg1_0", "comm", c0.agg, [prev])
    return ops


def iteration_time(num_blocks: int, costs, strategy: Strategy) -> float:
    g = build_graph(num_blocks, costs, strategy)
    return list_schedule(g).makespan


def simulate(num_blocks: int, costs, strategy: Strategy) -> Timeline:
    g = build_graph(num_blocks, costs, strategy)
    tl = list_schedule(g)
    tl.validate(g)
    return tl


def split_trans(trans: float, fec: float, fnec: float) -> tuple[float, float]:
    """Static sub-op split (Alg. 2): fill the FEC window first, spill the
    remainder into the FNEC window.  Returns (subtrans1, subtrans2)."""
    s1 = min(trans, fec)
    return s1, trans - s1
