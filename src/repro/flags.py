"""Experiment flags for §Perf hillclimbing (env-var driven so a dry-run
probe can flip one optimization at a time without code edits).

REPRO_TRANS_SHARDED=1   Trans psum runs on FSDP-sharded expert weights
                        (per-shard bytes over the EP axis), shadow params
                        gathered afterwards — instead of psum'ing the
                        fully-gathered weights.  Beyond-paper: cuts the
                        Trans all-reduce volume by the FSDP factor.
REPRO_XENT_CHUNK=N      Vocab-chunked streaming cross-entropy: never
                        materializes the [B,S,V] logits (N = vocab chunk).
REPRO_SEQ_PARALLEL=1    Sequence-parallel activation constraints between
                        blocks (Korthikanti-style): activations sharded
                        over the model axis on S between layers.
REPRO_CAPACITY_FACTOR=x Override MoE capacity factor (a2a volume lever).
REPRO_GQA_FLASH=1       Route big-shape attention through the chunked
                        online-softmax path with a larger q_block.
REPRO_MOE_PALLAS=0/1    Expert FFN through the ragged Pallas kernels
                        (repro.kernels.ragged_gmm): grouped matmul skips
                        tiles past each expert's actual token count and
                        the SwiGLU gate is fused into the epilogue.
                        Unset ⇒ on for TPU backends, off elsewhere
                        (=1 forces it on anywhere via interpret mode).
REPRO_DISPATCH_PALLAS=0/1  Token permutation (capacity dispatch/combine)
                        through the Pallas kernels
                        (repro.kernels.token_permute): dispatch becomes
                        a sorted gather (no [N·k, d] activation repeat,
                        no serialized scatter-add) and combine fuses the
                        gate-weighted k-way reduction into the gather
                        epilogue (f32 register accumulation — no
                        [N, k, d] f32 materialization).  Unset ⇒ on for
                        TPU backends, off elsewhere (=1 forces it on
                        anywhere via interpret mode, =0 forces the jnp
                        scatter/gather path, which stays bit-identical
                        to the pre-kernel implementation).
REPRO_A2A_CHUNKS=K      Manual override of the a2a↔FEC chunk count: the
                        MoE expert path splits its [E, C, d] capacity
                        buffer into K chunks along the capacity axis and
                        software-pipelines all_to_all(chunk k+1) against
                        expert_ffn(chunk k) — forward and backward — so
                        the data-dependent communication hides under the
                        ragged Pallas gmm (paper §V, realized on-device
                        in repro.models.moe).  K=1 reproduces the
                        unchunked path bit-identically.  Unset ⇒ the
                        engine picks K per layer from the scheduler's
                        analytical timeline on the profiled routing stats
                        (core/scheduler.py choose_chunks).  Read at trace
                        time like all flags here: set it before the
                        process jits (the trainer re-reads it per
                        dispatch and re-keys its jit cache).
REPRO_MIGRATION=0/1     Dynamic expert migration (owner re-layout): the
                        planner scores migrate-vs-shadow per greedy move
                        (core/planner.py strategy "both") and the trainer
                        executes the resulting relocations as infrequent
                        EP-axis weight/optimizer exchanges.  Unset ⇒ the
                        EngineConfig.enable_migration policy decides
                        (default off; disabled is bit-identical to the
                        shadow-only planner).
REPRO_FORECAST=0/1      Predictive load planning: a per-layer EMA
                        forecaster (core/forecast.py) classifies layers
                        fluctuating | drifting | stable, the planner
                        consumes the *forecast* for step j+1 instead of
                        step j−1's raw counts, and stable layers back
                        their replan cadence off exponentially (bounded
                        by REPRO_PLAN_CADENCE_MAX, reset the moment the
                        layer drifts).  Unset ⇒ the
                        EngineConfig.enable_forecast policy decides
                        (default off; disabled is bit-identical to the
                        last-value planner).
REPRO_PLAN_CADENCE_MAX=N  Upper bound of the forecast-driven cadence
                        backoff: a stable layer's replan interval
                        doubles after each executed search up to N
                        observations (default 16).  Larger ⇒ less host
                        plan work and fewer PlacementCache uploads in
                        the stabilized regime, slower reaction if the
                        stability detector misses a shift (the
                        fluctuating flag still forces an immediate
                        replan regardless of the backoff).
REPRO_RELOC_PREFETCH=0/1  Prefetched relocation: a pending owner
                        re-layout is dispatched once more on the old
                        device layout while the non-donating exchange is
                        issued *under* that step (queued behind it on
                        the device stream), and the pre-staged slabs are
                        swapped in at the next dispatch after the
                        fingerprint round-trip verifies — the exchange
                        transfer leaves the dispatch critical path.
                        Unset ⇒ the Trainer.reloc_prefetch policy
                        decides (default off; the relocation then runs
                        synchronously at dispatch as before).  Either
                        way the transactional verify/rollback and the
                        retry-once policy apply, and losses stay
                        bit-identical — placements and relocation timing
                        only decide *where/when* compute happens.
REPRO_PLAN_DEADLINE_MS=N  Plan watchdog deadline: a Plan primitive whose
                        host latency exceeds N milliseconds is treated as
                        failed — the engine rolls back to the last-good
                        placements (training continues on stale
                        placements, never blocks on a wedged planner) and
                        the fallback is counted in StepStats/
                        OverlapTelemetry.  Unset or 0 ⇒ no deadline.
REPRO_ASYNC_PLAN=0/1    Trainer runtime selection (escape hatch).  Unset
                        or 1 ⇒ the pipelined async runtime: the Plan
                        primitive (engine.observe + the per-layer greedy
                        searches) runs on a background planner thread
                        overlapped with device execution, placements are
                        uploaded only when they change, and loss
                        consumption is one step delayed.  =0 forces the
                        serial baseline (dispatch → block on loss → plan
                        inline).  Both runtimes are bit-identical in
                        losses and placements — planning is one-step-
                        delayed by design — so this only moves *when*
                        host work happens (tests/test_async_runtime.py).
REPRO_NORM_BF16=1       RMSNorm keeps the normalization in bf16 (variance
                        still f32-accumulated) so delayed TP all-reduces
                        of the backward move bf16 tensors (§Perf
                        collective lever; only active on bf16 inputs).
REPRO_ATTN_BF16_SCORES=1  Chunked-attention score einsums read bf16
                        operands with f32 accumulation via
                        preferred_element_type — halves score-traffic
                        bytes with the same f32 softmax statistics
                        (§Perf memory lever).
REPRO_ATTN_NAIVE_MAX=N  Sequence-length threshold below which attn_impl
                        "auto" picks the naive-scores path over the
                        chunked lax.map path (default 2048; §Perf lever —
                        naive + head-TP + remat beats chunked at moderate
                        S, whose q-block loop forces SPMD involuntary-
                        remat all-gathers).
REPRO_PIN_NORM=1        Constrain rmsnorm outputs to P(batch, None, None)
                        so the TP backward all-reduces ONE bf16 cotangent
                        at the boundary instead of three f32 x-shaped
                        intermediates inside the norm's backward (§Perf).
REPRO_HEALTH=0/1        Device health tracking (core/health.py): the
                        engine ingests measured per-device step timings,
                        classifies each EP rank healthy | degraded |
                        lost (EMA ratio vs the fleet median, with
                        patience), and re-prices the perf model with the
                        resulting throughput factors so planning drains
                        hot experts off slow ranks.  Unset ⇒ the
                        EngineConfig.enable_health policy decides
                        (default off; disabled never touches the tracker
                        and pricing stays bit-identical to the
                        homogeneous model).
REPRO_EVACUATE=0/1      Expert evacuation: when a rank is classified
                        *lost*, the planner force-moves its resident
                        experts onto the survivors (slot swaps + shadows
                        through the ordinary relocation path) before the
                        voluntary balance search.  Unset ⇒ the
                        EngineConfig.enable_evacuation policy decides
                        (default on — only reachable when health
                        tracking reports a lost device).
REPRO_RELOC_RETRY_MAX=N  Bound on consecutive relocation-exchange
                        retries when the failure is attributed to a
                        degraded/lost device (default 3; the legacy
                        retry-once policy applies when the fleet is
                        healthy).  After N failed attempts the pending
                        relocation is cancelled and the planner falls
                        back to shadow-only balancing.
REPRO_RELOC_BACKOFF=N   Steps to wait after a failed degraded-mode
                        relocation attempt before retrying, doubled per
                        consecutive failure (default 2).
REPRO_SANITIZE=1        Runtime sanitizer mode (repro.train.sanitize):
                        arms jax.transfer_guard("disallow") around the
                        trainer's step dispatch (any implicit host↔device
                        transfer on the hot path raises instead of
                        silently serializing), enables jax_debug_nans /
                        jax_debug_infs, and switches PlacementCache into
                        its torn-read assertion mode (the placement
                        version is re-read after the re-pack; a
                        background bump mid-pack raises TornReadError
                        instead of dispatching a torn placement).  The
                        static twin of these checks is
                        tools/prophetlint (scripts/ci.sh --lint).

All accessors in this module re-read their env var on every call (so
tests and dry-run probes can flip a flag mid-process); only the backend
probe below is cached, because jax pins the default backend at init.
prophetlint rule R2 (env-discipline) keeps this module — plus launch/ —
the only place ``os.environ`` is consulted.
"""
import os


def _flag(name: str, default: str = "0") -> str:
    return os.environ.get(name, default)


def trans_sharded() -> bool:
    return _flag("REPRO_TRANS_SHARDED") == "1"


def xent_chunk() -> int:
    return int(_flag("REPRO_XENT_CHUNK", "0"))


def seq_parallel() -> bool:
    return _flag("REPRO_SEQ_PARALLEL") == "1"


def capacity_factor_override():
    v = _flag("REPRO_CAPACITY_FACTOR", "")
    return float(v) if v else None


# The default backend cannot change after jax initializes, so probe it
# once per process instead of re-importing jax + calling
# jax.default_backend() on every trace-time flag read (moe_pallas is
# consulted per MoE layer per trace).  The env var itself stays re-read
# on every call, like every other flag in this module.
_DEFAULT_BACKEND: str | None = None


def _default_backend() -> str:
    global _DEFAULT_BACKEND
    if _DEFAULT_BACKEND is None:
        import jax
        _DEFAULT_BACKEND = jax.default_backend()
    return _DEFAULT_BACKEND


def moe_pallas() -> bool:
    """Ragged-Pallas expert FFN: default on for TPU, opt-in elsewhere."""
    v = _flag("REPRO_MOE_PALLAS", "")
    if v == "":
        return _default_backend() == "tpu"
    return v == "1"


def dispatch_pallas() -> bool:
    """Pallas token permutation (capacity dispatch/combine): default on
    for TPU, opt-in elsewhere — mirrors :func:`moe_pallas`."""
    v = _flag("REPRO_DISPATCH_PALLAS", "")
    if v == "":
        return _default_backend() == "tpu"
    return v == "1"


def a2a_chunks():
    """REPRO_A2A_CHUNKS=K: force the a2a↔FEC chunk count everywhere
    (None ⇒ unset; the engine's scheduler-driven per-layer choice, or 1
    where no engine runs).  See the module docstring."""
    v = _flag("REPRO_A2A_CHUNKS", "")
    return max(1, int(v)) if v else None


def plan_deadline_ms() -> float:
    """REPRO_PLAN_DEADLINE_MS: watchdog deadline for the Plan primitive
    in milliseconds (0.0 ⇒ disabled).  A plan finishing past the deadline
    is discarded and the engine falls back to the last-good placements —
    see the module docstring and repro.train.runtime.run_plan."""
    v = _flag("REPRO_PLAN_DEADLINE_MS", "")
    return float(v) if v else 0.0


def async_plan() -> bool:
    """Pipelined trainer runtime: default on; REPRO_ASYNC_PLAN=0 forces
    the fully-serial baseline (see module docstring)."""
    return _flag("REPRO_ASYNC_PLAN", "1") != "0"


def migration():
    """REPRO_MIGRATION=0/1: override the engine's dynamic expert
    migration policy (EngineConfig.enable_migration).  Unset ⇒ None (the
    engine config decides; default off — the disabled path is
    bit-identical to the shadow-only planner)."""
    v = _flag("REPRO_MIGRATION", "")
    return None if v == "" else v == "1"


def forecast():
    """REPRO_FORECAST=0/1: override the engine's predictive-planning
    policy (EngineConfig.enable_forecast).  Unset ⇒ None (the engine
    config decides; default off — the disabled path is bit-identical to
    the last-value planner)."""
    v = _flag("REPRO_FORECAST", "")
    return None if v == "" else v == "1"


def plan_cadence_max() -> int:
    """REPRO_PLAN_CADENCE_MAX: bound of the forecast-driven exponential
    cadence backoff, in observations between replans of a stable layer
    (default 16).  See the module docstring."""
    v = _flag("REPRO_PLAN_CADENCE_MAX", "")
    return max(1, int(v)) if v else 16


def reloc_prefetch():
    """REPRO_RELOC_PREFETCH=0/1: override the trainer's prefetched-
    relocation policy (Trainer.reloc_prefetch).  Unset ⇒ None (the
    trainer field decides; default off — relocations then execute
    synchronously at dispatch)."""
    v = _flag("REPRO_RELOC_PREFETCH", "")
    return None if v == "" else v == "1"


def health():
    """REPRO_HEALTH=0/1: override the engine's device-health-tracking
    policy (EngineConfig.enable_health).  Unset ⇒ None (the engine
    config decides; default off — the disabled path never consults the
    tracker and keeps pricing bit-identical)."""
    v = _flag("REPRO_HEALTH", "")
    return None if v == "" else v == "1"


def evacuate():
    """REPRO_EVACUATE=0/1: override the planner's expert-evacuation
    policy (EngineConfig.enable_evacuation).  Unset ⇒ None (the engine
    config decides; default on — only reachable when health tracking
    reports a lost device)."""
    v = _flag("REPRO_EVACUATE", "")
    return None if v == "" else v == "1"


def reloc_retry_max() -> int:
    """REPRO_RELOC_RETRY_MAX: consecutive relocation-exchange retries
    allowed when the failure is attributed to a degraded/lost device
    (default 3).  See the module docstring."""
    v = _flag("REPRO_RELOC_RETRY_MAX", "")
    return max(1, int(v)) if v else 3


def reloc_backoff() -> int:
    """REPRO_RELOC_BACKOFF: base steps to hold off after a failed
    degraded-mode relocation attempt, doubled per consecutive failure
    (default 2).  See the module docstring."""
    v = _flag("REPRO_RELOC_BACKOFF", "")
    return max(1, int(v)) if v else 2


def norm_bf16() -> bool:
    """REPRO_NORM_BF16=1: bf16 RMSNorm normalization (f32-accumulated
    variance) — see the module docstring."""
    return _flag("REPRO_NORM_BF16") == "1"


def attn_bf16_scores() -> bool:
    """REPRO_ATTN_BF16_SCORES=1: bf16 operands / f32 accumulation for the
    chunked-attention score einsums — see the module docstring."""
    return _flag("REPRO_ATTN_BF16_SCORES") == "1"


def attn_naive_max() -> int:
    """REPRO_ATTN_NAIVE_MAX: max sequence length for the naive-scores
    auto-impl choice (default 2048) — see the module docstring."""
    v = _flag("REPRO_ATTN_NAIVE_MAX", "")
    return int(v) if v else 2048


def pin_norm() -> bool:
    """REPRO_PIN_NORM=1: constrain rmsnorm outputs to
    P(batch, None, None) — see the module docstring."""
    return _flag("REPRO_PIN_NORM") == "1"


def sanitize() -> bool:
    """REPRO_SANITIZE=1: runtime sanitizer mode (transfer guard around
    dispatch, debug_nans/debug_infs, PlacementCache torn-read assertions)
    — see the module docstring and repro.train.sanitize."""
    return _flag("REPRO_SANITIZE") == "1"


def pin_residual() -> bool:
    """REPRO_PIN_RESIDUAL=1: constrain the residual stream to
    P(batch, None, None) at sublayer boundaries so the MoE's
    all-axes token sharding cannot propagate into attention internals
    (which triggers SPMD involuntary-remat all-gathers)."""
    return _flag("REPRO_PIN_RESIDUAL") == "1"
