#!/usr/bin/env bash
# Tier-1 verify — the single entry point CI and humans share (ROADMAP.md).
# Extra args pass through to pytest, e.g.  scripts/ci.sh -m 'not slow'
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
