#!/usr/bin/env bash
# Tier-1 verify — the single entry point CI and humans share (ROADMAP.md).
#
#   scripts/ci.sh             full suite (~10 min)
#   scripts/ci.sh --faults    fault-injection lane only: the self-healing
#                             runtime under deterministic injected faults
#                             (tests/test_resilience.py — plan watchdog
#                             fallback/rollback, transactional relocation,
#                             atomic/torn checkpoints, and the 12-step
#                             loss-bit-identity acceptance run — plus
#                             tests/test_health.py for the degraded-mode
#                             fault kinds: straggler and
#                             degraded_throughput re-price the perf model
#                             and drain hot experts off slow ranks;
#                             device_loss classifies the rank lost and
#                             force-evacuates every resident expert
#                             through the ordinary relocation path)
#   scripts/ci.sh --forecast  predictive-planning lane only: the load
#                             forecaster + plan-cadence backoff +
#                             prefetched relocation (tests/
#                             test_forecast.py — forecaster property
#                             tests, engine backoff/reset, snapshot
#                             rollback of the forecast state, the
#                             forecast_sweep acceptance ratios, and the
#                             forecast+prefetch ≡ per-step-sync loss
#                             bit-identity run)
#   scripts/ci.sh --fast      fast lane: skips @slow (multi-device
#                             subprocesses, long end-to-end trainer runs)
#                             but keeps the async≡sync equivalence tests
#                             (tests/test_async_runtime.py is not slow),
#                             the chunked a2a↔FEC equivalence sweep
#                             (tests/test_moe.py::TestChunkedA2aPipeline
#                             runs K∈{1,2,3,4} single-device; the (2,4)
#                             mesh subprocess sweep is @slow in
#                             tests/test_distributed.py), and the dynamic
#                             expert-migration fast lane
#                             (tests/test_migration.py: planner/placement
#                             units, single-device relocation
#                             bit-equivalence, and the migration-disabled
#                             guard TestDisabledPathGuard — catches
#                             numeric drift of the owner threading
#                             without subprocesses).  The (2,4)-mesh
#                             migration run is @slow:
#                             tests/test_distributed.py::
#                             test_migration_mesh_equivalence
#                             The token-permutation kernels
#                             (tests/test_token_permute.py: dispatch/
#                             combine oracle + VJP sweeps, the
#                             capacity_positions micro-opt oracle, the
#                             hypothesis property suite, and the
#                             REPRO_DISPATCH_PALLAS on/off layer
#                             equivalence for K∈{1,2,4}) are all fast
#                             lane; the (2,4)-mesh on/off sweep is
#                             @slow: tests/test_distributed.py::
#                             test_dispatch_pallas_mesh_equivalence
#   scripts/ci.sh --lint      static-analysis lane only: prophetlint
#                             (tools/prophetlint — host-sync, env
#                             discipline, jit-cache boundedness,
#                             shared-state registries, Pallas kernel
#                             contracts; see README.md §Static analysis
#                             & sanitizers) plus ruff (committed
#                             ruff.toml) when installed.  The lane also
#                             runs at the start of the default full
#                             suite.
#
# Extra args pass through to pytest, e.g.  scripts/ci.sh -k planner
set -euo pipefail
cd "$(dirname "$0")/.."

run_lint() {
  python -m tools.prophetlint src
  if command -v ruff >/dev/null 2>&1; then
    ruff check
  else
    echo "lint: ruff not installed — skipping the style pass" \
         "(pinned in requirements-dev.txt)"
  fi
}

if [[ "${1:-}" == "--lint" ]]; then
  shift
  run_lint
  exit 0
fi
if [[ $# -eq 0 ]]; then
  run_lint          # the default full run gates on the lint lane too
fi
if [[ "${1:-}" == "--fast" ]]; then
  shift
  set -- -m "not slow" "$@"
elif [[ "${1:-}" == "--faults" ]]; then
  shift
  set -- tests/test_resilience.py tests/test_health.py "$@"
elif [[ "${1:-}" == "--forecast" ]]; then
  shift
  set -- tests/test_forecast.py "$@"
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
