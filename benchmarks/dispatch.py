"""Token-permutation microbenchmark: modeled HBM traffic and time of the
MoE capacity dispatch/combine, jnp scatter-gather vs the Pallas kernels
(repro.kernels.token_permute), over an N / k / E / skew sweep.

The permute legs are pure data movement, so the headline is bytes, not
FLOPs: the jnp dispatch repeats the activations k× (``[N·k, d]``) and
read-modify-writes the whole ``[E, C, d]`` buffer through a serialized
scatter-add; the jnp combine materializes the ``[N, k, d]`` gather and
upcasts all of it to f32 for the gate einsum.  The kernels stream the
token panel and the capacity buffer exactly once each (the capacity
buffer scales with ``capacity_factor · k``, which is why the dispatch
ratio is ≈ k× on the routed grid rather than growing without bound).

Rows (``derived`` column; ``us_per_call`` carries the modeled HBM time
of the Pallas leg via ``PerfModel.t_dispatch``/``t_combine``, or the
measured wall time on TPU):

  dispatch/N<N>/k<k>/E<E>/traffic_ratio   jnp bytes / pallas bytes (≥ k)
  combine/N<N>/k<k>/E<E>/traffic_ratio    jnp bytes / pallas bytes
  dispatch/.../a<alpha>/kept_frac         kept (token, choice) fraction
                                          under power-law skew — drops
                                          don't change modeled traffic
                                          (both paths stream the full
                                          panel/buffer) but pin how the
                                          sweep's capacity clamps load

``run()`` also writes ``BENCH_dispatch.json`` next to the repo root —
one record per sweep point with both paths' modeled bytes/times — to
seed the repo's perf trajectory (compare future kernel revisions
against it).  The < 1e-12 agreement between these formulas and the
``PerfModel`` permute terms is asserted in ``perfmodel_accuracy.py``.
"""
import json
import os
import time

import numpy as np

D_MODEL = 512
CAPACITY_FACTOR = 1.25
SWEEP_N = (2048, 8192)
SWEEP_K = (1, 2, 4)
SWEEP_E = (8, 64)
ALPHAS = (0.0, 1.0, 2.0)
ITEMSIZE = 2                     # bf16 activations

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_dispatch.json")


def skewed_loads(alpha: float, total: int, e: int):
    """Power-law expert loads summing to ``total`` (alpha=0 ⇒ uniform)."""
    w = (1.0 / np.arange(1, e + 1)) ** alpha
    loads = np.floor(w / w.sum() * total).astype(int)
    loads[0] += total - loads.sum()
    return loads


def _time_pallas(n, k, e, capacity):
    """Measured wall time of one dispatch+combine round trip on TPU
    (interpret-mode timing off-TPU is meaningless → 0.0)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.models import moe
    if jax.default_backend() != "tpu":
        return 0.0
    x = jnp.zeros((n, D_MODEL), jnp.bfloat16)
    expert = jnp.asarray(
        np.random.default_rng(0).integers(0, e, size=(n, k)), jnp.int32)
    pos = moe.capacity_positions(expert.reshape(-1), e).reshape(n, k)
    gate = jnp.full((n, k), 1.0 / k, jnp.float32)

    def roundtrip():
        buf = ops.dispatch_tokens(x, expert, pos, num_buckets=e,
                                  capacity=capacity)
        return ops.combine_tokens(buf, expert, pos, gate)

    roundtrip().block_until_ready()      # compile
    t0 = time.perf_counter()
    reps = 10
    for _ in range(reps):
        out = roundtrip()
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def sweep():
    """[(params dict, modeled dict), ...] over the N/k/E grid."""
    from repro.core.perfmodel import (V5E_ICI_BW, V5E_PEAK_FLOPS,
                                      HardwareSpec, PerfModel)
    from repro.kernels.token_permute import (combine_modeled_bytes,
                                             dispatch_modeled_bytes)
    hw = HardwareSpec.from_model_dims(D_MODEL, 2 * D_MODEL,
                                      bandwidth=V5E_ICI_BW,
                                      flops_per_s=V5E_PEAK_FLOPS)
    out = []
    for n in SWEEP_N:
        for k in SWEEP_K:
            for e in SWEEP_E:
                pm = PerfModel(hw, e)
                capacity = max(8, int(n * k / e * CAPACITY_FACTOR))
                slots = e * capacity
                rec = {"n": n, "k": k, "e": e, "d": D_MODEL,
                       "capacity": capacity, "itemsize": ITEMSIZE}
                for leg, fn, t in (
                        ("dispatch", dispatch_modeled_bytes,
                         pm.t_dispatch),
                        ("combine", combine_modeled_bytes, pm.t_combine)):
                    pb = fn(n, slots, D_MODEL, top_k=k, itemsize=ITEMSIZE)
                    jb = fn(n, slots, D_MODEL, top_k=k, itemsize=ITEMSIZE,
                            pallas=False)
                    rec[f"{leg}_pallas_bytes"] = pb
                    rec[f"{leg}_jnp_bytes"] = jb
                    rec[f"{leg}_pallas_s"] = t(n, slots, top_k=k)
                    rec[f"{leg}_jnp_s"] = t(n, slots, top_k=k,
                                            pallas=False)
                out.append(rec)
    return out


def run(measure: bool = True):
    rows = []
    recs = sweep()
    for rec in recs:
        n, k, e = rec["n"], rec["k"], rec["e"]
        tag = f"N{n}/k{k}/E{e}"
        rows.append((f"dispatch/{tag}/traffic_ratio",
                     rec["dispatch_pallas_s"] * 1e6,
                     rec["dispatch_jnp_bytes"]
                     / rec["dispatch_pallas_bytes"]))
        rows.append((f"combine/{tag}/traffic_ratio",
                     rec["combine_pallas_s"] * 1e6,
                     rec["combine_jnp_bytes"]
                     / rec["combine_pallas_bytes"]))
        if measure:
            # measured wall time is a dispatch→combine ROUND TRIP — its
            # own row (TPU only; derived = modeled roundtrip µs so the
            # measured/modeled ratio is read straight off the row)
            meas = _time_pallas(n, k, e, rec["capacity"])
            if meas:
                rows.append((f"roundtrip/{tag}/measured_us", meas,
                             (rec["dispatch_pallas_s"]
                              + rec["combine_pallas_s"]) * 1e6))
    # skew axis: capacity clamps the hot expert exactly like the model's
    # dispatch — modeled traffic is occupancy-independent, so the ratio
    # rows above stand; these pin the drop accounting of the sweep.
    n, k, e = SWEEP_N[1], SWEEP_K[1], SWEEP_E[0]
    capacity = max(8, int(n * k / e * CAPACITY_FACTOR))
    for alpha in ALPHAS:
        loads = skewed_loads(alpha, n * k, e)
        kept = np.minimum(loads, capacity).sum() / (n * k)
        rows.append((f"dispatch/N{n}/k{k}/E{e}/a{alpha}/kept_frac",
                     0.0, float(kept)))
    payload = json.dumps({"d_model": D_MODEL,
                          "capacity_factor": CAPACITY_FACTOR,
                          "itemsize": ITEMSIZE, "sweep": recs}, indent=1)
    try:
        # idempotent write: the sweep is deterministic arithmetic, so
        # re-runs must not dirty the committed trajectory seed
        if (not os.path.exists(_JSON_PATH)
                or open(_JSON_PATH).read() != payload):
            with open(_JSON_PATH, "w") as f:
                f.write(payload)
    except OSError:
        pass                     # read-only checkout: rows still stand
    return rows


def table():
    """Markdown summary for benchmarks.report (modeled numbers only)."""
    lines = ["| N | k | E | dispatch jnp→pallas | combine jnp→pallas |"
             " dispatch win | combine win |",
             "|---|---|---|---|---|---|---|"]
    for rec in sweep():
        dj, dp = rec["dispatch_jnp_bytes"], rec["dispatch_pallas_bytes"]
        cj, cp = rec["combine_jnp_bytes"], rec["combine_pallas_bytes"]
        lines.append(
            f"| {rec['n']} | {rec['k']} | {rec['e']} "
            f"| {dj/1e6:.1f}→{dp/1e6:.1f} MB | {cj/1e6:.1f}→{cp/1e6:.1f} MB "
            f"| {dj/dp:.2f}× | {cj/cp:.2f}× |")
    return "\n".join(lines)


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived:.4f}")
