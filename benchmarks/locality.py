"""Fig. 4 analog: locality of input distributions across iterations."""
from repro.core import GatingTrace, LocalityTracker, distribution_similarity


def run():
    rows = []
    for drift, label in ((0.0, "frozen"), (0.05, "paper-like"),
                         (0.5, "no-locality")):
        tr = GatingTrace(16, 16, 1024, skew=0.1, drift=drift, seed=0)
        tracker = LocalityTracker(16, 16, history=16)
        pred_err = []
        gs = tr.take(16)
        for g in gs:
            prev = tracker.predict_next("last")
            if prev is not None:
                tot = g.sum()
                pred_err.append(abs(prev.sum(0) - g.sum(0)).sum() / tot)
            tracker.update(g)
        stats = tracker.locality_stats()
        rows.append((f"locality/{label}/similarity", stats.mean_similarity,
                     stats.mean_l1_drift))
        rows.append((f"locality/{label}/pred_l1_err",
                     sum(pred_err) / len(pred_err), drift))
    return rows
