"""Fig. 15 analog: planner vs static top2/top3 shadow-to-all policies."""
from .simlib import SimConfig, simulate, speedup


def run(iters: int = 20):
    rows = []
    for k in (1, 2):
        sim = SimConfig(model="moe-gpt-m", top_k=k, iters=iters)
        planner = simulate("planner", sim)
        for pol in ("top2", "top3"):
            other = simulate(pol, sim)
            rows.append((f"policies/k{k}/planner_vs_{pol}",
                         planner.mean_iter * 1e6, speedup(other, planner)))
    return rows
