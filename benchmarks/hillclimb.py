"""§Perf hillclimb probe: re-lower one (arch × shape) with the current
REPRO_* experiment flags and report the three roofline terms.

  REPRO_XENT_CHUNK=8192 PYTHONPATH=src:. python -m benchmarks.hillclimb \
      --arch smollm-360m --shape train_4k --tag chunked_xent
"""
import argparse
import json
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default="artifacts/hillclimb")
    args = ap.parse_args()

    # dryrun sets the 512-device XLA flag on import — import FIRST.
    from repro.launch import dryrun
    from . import roofline

    rec = dryrun.run_one(args.arch, args.shape, "single", args.out)
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out,
                        f"{args.arch}__{args.shape}__{args.tag}.json")
    rec["tag"] = args.tag
    rec["flags"] = {k: v for k, v in os.environ.items()
                    if k.startswith("REPRO_")}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] != "OK":
        print(f"STATUS={rec['status']}: {rec.get('error', rec.get('reason'))}")
        sys.exit(1)
    a = roofline.analyze(rec)
    print(f"tag={args.tag} flags={rec['flags']}")
    print(f"  compute    {a['t_compute_s']*1e3:10.2f} ms")
    print(f"  memory     {a['t_memory_s']*1e3:10.2f} ms")
    print(f"  collective {a['t_collective_s']*1e3:10.2f} ms")
    print(f"  dominant   {a['dominant']}  useful_ratio={a['useful_ratio']:.3f}")
    print(f"  temp bytes/dev {rec.get('temp_size_in_bytes', 0)/1e9:.2f} GB")


if __name__ == "__main__":
    main()
