"""§Roofline: derive compute/memory/collective terms per (arch × shape)
from the dry-run artifacts (single-pod mesh, per-device SPMD numbers).

Scan-aware accounting: XLA cost_analysis counts a lax.scan body once, so
totals are assembled from the per-layer probes × occurrence counts plus
the embed/unembed head (see repro.launch.dryrun.probe_layers).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link.
"""
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

ART_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def _probe_totals(rec):
    """Scan-corrected per-device totals from the probes."""
    probes = rec.get("probes") or {}
    flops = bytes_ = coll = 0.0
    ok = True
    for key, p in probes.items():
        if "error" in p:
            ok = False
            continue
        c = p.get("count", 1)
        flops += p.get("flops", 0.0) * c
        bytes_ += p.get("bytes_accessed", 0.0) * c
        pc = p.get("collectives", {})
        coll += sum(v for k, v in pc.items() if k != "count") * c
    return flops, bytes_, coll, ok and bool(probes)


def model_flops_per_device(rec):
    """Useful model FLOPs per device: 6·N_active·T (train) / 2·N_active·T
    (inference); T = global tokens this step."""
    n = rec["active_params"]
    if rec["kind"] == "decode":
        tokens = rec["batch"]                  # one new token per sequence
    else:
        tokens = rec["batch"] * rec["seq"]
    mult = 6 if rec["kind"] == "train" else 2
    return mult * n * tokens / CHIPS


def analyze(rec):
    flops, bytes_, coll, probed = _probe_totals(rec)
    if not probed:                             # fall back to full-step
        flops = rec.get("flops", 0.0)
        bytes_ = rec.get("bytes_accessed", 0.0)
        coll = sum(v for k, v in rec.get("collectives", {}).items()
                   if k != "count")
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    ratio = mf / flops if flops else 0.0
    hints = {
        "compute": "raise useful-FLOP fraction (less remat/causal-block "
                   "overcount) or grow per-chip batch",
        "memory": "fuse/reuse activations, bf16 everywhere, bigger tiles "
                  "to raise arithmetic intensity",
        "collective": "reshard to cut all-gather/all-reduce volume "
                      "(expert-FSDP gather and Trans psum are the levers)",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom, "model_flops_dev": mf, "hlo_flops_dev": flops,
        "useful_ratio": ratio, "scan_corrected": probed,
        "hbm_bytes_dev": rec.get("temp_size_in_bytes", 0)
        + rec.get("argument_size_in_bytes", 0),
        "hint": hints[dom],
    }


def load_records(mesh="single"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run():
    rows = []
    for rec in load_records("single"):
        if rec["status"] != "OK":
            continue
        a = analyze(rec)
        name = f"roofline/{a['arch']}/{a['shape']}"
        total = a["t_compute_s"] + a["t_memory_s"] + a["t_collective_s"]
        rows.append((name + "/dominant_" + a["dominant"], total * 1e6,
                     a["useful_ratio"]))
    return rows


def full_table():
    out = []
    for rec in load_records("single"):
        if rec["status"] == "OK":
            out.append(analyze(rec))
        else:
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "dominant": rec["status"],
                        "hint": rec.get("reason", rec.get("error", ""))})
    return out


if __name__ == "__main__":
    for a in full_table():
        print(a)
