"""Fig. 16 analog: balance capability — RB (balance-degree ratio) of the
planner vs FasterMoE across layers and k."""
import numpy as np

from .simlib import SimConfig, simulate


def run(iters: int = 20):
    rows = []
    for k in (1, 2):
        for seed in (0, 1, 2):       # stands in for different layers
            sim = SimConfig(model="moe-gpt-m", top_k=k, iters=iters,
                            seed=seed)
            pp = simulate("planner", sim)
            fm = simulate("fastermoe", sim)
            rb_pp = float(np.mean(pp.rb))
            rb_fm = float(np.mean(fm.rb))
            rows.append((f"balance/k{k}/layer{seed}/rb_ratio_pp_over_fm",
                         0.0, rb_pp / max(rb_fm, 1e-9)))
            rows.append((f"balance/k{k}/layer{seed}/rb_planner", 0.0, rb_pp))
    return rows
