"""Fig. 16 analog: balance capability — RB (balance-degree ratio) of the
planner vs FasterMoE across layers and k — plus the migration policy
sweep's balance rows (shadow / migrate / both greedy strategies on the
same traces, E = 4·D so owner re-layout has slack to re-home into)."""
import numpy as np

from .simlib import (MIGRATION_STRATEGIES, SimConfig, migration_sweep,
                     simulate)


def run(iters: int = 20):
    rows = []
    for k in (1, 2):
        for seed in (0, 1, 2):       # stands in for different layers
            sim = SimConfig(model="moe-gpt-m", top_k=k, iters=iters,
                            seed=seed)
            pp = simulate("planner", sim)
            fm = simulate("fastermoe", sim)
            rb_pp = float(np.mean(pp.rb))
            rb_fm = float(np.mean(fm.rb))
            rows.append((f"balance/k{k}/layer{seed}/rb_ratio_pp_over_fm",
                         0.0, rb_pp / max(rb_fm, 1e-9)))
            rows.append((f"balance/k{k}/layer{seed}/rb_planner", 0.0, rb_pp))
    # Migration policy sweep: RB and steady-state Trans bytes per greedy
    # strategy — derived column is RB, us column the per-step Trans+Agg
    # traffic in KB (what a migrated expert stops paying).
    sweep = migration_sweep(SimConfig(model="moe-gpt-m", iters=iters))
    for strategy in MIGRATION_STRATEGIES:
        s = sweep[strategy]
        rows.append((f"balance/migration/{strategy}/rb",
                     s["trans_gb"] * 1e6, s["rb"]))
    return rows
