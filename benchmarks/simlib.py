"""Shared simulation engine for the paper-table benchmarks.

CPU-only container ⇒ the end-to-end cluster numbers (Tables IV/V, Figs
10–12, 14–16) are **performance-model-driven simulations** over synthetic
gating traces with the paper's locality property, using the same eqs. 1–8
the planner itself uses, on cluster constants matched to the paper's
testbeds.  The performance model itself is validated against *real
measured compute* in perfmodel_accuracy.py (paper Fig. 13, <5 % target),
which grounds the simulated tables.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_config
from repro.core import (BlockCosts, GatingTrace, GreedyPlanner, HardwareSpec,
                        LocalityPlanner, PerfModel, balance_degree,
                        iteration_time, traditional)
from repro.core.baselines import fastermoe_plan, topk_policy

# ---------------------------------------------------------------------------
# Cluster profiles (paper §VI Testbed)
# ---------------------------------------------------------------------------

CLUSTERS = {
    # 4 GPUs/node, PCIe3 + 100 Gb/s IB, RTX 3090.
    "HPWNV": dict(bandwidth=10e9, flops=35e12),
    # + NVLink pairs ⇒ higher effective bandwidth.
    "HPNV": dict(bandwidth=40e9, flops=35e12),
    # 2080 Ti: lower compute.
    "LPWNV": dict(bandwidth=10e9, flops=18e12),
    # The TPU v5e target (per chip) — used by beyond-paper studies.
    "TPU_V5E": dict(bandwidth=50e9, flops=197e12),
}


@dataclasses.dataclass
class SimConfig:
    model: str = "moe-gpt-m"
    cluster: str = "HPWNV"
    devices: int = 16
    tokens: int = 16384
    top_k: int = 1
    iters: int = 30
    # Calibrated so the simulated baselines land in the paper's observed
    # regime: Fig. 3-level skew (top-3 experts >50% of tokens) and Table I
    # LB-overhead fractions (~20-40%).
    skew: float = 0.25
    drift: float = 0.05
    seed: int = 0
    s_max: int = 8
    n: int = 2                      # paper's n for the planner
    plan_unit_cost: float = 1e-4    # host greedy-search cost per step [s]


@dataclasses.dataclass
class SimResult:
    iter_times: List[float]
    breakdown: Dict[str, float]     # summed seconds by component
    rb: List[float]                 # per-iteration RB ratio
    per_layer_time: List[float]     # mean per-MoE-layer time

    @property
    def mean_iter(self) -> float:
        return float(np.mean(self.iter_times))


def _hw_for(cfg, sim: SimConfig) -> HardwareSpec:
    cl = CLUSTERS[sim.cluster]
    nm = 2 if cfg.ffn_kind == "gelu" else 3
    # Non-MoE (attention) per-layer time: 8·d² matmul flops/token fwd,
    # 2× for backward.
    tok_per_dev = sim.tokens / sim.devices
    attn_flops = 8 * cfg.d_model ** 2 * tok_per_dev
    t_fnec = attn_flops / cl["flops"]
    return HardwareSpec.from_model_dims(
        cfg.d_model, cfg.moe.d_expert, bandwidth=cl["bandwidth"],
        flops_per_s=cl["flops"], num_ffn_mats=nm,
        t_fnec=t_fnec, t_bnec=2 * t_fnec)


def _planner_setup(sim: SimConfig, *, plan_scheduled: bool,
                   trans_mode: str = "p2p", strategy: str = "shadow",
                   migrate_window: float = 50.0, experts: int = 0):
    """Shared harness: (cfg, hw, perf, per-layer LocalityPlanners,
    per-layer GatingTraces) for a SimConfig — one construction used by
    the policy simulator, the chunk K-sweep, and the migration policy
    sweep, so their rows stay comparable by design.  ``strategy`` /
    ``migrate_window`` configure the greedy search space (owner
    re-layout); ``experts`` overrides the model's expert count (migration
    needs E > D to have slack to re-home into)."""
    cfg = get_config(sim.model)
    if experts:
        from repro.configs.moe_gpt import with_experts
        cfg = with_experts(cfg, experts, top_k=cfg.moe.top_k)
    E, D, L = cfg.moe.num_experts, sim.devices, cfg.num_moe_layers
    hw = _hw_for(cfg, sim)
    perf = PerfModel(hw, D, trans_mode=trans_mode)
    greedy = GreedyPlanner(perf, n=sim.n, alpha=0.25, s_max=sim.s_max,
                           scheduled=plan_scheduled, strategy=strategy,
                           migrate_window=migrate_window)
    planners = [LocalityPlanner(greedy, D, E) for _ in range(L)]
    traces = [GatingTrace(D, E, sim.tokens // D, skew=sim.skew,
                          drift=sim.drift, seed=sim.seed * 1000 + li)
              for li in range(L)]
    return cfg, hw, perf, planners, traces


def simulate(policy: str, sim: SimConfig, *, scheduled: Optional[bool] = None,
             trans_mode: str = "p2p") -> SimResult:
    """policy ∈ {deepspeed, fastermoe, top2, top3, planner, scheduler,
    pro_prophet}.

    deepspeed    — plain EP, blocked.
    fastermoe    — shadow-to-all while its cost model improves, blocked.
    top2/top3    — static heaviest-k to all devices, blocked.
    planner      — Pro-Prophet planner only (lightweight placement, eq. 6).
    scheduler    — FasterMoE placement + block-wise overlap (eq. 8 resid).
    pro_prophet  — planner×scheduler coupling (plans against eq. 8).
    """
    use_sched = scheduled if scheduled is not None else policy in (
        "scheduler", "pro_prophet")
    cfg, hw, perf, planners, traces = _planner_setup(
        sim, plan_scheduled=policy == "pro_prophet", trans_mode=trans_mode)
    E, D, L = cfg.moe.num_experts, sim.devices, cfg.num_moe_layers
    assert E == D or E % D == 0
    # top-k routing: k choices per token ⇒ k× entries in G
    iter_times, rbs, layer_ts = [], [], []
    breakdown = {"a2a": 0.0, "fec": 0.0, "bec": 0.0, "trans": 0.0,
                 "agg": 0.0, "plan": 0.0, "fnec": 0.0}
    prev_g = [None] * L
    for it in range(sim.iters):
        total = 0.0
        for li in range(L):
            g = traces[li].step() * sim.top_k
            if policy == "deepspeed":
                placement, plan_steps = traditional(E, D), 0
            elif policy in ("fastermoe", "scheduler"):
                res = fastermoe_plan(perf, g, max_shadows=sim.s_max)
                placement, plan_steps = res.placement, res.steps_examined
            elif policy in ("top2", "top3"):
                placement = topk_policy(g, int(policy[-1]))
                plan_steps = 0
            else:  # planner / pro_prophet: locality — plan on last iter's G
                res = planners[li].maybe_plan(prev_g[li] if prev_g[li]
                                              is not None else g)
                placement, plan_steps = res.placement, res.steps_examined
            prev_g[li] = g

            bd = perf.breakdown(placement, g, scheduled=use_sched)
            layer_t = bd["total"]
            plan_t = plan_steps * sim.plan_unit_cost
            if policy in ("planner", "pro_prophet"):
                plan_t = 0.0        # hidden under the a2a (scheduling space)
            total += layer_t + hw.t_fnec + hw.t_bnec + plan_t
            for k in ("a2a", "fec", "bec", "trans", "agg"):
                breakdown[k] += bd[k]
            breakdown["plan"] += plan_t
            breakdown["fnec"] += hw.t_fnec + hw.t_bnec
            if li == 0:
                layer_ts.append(layer_t)
            H0, _ = traditional(E, D).compute_loads(g)
            H1, _ = placement.compute_loads(g)
            if li == 0:
                rbs.append(balance_degree(H0)
                           / max(balance_degree(H1), 1e-9))
        iter_times.append(total)
    return SimResult(iter_times, breakdown, rbs, layer_ts)


def speedup(a: SimResult, b: SimResult) -> float:
    """How much faster is b than a."""
    return a.mean_iter / b.mean_iter


def chunk_sweep(sim: SimConfig, ks=(1, 2, 4, 8),
                chunk_overhead: float = 0.0) -> Dict[int, Dict[str, float]]:
    """K-sweep of the chunked a2a↔FEC pipeline under Pro-Prophet
    placements (the device path in repro.models.moe; timeline in
    repro.core.scheduler).  Per chunk count K returns the mean per-layer
    expert-path time (fwd+bwd, ``PerfModel.layer_time_chunked``), the
    mean simulated iteration time, and the mean timeline hidden-comm
    fraction.  K=1 reproduces the eq. 8 serial numbers exactly."""
    from repro.core import scheduler as sched

    cfg, hw, perf, planners, traces = _planner_setup(sim,
                                                     plan_scheduled=True)
    D, L = sim.devices, cfg.num_moe_layers
    prev_g: List[Optional[np.ndarray]] = [None] * L
    layer_t = {k: [] for k in ks}
    iter_t = {k: [] for k in ks}
    hidden = {k: [] for k in ks}
    for _ in range(sim.iters):
        totals = {k: 0.0 for k in ks}
        for li in range(L):
            g = traces[li].step() * sim.top_k
            res = planners[li].maybe_plan(prev_g[li] if prev_g[li]
                                          is not None else g)
            prev_g[li] = g
            pl = res.placement
            H, R = pl.compute_loads(g)
            n = perf.effective_n(pl)
            for k in ks:
                t = perf.layer_time_chunked(R, H, pl.num_shadowed, n, k,
                                            chunk_overhead=chunk_overhead)
                layer_t[k].append(t)
                totals[k] += t + hw.t_fnec + hw.t_bnec
                hidden[k].append(sched.hidden_comm_fraction(
                    perf.t_a2a(R), perf.t_fec(H), k,
                    chunk_overhead=chunk_overhead))
        for k in ks:
            iter_t[k].append(totals[k])
    return {k: {"layer_s": float(np.mean(layer_t[k])),
                "iter_s": float(np.mean(iter_t[k])),
                "hidden_frac": float(np.mean(hidden[k]))}
            for k in ks}


class StabilizingTrace(GatingTrace):
    """Fluctuating→stabilizing gating trace: the expert-popularity drift
    decays geometrically from ``drift0`` to ``drift1`` over the first
    ``settle`` steps, then stays at ``drift1``.  Early iterations look
    like warmup routing (hot set churning every step); late iterations
    look like a converged gate (near-static distribution) — the regime
    the forecast cadence backoff is designed for."""

    def __init__(self, num_devices: int, num_experts: int, tokens: int, *,
                 skew: float = 0.25, drift0: float = 0.5,
                 drift1: float = 0.005, settle: int = 10, seed: int = 0):
        super().__init__(num_devices, num_experts, tokens, skew=skew,
                         drift=drift0, seed=seed)
        self.drift0, self.drift1 = float(drift0), float(drift1)
        self.settle = max(int(settle), 1)
        self._t = 0

    def step(self):
        frac = min(self._t / self.settle, 1.0)
        self.drift = self.drift0 * (self.drift1 / self.drift0) ** frac
        self._t += 1
        return super().step()


MIGRATION_STRATEGIES = ("shadow", "migrate", "both")


def migration_sweep(sim: SimConfig, *, window: float = 100.0,
                    experts_factor: int = 4) -> Dict[str, Dict[str, float]]:
    """Migration-vs-shadow-vs-both policy sweep (the tentpole benchmark).

    Runs the locality planner with each greedy ``strategy`` over the same
    gating traces (E = ``experts_factor``·D so devices own several
    experts and re-homing has somewhere to go) and reports, per strategy:

    ``iter_s``        — mean simulated iteration time, eq. 6 blocked
                        evaluation + the amortized migration term (the
                        regime where the Trans-vs-migrate tradeoff is
                        explicit rather than hidden by the scheduler);
    ``trans_gb``      — modeled **steady-state** Trans+Agg bytes per step
                        (what shadowing pays every iteration and a
                        migrated expert never pays again);
    ``migrate_gb``    — amortized migration bytes per step;
    ``relocations``   — owner changes executed across the run (placement
                        diffs between consecutive iterations);
    ``shadows``/``migrations`` — mean live shadow slots / re-homed
                        experts per iteration;
    ``rb``            — mean balance-degree ratio vs plain EP.
    """
    out: Dict[str, Dict[str, float]] = {}
    for strategy in MIGRATION_STRATEGIES:
        cfg, hw, perf, planners, traces = _planner_setup(
            sim, plan_scheduled=False, strategy=strategy,
            migrate_window=window, experts=experts_factor * sim.devices)
        E, D, L = cfg.moe.num_experts, sim.devices, cfg.num_moe_layers
        iter_t, trans_b, mig_b, rbs = [], [], [], []
        shadows, migrations, relocations = [], [], 0
        prev_g: List[Optional[np.ndarray]] = [None] * L
        prev_pl: List[Optional[object]] = [None] * L
        for _ in range(sim.iters):
            total = t_bytes = m_bytes = 0.0
            n_sh = n_mig = 0
            for li in range(L):
                g = traces[li].step() * sim.top_k
                res = planners[li].maybe_plan(prev_g[li] if prev_g[li]
                                              is not None else g)
                prev_g[li] = g
                pl = res.placement
                if prev_pl[li] is not None:
                    relocations += len(pl.diff(prev_pl[li]))
                prev_pl[li] = pl
                H, R = pl.compute_loads(g)
                s, n = pl.num_shadowed, perf.effective_n(pl)
                t_mig = perf.t_migrate(pl.num_migrated, window=window)
                total += (perf.layer_time(R, H, s, n) + t_mig
                          + hw.t_fnec + hw.t_bnec)
                t_bytes += 2.0 * perf.t_trans(s, n) * hw.bandwidth
                m_bytes += t_mig * hw.bandwidth
                n_sh += s
                n_mig += pl.num_migrated
                if li == 0:
                    H0, _ = traditional(E, D).compute_loads(g)
                    rbs.append(balance_degree(H0)
                               / max(balance_degree(H), 1e-9))
            iter_t.append(total)
            trans_b.append(t_bytes)
            mig_b.append(m_bytes)
            shadows.append(n_sh)
            migrations.append(n_mig)
        out[strategy] = {
            "iter_s": float(np.mean(iter_t)),
            "trans_gb": float(np.mean(trans_b)) / 1e9,
            "migrate_gb": float(np.mean(mig_b)) / 1e9,
            "relocations": float(relocations),
            "shadows": float(np.mean(shadows)),
            "migrations": float(np.mean(migrations)),
            "rb": float(np.mean(rbs)),
        }
    return out


def fault_sweep(sim: SimConfig, *,
                planner_faults=(4, 12), count_faults=(8, 16),
                slow_faults=(), deadline_ms: float = 0.0
                ) -> Dict[str, Dict[str, float]]:
    """Resilience sweep: the pipelined planner loop driven through the
    production plan watchdog (:func:`repro.train.runtime.run_plan`) with
    and without injected faults (:mod:`repro.testing.faults`).

    Per variant (``fault_free`` / ``faulted``) the loop observes one-step-
    delayed counts through ``run_plan`` and prices each iteration with the
    eq. 8 breakdown of whatever placements the engine currently holds —
    a rejected plan means the next iteration runs on *stale* placements
    (the watchdog's fallback), so ``slowdown`` quantifies the throughput
    cost of degradation: under paper-like locality a stale plan stays
    near-optimal, which is exactly why fallback-to-last-good is safe.

    Returns per variant: ``iter_s`` (mean simulated iteration), ``plan_s``
    (mean measured wall-clock watchdog latency, validation included),
    ``fallbacks`` / ``sanitized`` (watchdog interventions), and
    ``stale_frac`` (fraction of iterations run on stale placements).
    """
    import time as _time

    from repro.core import EngineConfig, ProProphetEngine
    from repro.testing import Fault, FaultInjector, injected
    from repro.train.runtime import run_plan

    cfg = get_config(sim.model)
    E, D, L = cfg.moe.num_experts, sim.devices, cfg.num_moe_layers
    hw = _hw_for(cfg, sim)

    def one(inj: Optional[FaultInjector]) -> Dict[str, float]:
        ec = EngineConfig(num_experts=E, num_devices=D, num_moe_layers=L,
                          s_max=sim.s_max, n=sim.n, scheduled=True)
        eng = ProProphetEngine(ec, hw)
        perf = PerfModel(hw, D)
        traces = [GatingTrace(D, E, sim.tokens // D, skew=sim.skew,
                              drift=sim.drift, seed=sim.seed * 1000 + li)
                  for li in range(L)]
        iter_t, plan_t = [], []
        fallbacks = sanitized = stale = 0
        prev = None
        ctx = injected(inj) if inj is not None else None
        if ctx is not None:
            ctx.__enter__()
        try:
            for _ in range(sim.iters):
                gs = [t.step() * sim.top_k for t in traces]
                if prev is not None:     # locality: plan on last counts
                    t0 = _time.perf_counter()
                    ev = run_plan(eng, np.stack(prev))
                    plan_t.append(_time.perf_counter() - t0)
                    sanitized += ev.sanitized_layers
                    if not ev.ok:
                        fallbacks += 1
                        stale += 1
                prev = gs
                total = 0.0
                for li, g in enumerate(gs):
                    bd = perf.breakdown(eng.placements[li], g,
                                        scheduled=True)
                    total += bd["total"] + hw.t_fnec + hw.t_bnec
                iter_t.append(total)
        finally:
            if ctx is not None:
                ctx.__exit__(None, None, None)
        return {"iter_s": float(np.mean(iter_t)),
                "plan_s": float(np.mean(plan_t)) if plan_t else 0.0,
                "fallbacks": float(fallbacks),
                "sanitized": float(sanitized),
                "stale_frac": float(stale) / max(sim.iters, 1)}

    schedule = ([Fault("planner_exception", a) for a in planner_faults]
                + [Fault("corrupt_counts", a, {"mode": "mixed"})
                   for a in count_faults]
                + [Fault("slow_plan", a, {"delay_s": deadline_ms * 2e-3})
                   for a in slow_faults])
    out = {"fault_free": one(None),
           "faulted": one(FaultInjector(schedule, seed=sim.seed))}
    out["faulted"]["slowdown"] = (out["faulted"]["iter_s"]
                                  / max(out["fault_free"]["iter_s"], 1e-12))
    return out


def measure_plan_overlap(engine, traces, step_window_fn, iters: int,
                         top_k: int = 1):
    """Shared pipelined-runtime measurement harness: per iteration,
    wall-clock the Plan primitive (``engine.observe`` over all layers)
    and the placement pack (paid only on a ``placements_version`` bump —
    exactly the :class:`repro.train.runtime.PlacementCache` policy),
    score it against ``step_window_fn(engine)``'s device window, and
    record into an :class:`~repro.train.runtime.OverlapTelemetry` (the
    async runtime exposes ``max(0, plan − step) + upload``; the serial
    baseline exposes ``plan + upload`` every step).

    Cadence-aware accounting: ``plans`` is the number of per-layer Plan
    primitives the engine actually executed across the run (the engine's
    ``plans_executed`` counter — cached-plan reuse at
    ``replan_interval > 1`` and forecast-backoff skips both count as
    skips), so backed-off rows stay comparable to the fixed-cadence
    baseline, whose observe also runs every iteration but plans every
    layer every time.

    Returns ``(telemetry, uploads, plans)``.
    """
    import time

    from repro.train.runtime import OverlapTelemetry

    tel = OverlapTelemetry()
    uploads, version = 0, -1
    plans0 = int(getattr(engine, "plans_executed", 0))
    for _ in range(iters):
        gs = [t.step() * top_k for t in traces]
        t0 = time.perf_counter()
        engine.observe(gs)
        t1 = time.perf_counter()
        upload = 0.0
        if engine.placements_version != version:
            engine.step_arrays()
            version = engine.placements_version
            uploads += 1
            upload = time.perf_counter() - t1
        step = step_window_fn(engine)
        info = getattr(engine, "last_plan_info", None) or {}
        tel.record(plan=t1 - t0, step=step,
                   exposed=max(0.0, (t1 - t0) - step), upload=upload)
        tel.plans_skipped += int(info.get("skipped", 0))
        tel.stable_layers += int(info.get("stable", 0))
    plans = int(getattr(engine, "plans_executed", 0)) - plans0
    return tel, uploads, plans


def host_overlap(sim: SimConfig, device_step: float, iters: int = 10, *,
                 replan_interval: int = 1, forecast: bool = False,
                 cadence_max: int = 16) -> Dict[str, float]:
    """Pipelined-runtime telemetry for this model/cluster: measured
    wall-clock Plan latency of a real engine (all MoE layers) against the
    given simulated device-step window.  Returns
    :meth:`repro.train.runtime.OverlapTelemetry.summary` — plan latency,
    step latency, hidden fraction, and host overhead (exposed plan +
    placement pack, paid only when the placements changed) vs the serial
    baseline's plan-every-step cost — plus cadence-aware counters:
    ``plans_per_iter`` (per-layer Plan primitives actually executed per
    iteration) and ``uploads`` so rows at different cadences (fixed
    ``replan_interval`` or forecast backoff) stay comparable."""
    from repro.core import EngineConfig, ProProphetEngine

    cfg = get_config(sim.model)
    E, D, L = cfg.moe.num_experts, sim.devices, cfg.num_moe_layers
    ec = EngineConfig(num_experts=E, num_devices=D, num_moe_layers=L,
                      s_max=sim.s_max, n=sim.n, scheduled=True,
                      replan_interval=replan_interval,
                      enable_forecast=forecast,
                      plan_cadence_max=cadence_max if forecast else 0)
    eng = ProProphetEngine(ec, _hw_for(cfg, sim))
    traces = [GatingTrace(D, E, sim.tokens // D, skew=sim.skew,
                          drift=sim.drift, seed=sim.seed * 1000 + li)
              for li in range(L)]
    tel, uploads, plans = measure_plan_overlap(
        eng, traces, lambda _: device_step, iters, top_k=sim.top_k)
    out = tel.summary()
    out["plans_per_iter"] = plans / max(iters, 1)
    out["uploads"] = float(uploads)
    return out


def forecast_sweep(sim: SimConfig, *, cadence_max: int = 16,
                   experts_factor: int = 4, window: float = 50.0,
                   settle: Optional[int] = None,
                   stable_threshold: float = 0.2,
                   drift_threshold: float = 0.35
                   ) -> Dict[str, Dict[str, float]]:
    """Predictive-planning acceptance sweep (the tentpole benchmark).

    Runs two engines over *identical* fluctuating→stabilizing gating
    streams (:class:`StabilizingTrace`, same seeds):

    * ``fixed``    — per-step planning (``replan_interval=1``) with
      migration, relocations executed synchronously on the dispatch path
      (each pending exchange blocks one dispatch for the full
      ``PerfModel.t_exchange``);
    * ``forecast`` — the forecaster's cadence backoff
      (``enable_forecast``, bounded by ``cadence_max``) with prefetched
      relocation: a pending exchange holds the old placements for one
      step while it stages under the in-flight step's backward pass,
      then commits off the dispatch path (the modeled cost is one step
      of stale placements instead of an exposed exchange).

    Per variant: ``plans`` (per-layer Plan primitives executed),
    ``reloc_blocked`` (dispatches that waited on a relocation exchange),
    ``uploads`` (placement array uploads consumed at dispatch),
    ``step_s`` (mean modeled step time, eq. 6 + fnec/bnec + any exposed
    exchange), ``relocations`` (owner moves committed).  The ``accuracy``
    entry compares the forecast variant's EMA prediction against the
    last-value predictor on the realized loads (mean relative L1 —
    smaller is better)."""
    from repro.core import EngineConfig, ProProphetEngine

    cfg = get_config(sim.model)
    if experts_factor:
        from repro.configs.moe_gpt import with_experts
        cfg = with_experts(cfg, experts_factor * sim.devices,
                           top_k=cfg.moe.top_k)
    E, D, L = cfg.moe.num_experts, sim.devices, cfg.num_moe_layers
    hw = _hw_for(cfg, sim)
    perf = PerfModel(hw, D)
    settle_n = settle if settle is not None else max(sim.iters // 3, 4)

    def make_traces():
        return [StabilizingTrace(D, E, sim.tokens // D, skew=sim.skew,
                                 settle=settle_n,
                                 seed=sim.seed * 1000 + li)
                for li in range(L)]

    def run(forecast: bool) -> Dict[str, float]:
        ec = EngineConfig(num_experts=E, num_devices=D, num_moe_layers=L,
                          s_max=sim.s_max, n=sim.n, scheduled=False,
                          replan_interval=1,
                          enable_migration=True, migrate_window=window,
                          enable_forecast=forecast,
                          plan_cadence_max=cadence_max if forecast else 0,
                          # Classification thresholds sit between the
                          # trace's fluctuating-phase drift and the
                          # multinomial sampling-noise floor (~0.15
                          # rel-L1 at these token counts).
                          forecast_stable_threshold=stable_threshold,
                          forecast_drift_threshold=drift_threshold)
        eng = ProProphetEngine(ec, hw)
        traces = make_traces()
        blocked = uploads = relocated = 0
        consumed_version = -1
        step_t: List[float] = []
        err_ema: List[float] = []
        err_last: List[float] = []
        prev_g: Optional[List[np.ndarray]] = None
        # Placements the dispatch actually ran with (prefetch holds the
        # previous ones for one step while the exchange stages).
        live_pl = list(eng.placements)
        staged = False
        for _ in range(sim.iters):
            gs = [t.step() * sim.top_k for t in traces]
            if prev_g is not None:
                for li, g in enumerate(gs):
                    tot = max(float(np.abs(g).sum()), 1.0)
                    err_last.append(
                        float(np.abs(g - prev_g[li]).sum()) / tot)
                    pred = (eng.forecasters[li].predict()
                            if forecast else None)
                    if pred is not None:
                        err_ema.append(
                            float(np.abs(g - pred * sim.top_k).sum()) / tot)
            total = 0.0
            pend = eng.pending_relocation()
            if forecast:
                # Prefetched relocation: hold one step (dispatch on the
                # previous placements), then commit for free.
                if pend is not None and staged:
                    relocated += len(eng.relocations())
                    eng.mark_relocated()
                    live_pl = list(eng.placements)
                    staged = False
                elif pend is not None:
                    staged = True
                else:
                    live_pl = list(eng.placements)
            else:
                # Synchronous relocation: the exchange blocks dispatch.
                if pend is not None:
                    moves = eng.relocations()
                    blocked += 1
                    relocated += len(moves)
                    total += perf.t_exchange(len(moves))
                    eng.mark_relocated()
                live_pl = list(eng.placements)
            if not staged and eng.placements_version != consumed_version:
                uploads += 1
                consumed_version = eng.placements_version
            for li, g in enumerate(gs):
                bd = perf.breakdown(live_pl[li], g, scheduled=False)
                total += bd["total"] + hw.t_fnec + hw.t_bnec
            step_t.append(total)
            eng.observe(gs)        # Plan primitive for the next dispatch
            prev_g = gs
        out = {"plans": float(eng.plans_executed),
               "plans_skipped": float(eng.plans_skipped),
               "reloc_blocked": float(blocked),
               "uploads": float(uploads),
               "relocations": float(relocated),
               "step_s": float(np.mean(step_t))}
        if forecast:
            out["err_ema"] = (float(np.mean(err_ema))
                              if err_ema else float("nan"))
            out["err_last"] = (float(np.mean(err_last))
                               if err_last else float("nan"))
        return out

    fixed = run(False)
    fore = run(True)
    return {
        "fixed": fixed,
        "forecast": fore,
        "accuracy": {"ema": fore.get("err_ema", float("nan")),
                     "last": fore.get("err_last", float("nan"))},
    }
