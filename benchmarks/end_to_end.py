"""Tables IV/V + Fig. 10 analog: end-to-end speedups of Pro-Prophet vs
DeepSpeed-MoE-style plain EP and FasterMoE-style shadowing, across the five
MoE-GPT models, k ∈ {1,2}, and three cluster profiles.

The ``host_plan`` rows consume the async runtime's overlap telemetry
(see repro.train.runtime): measured Plan latency for the model's engine
vs that model's simulated iteration time — ``us_per_call`` is the mean
host Plan latency, ``derived`` the fraction hidden under the device step
by the pipelined runtime."""
from .simlib import CLUSTERS, SimConfig, host_overlap, simulate, speedup

MODELS = ["moe-gpt-s", "moe-gpt-m", "moe-gpt-l", "moe-gpt-ds", "moe-gpt-dm"]


def run(iters: int = 20):
    rows = []
    for cluster, devices, tokens in (("HPWNV", 16, 16384),
                                     ("HPNV", 16, 16384),
                                     ("LPWNV", 8, 4096)):
        models = MODELS if cluster == "HPWNV" else [m for m in MODELS
                                                    if m != "moe-gpt-l"]
        for model in models:
            for k in (1, 2):
                sim = SimConfig(model=model, cluster=cluster,
                                devices=devices, tokens=tokens, top_k=k,
                                iters=iters)
                ds = simulate("deepspeed", sim)
                fm = simulate("fastermoe", sim)
                pp = simulate("pro_prophet", sim)
                rows.append((f"e2e/{cluster}/{model}/k{k}/vs_deepspeed",
                             pp.mean_iter * 1e6, speedup(ds, pp)))
                rows.append((f"e2e/{cluster}/{model}/k{k}/vs_fastermoe",
                             pp.mean_iter * 1e6, speedup(fm, pp)))
                if k == 1:
                    ov = host_overlap(sim, pp.mean_iter)
                    rows.append((f"e2e/{cluster}/{model}/host_plan",
                                 ov["mean_plan_s"] * 1e6,
                                 ov["hidden_frac"]))
    return rows
