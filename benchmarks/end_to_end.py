"""Tables IV/V + Fig. 10 analog: end-to-end speedups of Pro-Prophet vs
DeepSpeed-MoE-style plain EP and FasterMoE-style shadowing, across the five
MoE-GPT models, k ∈ {1,2}, and three cluster profiles.

The ``host_plan`` rows consume the async runtime's overlap telemetry
(see repro.train.runtime): measured Plan latency for the model's engine
vs that model's simulated iteration time — ``us_per_call`` is the mean
host Plan latency, ``derived`` the fraction hidden under the device step
by the pipelined runtime.

The ``a2a_chunks_k*`` rows are the chunked a2a↔FEC K-sweep (the device
pipeline in repro.models.moe): simulated iteration time with both expert
paths chunked at K, derived = step speedup over the serial K=1 timeline
(strictly > 1 for K > 1 on these skewed loads — the chunked-overlap
acceptance shape).

The ``migration/*`` rows are the dynamic-expert-migration policy sweep
(owner re-layout, repro.core.planner strategies): per strategy the
simulated iteration time (µs) and derived = iteration speedup over the
shadow-only planner; the ``trans_gb`` rows report the modeled
steady-state Trans+Agg traffic each strategy pays per step, derived =
its reduction factor vs shadow-only (the acceptance shape: migration
drives steady-state comm below the shadow-only baseline on
persistent-skew traces)."""
from .simlib import (CLUSTERS, MIGRATION_STRATEGIES, SimConfig, chunk_sweep,
                     host_overlap, migration_sweep, simulate, speedup)

MODELS = ["moe-gpt-s", "moe-gpt-m", "moe-gpt-l", "moe-gpt-ds", "moe-gpt-dm"]
CHUNK_KS = (1, 2, 4, 8)


def run(iters: int = 20):
    rows = []
    for cluster, devices, tokens in (("HPWNV", 16, 16384),
                                     ("HPNV", 16, 16384),
                                     ("LPWNV", 8, 4096)):
        models = MODELS if cluster == "HPWNV" else [m for m in MODELS
                                                    if m != "moe-gpt-l"]
        for model in models:
            for k in (1, 2):
                sim = SimConfig(model=model, cluster=cluster,
                                devices=devices, tokens=tokens, top_k=k,
                                iters=iters)
                ds = simulate("deepspeed", sim)
                fm = simulate("fastermoe", sim)
                pp = simulate("pro_prophet", sim)
                rows.append((f"e2e/{cluster}/{model}/k{k}/vs_deepspeed",
                             pp.mean_iter * 1e6, speedup(ds, pp)))
                rows.append((f"e2e/{cluster}/{model}/k{k}/vs_fastermoe",
                             pp.mean_iter * 1e6, speedup(fm, pp)))
                if k == 1:
                    ov = host_overlap(sim, pp.mean_iter)
                    rows.append((f"e2e/{cluster}/{model}/host_plan",
                                 ov["mean_plan_s"] * 1e6,
                                 ov["hidden_frac"]))
                    # Forecast cadence backoff vs per-step planning on
                    # the same traces: derived = fraction of per-layer
                    # Plan primitives the backoff still executes
                    # (cadence-aware accounting, so the rows compare).
                    ovf = host_overlap(sim, pp.mean_iter, forecast=True)
                    rows.append((
                        f"e2e/{cluster}/{model}/host_plan_forecast",
                        ovf["mean_plan_s"] * 1e6,
                        ovf["plans_per_iter"]
                        / max(ov["plans_per_iter"], 1e-12)))
                    sweep = chunk_sweep(
                        SimConfig(model=model, cluster=cluster,
                                  devices=devices, tokens=tokens,
                                  top_k=k, iters=min(iters, 6)),
                        ks=CHUNK_KS)
                    for ck in CHUNK_KS:
                        rows.append((
                            f"e2e/{cluster}/{model}/a2a_chunks_k{ck}",
                            sweep[ck]["iter_s"] * 1e6,
                            sweep[1]["iter_s"] / sweep[ck]["iter_s"]))
                    mig = migration_sweep(
                        SimConfig(model=model, cluster=cluster,
                                  devices=devices, tokens=tokens,
                                  top_k=k, iters=min(iters, 10)))
                    base = mig["shadow"]
                    for strategy in MIGRATION_STRATEGIES:
                        s = mig[strategy]
                        rows.append((
                            f"e2e/{cluster}/{model}/migration/{strategy}",
                            s["iter_s"] * 1e6,
                            base["iter_s"] / max(s["iter_s"], 1e-12)))
                        rows.append((
                            f"e2e/{cluster}/{model}/migration/"
                            f"{strategy}_trans_gb",
                            s["trans_gb"] * 1e6,
                            base["trans_gb"] / max(s["trans_gb"], 1e-12)))
    return rows
