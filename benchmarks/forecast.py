"""Predictive load planning: forecast accuracy, plan-cadence backoff,
and prefetched relocation — the acceptance benchmark for the
forecast-driven runtime (repro.core.forecast + the engine's cadence
backoff + the trainer's relocation prefetch).

One :func:`benchmarks.simlib.forecast_sweep` drives two engines over
*identical* fluctuating→stabilizing gating streams
(:class:`~benchmarks.simlib.StabilizingTrace`):

* ``fixed``    — per-step planning, relocations executed synchronously
  on the dispatch path (each exchange blocks one dispatch);
* ``forecast`` — EMA forecaster + cadence backoff (stable layers skip
  the Plan primitive, bounded by ``plan_cadence_max``), relocations
  staged one step ahead and committed off the dispatch path.

Row shapes (acceptance criteria in ROADMAP.md):

* ``forecast/accuracy/{ema,last}`` — mean relative-L1 prediction error
  of the EMA forecast vs the last-value predictor on realized loads
  (derived; EMA must not be worse on the stabilizing trace);
* ``forecast/plans/{fixed,backoff}`` — per-layer Plan primitives
  executed (derived = fraction of the fixed-cadence count; the backoff
  row must be ≤ 0.5, i.e. ≥ 2× fewer plans);
* ``forecast/reloc_blocked/{sync,prefetch}`` — dispatches that waited on
  a relocation exchange (prefetch must be ≥ 2× fewer);
* ``forecast/uploads/{fixed,backoff}`` — placement uploads consumed;
* ``forecast/step_time/{fixed,forecast}`` — mean modeled step time in
  µs (derived = speedup vs fixed; must be ≥ ~1.0: backoff + prefetch
  may not slow the modeled step down).

The sweep is deterministic arithmetic over seeded traces, so the JSON
seed write (``BENCH_forecast.json``) is idempotent.
"""
import json
import os

from .simlib import SimConfig, forecast_sweep

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_forecast.json")

SWEEP = dict(cadence_max=16, experts_factor=4, window=50.0,
             stable_threshold=0.2, drift_threshold=0.35)


def run(iters: int = 30):
    sim = SimConfig(iters=iters)
    out = forecast_sweep(sim, **SWEEP)
    f, o, acc = out["fixed"], out["forecast"], out["accuracy"]
    rows = [
        ("forecast/accuracy/ema", 0.0, acc["ema"]),
        ("forecast/accuracy/last", 0.0, acc["last"]),
        ("forecast/plans/fixed", 0.0, f["plans"]),
        ("forecast/plans/backoff", 0.0,
         o["plans"] / max(f["plans"], 1.0)),
        ("forecast/reloc_blocked/sync", 0.0, f["reloc_blocked"]),
        ("forecast/reloc_blocked/prefetch", 0.0,
         o["reloc_blocked"] / max(f["reloc_blocked"], 1.0)),
        ("forecast/uploads/fixed", 0.0, f["uploads"]),
        ("forecast/uploads/backoff", 0.0,
         o["uploads"] / max(f["uploads"], 1.0)),
        ("forecast/step_time/fixed", f["step_s"] * 1e6, 1.0),
        ("forecast/step_time/forecast", o["step_s"] * 1e6,
         f["step_s"] / max(o["step_s"], 1e-12)),
        ("forecast/relocations/fixed", 0.0, f["relocations"]),
        ("forecast/relocations/forecast", 0.0, o["relocations"]),
    ]
    payload = json.dumps({"sim": {"model": sim.model,
                                  "cluster": sim.cluster,
                                  "devices": sim.devices,
                                  "tokens": sim.tokens,
                                  "iters": sim.iters,
                                  "skew": sim.skew, "seed": sim.seed},
                          "sweep": SWEEP, "result": out}, indent=1)
    try:
        # idempotent write: deterministic seeded arithmetic, so re-runs
        # must not dirty the committed trajectory seed
        if (not os.path.exists(_JSON_PATH)
                or open(_JSON_PATH).read() != payload):
            with open(_JSON_PATH, "w") as fh:
                fh.write(payload)
    except OSError:
        pass                     # read-only checkout: rows still stand
    return rows


def table(iters: int = 30):
    """Markdown summary for benchmarks.report."""
    out = forecast_sweep(SimConfig(iters=iters), **SWEEP)
    f, o, acc = out["fixed"], out["forecast"], out["accuracy"]
    return "\n".join([
        "| metric | fixed (per-step) | forecast (backoff+prefetch) | "
        "ratio |",
        "|---|---|---|---|",
        f"| plan invocations | {f['plans']:.0f} | {o['plans']:.0f} "
        f"| {f['plans'] / max(o['plans'], 1.0):.2f}x fewer |",
        f"| reloc-blocked dispatches | {f['reloc_blocked']:.0f} "
        f"| {o['reloc_blocked']:.0f} "
        f"| {f['reloc_blocked'] / max(o['reloc_blocked'], 1.0):.1f}x "
        f"fewer |",
        f"| placement uploads | {f['uploads']:.0f} | {o['uploads']:.0f} "
        f"| {f['uploads'] / max(o['uploads'], 1.0):.2f}x fewer |",
        f"| modeled step time | {f['step_s'] * 1e3:.2f} ms "
        f"| {o['step_s'] * 1e3:.2f} ms "
        f"| {f['step_s'] / max(o['step_s'], 1e-12):.3f}x |",
        f"| forecast error (rel-L1) | last-value {acc['last']:.3f} "
        f"| EMA {acc['ema']:.3f} "
        f"| {acc['last'] / max(acc['ema'], 1e-12):.2f}x |",
    ])


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived:.4f}")
