"""Figs. 11/12 analog: single-layer and single-iteration speedups on
MoE-GPT-M."""
import numpy as np

from .simlib import SimConfig, simulate


def run(iters: int = 30):
    rows = []
    for k in (1, 2):
        sim = SimConfig(model="moe-gpt-m", top_k=k, iters=iters)
        ds = simulate("deepspeed", sim)
        fm = simulate("fastermoe", sim)
        pp = simulate("pro_prophet", sim)
        # per-layer (Fig. 11)
        sl_ds = np.mean(ds.per_layer_time) / np.mean(pp.per_layer_time)
        sl_fm = np.mean(fm.per_layer_time) / np.mean(pp.per_layer_time)
        rows.append((f"fine/layer/k{k}/vs_deepspeed",
                     np.mean(pp.per_layer_time) * 1e6, sl_ds))
        rows.append((f"fine/layer/k{k}/vs_fastermoe",
                     np.mean(pp.per_layer_time) * 1e6, sl_fm))
        if k == 1:
            # per-iteration variability (Fig. 12): Pro-Prophet should be
            # both faster on average and more consistent.
            per_it = np.array(fm.iter_times) / np.array(pp.iter_times)
            rows.append(("fine/iteration/k1/mean_speedup_vs_fm",
                         np.mean(pp.iter_times) * 1e6, float(per_it.mean())))
            cv_pp = float(np.std(pp.iter_times) / np.mean(pp.iter_times))
            cv_fm = float(np.std(fm.iter_times) / np.mean(fm.iter_times))
            rows.append(("fine/iteration/k1/cv_ratio_fm_over_pp", 0.0,
                         cv_fm / max(cv_pp, 1e-9)))
    return rows
