"""Table I analog: load-balancing overhead (Search/Place/Reduce) of a
prior-art blocked method (FasterMoE-style) as a fraction of step time,
plus the chunked a2a↔FEC K-sweep: per-layer expert-path makespan and
timeline hidden-comm fraction vs the chunk count the device path runs
with (repro.models.moe; K chosen by repro.core.scheduler)."""
from .simlib import SimConfig, chunk_sweep, simulate

MODELS = ["moe-gpt-s", "moe-gpt-m", "moe-gpt-l", "moe-gpt-ds", "moe-gpt-dm"]
CHUNK_KS = (1, 2, 4, 8)


def run(iters: int = 12):
    rows = []
    for model in MODELS:
        sim = SimConfig(model=model, iters=iters)
        fm = simulate("fastermoe", sim)
        bd = fm.breakdown
        total = sum(bd.values())
        search = bd["plan"] / total
        place = bd["trans"] / total
        reduce_ = bd["agg"] / total
        lb = search + place + reduce_
        rows.append((f"breakdown/{model}/lb_frac", fm.mean_iter * 1e6, lb))
        rows.append((f"breakdown/{model}/search", 0.0, search))
        rows.append((f"breakdown/{model}/place", 0.0, place))
        rows.append((f"breakdown/{model}/reduce", 0.0, reduce_))
        # K-sweep: us = mean per-layer expert path (fwd+bwd), derived =
        # mean hidden-comm fraction of the chunked timeline.
        sweep = chunk_sweep(SimConfig(model=model, iters=min(iters, 6)),
                            ks=CHUNK_KS)
        for k in CHUNK_KS:
            rows.append((f"breakdown/{model}/chunk_k{k}",
                         sweep[k]["layer_s"] * 1e6,
                         sweep[k]["hidden_frac"]))
    return rows
