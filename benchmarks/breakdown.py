"""Table I analog: load-balancing overhead (Search/Place/Reduce) of a
prior-art blocked method (FasterMoE-style) as a fraction of step time."""
from .simlib import SimConfig, simulate

MODELS = ["moe-gpt-s", "moe-gpt-m", "moe-gpt-l", "moe-gpt-ds", "moe-gpt-dm"]


def run(iters: int = 12):
    rows = []
    for model in MODELS:
        sim = SimConfig(model=model, iters=iters)
        fm = simulate("fastermoe", sim)
        bd = fm.breakdown
        total = sum(bd.values())
        search = bd["plan"] / total
        place = bd["trans"] / total
        reduce_ = bd["agg"] / total
        lb = search + place + reduce_
        rows.append((f"breakdown/{model}/lb_frac", fm.mean_iter * 1e6, lb))
        rows.append((f"breakdown/{model}/search", 0.0, search))
        rows.append((f"breakdown/{model}/place", 0.0, place))
        rows.append((f"breakdown/{model}/reduce", 0.0, reduce_))
    return rows
