"""Fig. 14 analog: component ablation on MoE-GPT-M — planner only,
scheduler only, and the full planner×scheduler coupling (eq. 8)."""
from .simlib import SimConfig, simulate, speedup


def run(iters: int = 20):
    rows = []
    for k in (1, 2):
        sim = SimConfig(model="moe-gpt-m", top_k=k, iters=iters)
        base = simulate("deepspeed", sim)
        planner = simulate("planner", sim)
        sched = simulate("scheduler", sim)
        # planner + scheduler overlap but planning against eq. 6:
        pl_sched = simulate("planner", sim, scheduled=True)
        full = simulate("pro_prophet", sim)
        rows.append((f"ablation/k{k}/planner", planner.mean_iter * 1e6,
                     speedup(base, planner)))
        rows.append((f"ablation/k{k}/scheduler", sched.mean_iter * 1e6,
                     speedup(base, sched)))
        rows.append((f"ablation/k{k}/planner+scheduler",
                     pl_sched.mean_iter * 1e6, speedup(base, pl_sched)))
        # the eq.8 coupling's extra win over uncoupled planner+scheduler
        rows.append((f"ablation/k{k}/full_coupling_gain",
                     full.mean_iter * 1e6, speedup(pl_sched, full)))
    return rows
