"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts.  §Perf entries are maintained by hand (hillclimb log) in
perf_log.json and rendered here.

  PYTHONPATH=src:. python -m benchmarks.report > EXPERIMENTS.md
"""
import json
import os
from collections import defaultdict

from .roofline import CHIPS, HBM_BW, ICI_BW, PEAK_FLOPS, full_table, load_records

V5E_HBM_PER_CHIP = 16e9


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}"


def dryrun_section():
    lines = ["## §Dry-run", "",
             "Every (architecture × input-shape × mesh) lowered and "
             "compiled with `jax.jit(...).lower(**input_specs).compile()` "
             "on 512 placeholder devices; ShapeDtypeStruct stand-ins, no "
             "allocation.  Meshes: single pod `(16,16)('data','model')` "
             "= 256 chips, multi-pod `(2,16,16)('pod','data','model')` = "
             "512 chips.  Full per-pair artifacts (memory_analysis, "
             "cost_analysis, collective-byte breakdown, per-layer probes) "
             "in `artifacts/dryrun/*.json`.", ""]
    for mesh in ("single", "multi"):
        recs = load_records(mesh)
        ok = sum(r["status"] == "OK" for r in recs)
        sk = sum(r["status"] == "SKIP" for r in recs)
        fl = sum(r["status"] == "FAIL" for r in recs)
        lines += [f"### Mesh: {mesh} ({ok} OK / {sk} SKIP / {fl} FAIL)", ""]
        lines.append("| arch | shape | status | compile s | temp GB/dev | "
                     "arg GB/dev | a2a GB | all-gather GB | all-reduce GB | "
                     "reduce-scatter GB | permute GB |")
        lines.append("|---|---|---|---|---|---|---|---|---|---|---|")
        for r in recs:
            if r["status"] != "OK":
                lines.append(
                    f"| {r['arch']} | {r['shape']} | {r['status']} | - | - "
                    f"| - | - | - | - | - | - |")
                continue
            c = r.get("collectives", {})
            lines.append(
                f"| {r['arch']} | {r['shape']} | OK "
                f"| {r.get('compile_s', '-')} "
                f"| {_fmt_bytes(r.get('temp_size_in_bytes'))} "
                f"| {_fmt_bytes(r.get('argument_size_in_bytes'))} "
                f"| {_fmt_bytes(c.get('all-to-all'))} "
                f"| {_fmt_bytes(c.get('all-gather'))} "
                f"| {_fmt_bytes(c.get('all-reduce'))} "
                f"| {_fmt_bytes(c.get('reduce-scatter'))} "
                f"| {_fmt_bytes(c.get('collective-permute'))} |")
        lines.append("")
    lines += [
        "Notes:",
        "- collective byte columns are from the *full-step* HLO; scan "
        "bodies appear once (per-layer collective volumes are in the "
        "probes and drive §Roofline).",
        "- `temp GB/dev` above 16 GB flags configs that exceed v5e HBM "
        "as lowered (see the memory-honesty notes in §Roofline).",
        "- SKIPs are the intentional pairs from DESIGN.md §5 "
        "(encoder-only decode; full-attention long_500k).", ""]
    return "\n".join(lines)


def roofline_section():
    rows = full_table()
    lines = ["## §Roofline", "",
             "Per (arch × shape) on the single-pod mesh (256 chips), "
             "per-device terms assembled scan-aware from per-layer probes "
             "(XLA cost_analysis counts a `lax.scan` body once — see "
             "`repro.launch.dryrun.probe_layers`):", "",
             f"- compute term = HLO_FLOPs / {PEAK_FLOPS/1e12:.0f} TFLOP/s",
             f"- memory term = HLO_bytes / {HBM_BW/1e9:.0f} GB/s",
             f"- collective term = collective_bytes / {ICI_BW/1e9:.0f} GB/s"
             " (per-device ICI)", "",
             "| arch | shape | compute ms | memory ms | collective ms | "
             "dominant | model GFLOP/dev | useful ratio | next lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for a in rows:
        if "t_compute_s" not in a:
            lines.append(f"| {a['arch']} | {a['shape']} | - | - | - | "
                         f"{a['dominant']} | - | - | {a['hint']} |")
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} "
            f"| {a['t_compute_s']*1e3:.2f} | {a['t_memory_s']*1e3:.2f} "
            f"| {a['t_collective_s']*1e3:.2f} | **{a['dominant']}** "
            f"| {a['model_flops_dev']/1e9:.1f} "
            f"| {a['useful_ratio']:.3f} | {a['hint']} |")
    lines += ["",
              "`useful ratio` = MODEL_FLOPS (6·N_active·T train / "
              "2·N_active·T inference, per device) ÷ scan-corrected "
              "HLO FLOPs — >1 would mean undercounted HLO (probe gaps), "
              "≪1 flags remat/causal-block overcount or bandwidth-bound "
              "shapes where FLOPs aren't the story (decode).", ""]
    return "\n".join(lines)


def perf_section():
    path = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "perf_log.json")
    lines = ["## §Perf", ""]
    if not os.path.exists(path):
        lines.append("(no hillclimb entries yet)")
        return "\n".join(lines)
    with open(path) as f:
        log = json.load(f)
    for pair in log["pairs"]:
        lines += [f"### {pair['name']}", "", pair["why"], ""]
        lines.append("| iter | hypothesis | change | before (dominant) | "
                     "after | verdict |")
        lines.append("|---|---|---|---|---|---|")
        for i, it in enumerate(pair["iterations"]):
            lines.append(f"| {i} | {it['hypothesis']} | {it['change']} | "
                         f"{it['before']} | {it['after']} | {it['verdict']} |")
        lines.append("")
        if pair.get("summary"):
            lines += [pair["summary"], ""]
    if log.get("notes"):
        lines += ["### Notes", ""] + [f"- {n}" for n in log["notes"]] + [""]
    return "\n".join(lines)


def moe_ffn_section():
    from .moe_ffn import CAPACITY_FACTOR, table
    return "\n".join([
        "## §Ragged GMM", "",
        "Modeled FLOP utilization of the ragged Pallas expert FFN "
        "(`repro.kernels.ragged_gmm`, enabled by `REPRO_MOE_PALLAS`) vs "
        "the dense capacity-buffer einsum, as a function of expert-load "
        f"skew (power-law loads, capacity factor {CAPACITY_FACTOR}).  "
        "Counted at the kernel's MXU-tile granularity — `ragged speedup` "
        "is the modeled FEC/BEC win the load balancer's measurements "
        "ride on.  `perfmodel FEC util` is the eq.-2 straggler view "
        "(PerfModel.fec_utilization): once the hot expert saturates "
        "capacity the straggler device gains nothing from raggedness — "
        "the fleet-wide FLOP savings in `utilization` land on the other "
        "devices, which is exactly the imbalance Pro-Prophet's placement "
        "then moves.  Run `python -m benchmarks.run` (or "
        "`benchmarks.moe_ffn` directly) for the raw rows incl. measured "
        "µs on TPU.", "",
        table(), ""])


def dispatch_section():
    from .dispatch import CAPACITY_FACTOR, table
    return "\n".join([
        "## §Token permutation", "",
        "Modeled HBM traffic of the MoE capacity dispatch/combine "
        "(`repro.kernels.token_permute`, enabled by "
        "`REPRO_DISPATCH_PALLAS`) vs the jnp scatter/gather, over the "
        f"N/k/E grid (capacity factor {CAPACITY_FACTOR}).  The jnp "
        "dispatch repeats the activations k× and read-modify-writes the "
        "capacity buffer; the jnp combine materializes the `[N, k, d]` "
        "gather in f32.  The kernels stream the token panel and the "
        "buffer once each — `PerfModel.t_dispatch`/`t_combine` price "
        "both paths (agreement with these formulas pinned < 1e-12 in "
        "`perfmodel_accuracy.py`), and `benchmarks.dispatch` writes the "
        "sweep to `BENCH_dispatch.json` as the perf trajectory seed.", "",
        table(), ""])


def resilience_section():
    from .resilience import run as resilience_run
    rows = resilience_run(iters=30)
    by = {name: (us, derived) for name, us, derived in rows}
    free_us, _ = by["resilience/sim/fault_free"]
    bad_us, slowdown = by["resilience/sim/faulted"]
    _, fb_rate = by["resilience/sim/fallbacks"]
    _, stale = by["resilience/sim/stale_frac"]
    raw_us, _ = by["resilience/watchdog/raw_observe"]
    plan_us, ratio = by["resilience/watchdog/plan"]
    return "\n".join([
        "## §Resilience", "",
        "The self-healing runtime's two load-bearing numbers, measured by "
        "`benchmarks.resilience` (the production watchdog "
        "`repro.train.runtime.run_plan` + `repro.core.guard` driven "
        "through `repro.testing.faults` inside the simulated planner "
        "loop):", "",
        "| row | iter/plan µs | derived |",
        "|---|---|---|",
        f"| sim fault-free | {free_us:.1f} | 1.0 |",
        f"| sim faulted (2 planner faults + 2 corrupted-count batches) "
        f"| {bad_us:.1f} | {slowdown:.4f}x slowdown |",
        f"| fallback rate | - | {fb_rate:.3f}/iter "
        f"(stale-placement iters: {stale:.3f}) |",
        f"| bare engine.observe | {raw_us:.1f} | 1.0 |",
        f"| watchdog plan (sanitize+snapshot+validate) | {plan_us:.1f} "
        f"| {ratio:.2f}x observe |", "",
        "Fallback-to-last-good is cheap because of the same locality "
        "property that lets Plan overlap the device step: a stale "
        "placement stays near-optimal for the handful of iterations a "
        "fault costs, so the faulted run's iteration time is within "
        "noise of fault-free.  Loss is *bit-identical* under every fault "
        "class by construction (placements only move compute) — asserted "
        "end-to-end in `tests/test_resilience.py`.", ""])


def forecast_section():
    from .forecast import table
    return "\n".join([
        "## §Predictive planning", "",
        "Forecast-driven plan-cadence backoff + prefetched relocation "
        "(`repro.core.forecast`, `REPRO_FORECAST` / "
        "`REPRO_PLAN_CADENCE_MAX` / `REPRO_RELOC_PREFETCH`) vs per-step "
        "synchronous planning, on identical fluctuating→stabilizing "
        "gating streams (`benchmarks.simlib.forecast_sweep`; seed JSON "
        "in `BENCH_forecast.json`).  Loss is bit-identical by "
        "construction — placements and relocation *timing* only move "
        "compute — asserted end-to-end in `tests/test_forecast.py`.", "",
        table(), ""])


def main():
    header = os.path.join(os.path.dirname(__file__), "..",
                          "EXPERIMENTS.header.md")
    print(open(header).read() if os.path.exists(header)
          else "# EXPERIMENTS\n")
    print(dryrun_section())
    print(roofline_section())
    print(moe_ffn_section())
    print(dispatch_section())
    print(resilience_section())
    print(forecast_section())
    print(perf_section())


if __name__ == "__main__":
    main()
