"""Benchmark harness — one module per paper table/figure (DESIGN.md §9).

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the
simulated/measured time of the subject in µs (0.0 where the row is a pure
ratio); ``derived`` is the benchmark's headline metric (speedup, error,
fraction, RB, useful-FLOP ratio).
"""
import sys
import time


def main() -> None:
    from . import (ablation, balance, breakdown, cadence, dispatch,
                   end_to_end, fine_grained, forecast, locality, moe_ffn,
                   perfmodel_accuracy, policies, resilience, roofline)
    modules = [
        ("locality(Fig4)", locality),
        ("moe_ffn(ragged-GMM)", moe_ffn),
        ("dispatch(token-permute)", dispatch),
        ("breakdown(TableI)", breakdown),
        ("end_to_end(TablesIV-V,Fig10)", end_to_end),
        ("fine_grained(Figs11-12)", fine_grained),
        ("perfmodel_accuracy(Fig13)", perfmodel_accuracy),
        ("ablation(Fig14)", ablation),
        ("policies(Fig15)", policies),
        ("balance(Fig16)", balance),
        ("cadence(beyond-paper)", cadence),
        ("forecast(predictive)", forecast),
        ("resilience(watchdog)", resilience),
        ("roofline(Roofline)", roofline),
    ]
    print("name,us_per_call,derived")
    for label, mod in modules:
        t0 = time.time()
        rows = mod.run()
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived:.4f}")
        print(f"# {label} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
