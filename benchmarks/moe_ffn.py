"""Ragged-GMM microbenchmark: FLOP utilization of the MoE expert FFN as a
function of expert-load skew (repro.kernels.ragged_gmm vs the dense
capacity-buffer einsum).

Loads are drawn from a Zipf-style power law over experts (``alpha``
controls skew; measured skew = max load / mean load).  The capacity
buffer is sized like the model does (capacity_factor × mean load), hot
experts drop over-capacity tokens exactly like the dispatch path, and
modeled work is counted at the kernel's tile granularity — the same
predicate the kernel uses to skip MXU tiles, so the numbers are the
compute the hardware actually runs.

Rows (``derived`` column):
  moe_ffn/a<alpha>/skew            measured max/mean load ratio
  moe_ffn/a<alpha>/utilization     ragged FLOPs / dense FLOPs  (≤ 1)
  moe_ffn/a<alpha>/ragged_speedup  dense / ragged — the modeled FEC win

On TPU the per-call wall time of the fused pallas path is measured into
``us_per_call``; on other backends (interpret mode) timing is
meaningless and reported as 0.0.
"""
import time

import numpy as np

# Model-ish layer constants (small enough that the optional TPU timing
# pass stays cheap; modeled ratios are shape-independent up to tiling).
E, D, F = 16, 256, 512
TOKENS = 8192                 # total routed token-choices (512/expert mean,
                              # several MXU row tiles, so tile rounding is
                              # second-order in the modeled ratios)
CAPACITY_FACTOR = 1.25
ALPHAS = (0.0, 0.5, 1.0, 1.5, 2.0)


def skewed_loads(alpha: float, total: int = TOKENS, e: int = E):
    """Power-law expert loads summing to ``total`` (alpha=0 ⇒ uniform)."""
    w = (1.0 / np.arange(1, e + 1)) ** alpha
    loads = np.floor(w / w.sum() * total).astype(int)
    loads[0] += total - loads.sum()          # remainder to the hot expert
    return loads


def _time_pallas(loads, capacity):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    if jax.default_backend() != "tpu":
        return 0.0               # interpret-mode timing is meaningless
    x = jnp.zeros((E, capacity, D), jnp.bfloat16)
    wg = jnp.zeros((E, D, F), jnp.bfloat16)
    wi = jnp.zeros((E, D, F), jnp.bfloat16)
    wo = jnp.zeros((E, F, D), jnp.bfloat16)
    gs = jnp.asarray(loads, jnp.int32)

    def ffn():
        h = ops.gmm_swiglu(x, wg, wi, gs)
        return ops.ragged_gmm(h, wo, gs)

    ffn().block_until_ready()    # compile
    t0 = time.perf_counter()
    n = 10
    for _ in range(n):
        out = ffn()
    out.block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def run(measure: bool = True):
    """``measure=False`` skips the (TPU-only) wall-time pass — the
    modeled rows are pure arithmetic and safe to call from report
    generation without compiling anything."""
    from repro.core.perfmodel import (V5E_ICI_BW, V5E_PEAK_FLOPS,
                                      HardwareSpec, PerfModel)
    from repro.kernels.ragged_gmm import modeled_flops

    # Perfmodel view of the same layer: per-device FEC time under the
    # straggler max (eq. 2) vs a dense capacity-padded kernel — the
    # time-domain counterpart of the tile-level utilization below.
    hw = HardwareSpec.from_model_dims(D, F, bandwidth=V5E_ICI_BW,
                                      flops_per_s=V5E_PEAK_FLOPS,
                                      num_ffn_mats=3)
    pm = PerfModel(hw, E)        # one expert per device for this sweep

    rows = []
    mean = TOKENS / E
    capacity = int(mean * CAPACITY_FACTOR)
    for alpha in ALPHAS:
        loads = skewed_loads(alpha)
        skew = float(loads.max() / mean)
        kept = np.minimum(loads, capacity)   # dispatch drops the rest
        # Expert FFN = 2 up-projections (fused) + 1 down-projection, all
        # ragged on the same counts.
        up_r, up_d = modeled_flops(capacity, D, F, kept, capacity,
                                   num_mats=2)
        dn_r, dn_d = modeled_flops(capacity, F, D, kept, capacity)
        ragged, dense = up_r + dn_r, up_d + dn_d
        util = ragged / dense
        us = _time_pallas(kept, capacity) if measure else 0.0
        rows.append((f"moe_ffn/a{alpha}/skew", 0.0, skew))
        rows.append((f"moe_ffn/a{alpha}/utilization", us, util))
        rows.append((f"moe_ffn/a{alpha}/ragged_speedup", 0.0,
                     dense / max(ragged, 1)))
        rows.append((f"moe_ffn/a{alpha}/perfmodel_fec_util",
                     pm.t_fec(kept) * 1e6,
                     pm.fec_utilization(kept, capacity)))
    return rows


def table():
    """Markdown rows for benchmarks.report — modeled numbers only (no
    kernel compilation or timing)."""
    lines = ["| alpha | skew (max/mean) | utilization | ragged speedup |"
             " perfmodel FEC util |",
             "|---|---|---|---|---|"]
    by_alpha = {}
    for name, _, val in run(measure=False):
        a = name.split("/")[1][1:]
        by_alpha.setdefault(a, {})[name.rsplit("/", 1)[1]] = val
    for a, vals in by_alpha.items():
        lines.append(f"| {a} | {vals['skew']:.2f} "
                     f"| {vals['utilization']:.3f} "
                     f"| {vals['ragged_speedup']:.2f}× "
                     f"| {vals['perfmodel_fec_util']:.3f} |")
    return "\n".join(lines)
