"""Beyond-paper ablation: locality-based replan cadence.

The paper notes the search frequency can be reduced "based on the
locality" but does not quantify it.  We sweep replan_interval × drift:
with paper-like locality a stale plan stays near-optimal for many
iterations (amortizing Plan); when locality is broken the cached plan
decays — quantifying exactly when the locality assumption pays."""
import numpy as np

from repro.core import GatingTrace, GreedyPlanner, HardwareSpec, LocalityPlanner, PerfModel


def run(iters: int = 40):
    rows = []
    D = E = 16
    hw = HardwareSpec.from_model_dims(1024, 2048, bandwidth=10e9,
                                      flops_per_s=35e12, num_ffn_mats=2,
                                      t_fnec=1e-3, t_bnec=2e-3)
    perf = PerfModel(hw, D)
    for drift, dlabel in ((0.05, "paper_like"), (0.5, "no_locality")):
        base_times = None
        for interval in (1, 5, 20):
            planner = LocalityPlanner(
                GreedyPlanner(perf, n=2, alpha=0.25, s_max=8,
                              scheduled=True),
                D, E, replan_interval=interval)
            trace = GatingTrace(D, E, 1024, skew=0.25, drift=drift, seed=0)
            times = []
            prev = None
            for _ in range(iters):
                g = trace.step()
                res = planner.maybe_plan(prev if prev is not None else g)
                prev = g
                times.append(perf.layer_time_for(res.placement, g,
                                                 scheduled=True))
            mean_t = float(np.mean(times))
            if interval == 1:
                base_times = mean_t
            rows.append((f"cadence/{dlabel}/interval{interval}",
                         mean_t * 1e6, base_times / mean_t))
    return rows
