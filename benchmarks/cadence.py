"""Beyond-paper ablation: locality-based replan cadence + plan overlap.

The paper notes the search frequency can be reduced "based on the
locality" but does not quantify it.  We sweep replan_interval × drift:
with paper-like locality a stale plan stays near-optimal for many
iterations (amortizing Plan); when locality is broken the cached plan
decays — quantifying exactly when the locality assumption pays.

The ``cadence/overlap/*`` rows exercise the async runtime's telemetry
surface (repro.train.runtime.OverlapTelemetry): measured wall-clock Plan
latency of a full engine (all MoE layers), the simulated device-step
window it hides under, the hidden fraction, and the host-side per-step
overhead (exposed plan + placement pack/upload) of the pipelined runtime
vs the serial baseline — the latter must be measurably lower at
``replan_interval=1``."""
import numpy as np

from repro.core import (EngineConfig, GatingTrace, GreedyPlanner,
                        HardwareSpec, LocalityPlanner, PerfModel,
                        ProProphetEngine)


def run(iters: int = 40):
    rows = []
    D = E = 16
    hw = HardwareSpec.from_model_dims(1024, 2048, bandwidth=10e9,
                                      flops_per_s=35e12, num_ffn_mats=2,
                                      t_fnec=1e-3, t_bnec=2e-3)
    perf = PerfModel(hw, D)
    for drift, dlabel in ((0.05, "paper_like"), (0.5, "no_locality")):
        base_times = None
        for interval in (1, 5, 20):
            planner = LocalityPlanner(
                GreedyPlanner(perf, n=2, alpha=0.25, s_max=8,
                              scheduled=True),
                D, E, replan_interval=interval)
            trace = GatingTrace(D, E, 1024, skew=0.25, drift=drift, seed=0)
            times = []
            prev = None
            for _ in range(iters):
                g = trace.step()
                res = planner.maybe_plan(prev if prev is not None else g)
                prev = g
                times.append(perf.layer_time_for(res.placement, g,
                                                 scheduled=True))
            mean_t = float(np.mean(times))
            if interval == 1:
                base_times = mean_t
            rows.append((f"cadence/{dlabel}/interval{interval}",
                         mean_t * 1e6, base_times / mean_t))
    rows.extend(overlap_rows(iters))
    return rows


def overlap_rows(iters: int = 30):
    """Plan-overlap telemetry for a whole-engine (L MoE layers) loop.

    Per iteration: wall-clock the Plan primitive (``engine.observe`` over
    all layers) and the placement pack (paid only when the placements
    changed), then score it against the engine's own predicted device
    step.  The async runtime exposes ``max(0, plan − step) + upload``;
    the serial baseline exposes ``plan + upload`` every step."""
    from .simlib import measure_plan_overlap

    D = E = 16
    L = 8
    hw = HardwareSpec.from_model_dims(1024, 2048, bandwidth=10e9,
                                      flops_per_s=35e12, num_ffn_mats=2,
                                      t_fnec=1e-3, t_bnec=2e-3)

    # Device window the plan hides under: the engine's predicted
    # MoE-layer times + the static non-MoE fwd/bwd per layer.
    def step_window(eng):
        return (eng.predicted_times()["predicted"]
                + L * (hw.t_fnec + hw.t_bnec))

    rows = []
    variants = [(f"interval{i}", dict(replan_interval=i)) for i in (1, 5, 20)]
    # Forecast cadence backoff: per-step cadence that backs itself off on
    # stable layers (bounded by plan_cadence_max) — comparable to the
    # fixed-interval rows above because the plans-per-iteration counter
    # comes from the same cadence-aware engine accounting.
    variants.append(("forecast", dict(replan_interval=1,
                                      enable_forecast=True,
                                      plan_cadence_max=16)))
    for label, kw in variants:
        ec = EngineConfig(num_experts=E, num_devices=D, num_moe_layers=L,
                          s_max=8, n=2, scheduled=True, **kw)
        eng = ProProphetEngine(ec, hw)
        traces = [GatingTrace(D, E, 1024, skew=0.25, drift=0.05, seed=li)
                  for li in range(L)]
        tel, uploads, plans = measure_plan_overlap(eng, traces, step_window,
                                                   iters)
        s = tel.summary()
        pre = f"cadence/overlap/{label}"
        rows.append((f"{pre}/plan", s["mean_plan_s"] * 1e6,
                     s["hidden_frac"]))
        rows.append((f"{pre}/step", s["mean_step_s"] * 1e6,
                     s["mean_plan_s"] / max(s["mean_step_s"], 1e-12)))
        rows.append((f"{pre}/host_overhead", s["host_overhead_s"] * 1e6,
                     s["host_overhead_s"] / max(s["serial_overhead_s"],
                                                1e-12)))
        rows.append((f"{pre}/uploads", s["mean_upload_s"] * 1e6,
                     uploads / iters))
        rows.append((f"{pre}/plans", 0.0, plans / (iters * L)))
    return rows
