"""Fig. 13 analog: accuracy of the performance model against REAL measured
execution on this host.

We calibrate each term's hardware constant on ONE reference shape, then
predict across a sweep of other shapes/loads and report |err|/measured.
Components: expert computation (grouped matmul), A2A (memcpy-bound token
exchange stand-in), Trans/Agg (parameter copy).  Target: mean error < 5 %
(paper's claim) for compute; communication is memcpy-stand-in on CPU.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np


def _t(f, *a, reps=3):
    f(*a)  # compile/warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*a))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # --- expert computation: T = max_i H_i / t (eq. 2) ------------------
    d, f = 512, 1024
    gmm = jax.jit(lambda x, w: jnp.einsum("gtd,gdf->gtf", x, w))
    w = jax.random.normal(key, (4, d, f), jnp.float32)
    # calibrate throughput on H=2048
    href = 2048
    xref = jax.random.normal(key, (4, href, d), jnp.float32)
    tref = _t(gmm, xref, w)
    thr = 4 * href / tref                       # tokens/s
    errs = []
    for h in (512, 1024, 4096, 8192):
        x = jax.random.normal(key, (4, h, d), jnp.float32)
        meas = _t(gmm, x, w)
        pred = 4 * h / thr
        errs.append(abs(pred - meas) / meas)
    rows.append(("perfmodel/ec_mean_err", tref * 1e6,
                 float(np.mean(errs))))

    # --- Trans/Agg: parameter-copy cost linear in s (eq. 4) ------------
    copy = jax.jit(lambda a: a * 1.0)
    sref = 4
    pref = jax.random.normal(key, (sref, d, f), jnp.float32)
    tref = _t(copy, pref)
    per_expert = tref / sref
    errs = []
    for s in (1, 2, 8, 16):
        p = jax.random.normal(key, (s, d, f), jnp.float32)
        meas = _t(copy, p)
        pred = s * per_expert
        errs.append(abs(pred - meas) / meas)
    rows.append(("perfmodel/trans_mean_err", tref * 1e6,
                 float(np.mean(errs))))

    # --- chunked-overlap term vs the §V timeline -----------------------
    # PerfModel.chunked_path_time is the closed form of the scheduler's
    # list-scheduled chunked a2a↔FEC pipeline (same graph, same program
    # order) — validate it against core/scheduler.py for the same K grid
    # the engine chooses from.  Target: exact (err ≈ float eps).
    from repro.core import scheduler as _sched
    from repro.core.perfmodel import PerfModel as _PM
    cerrs = []
    for a2a_t in (1e-4, 1e-3, 5e-3):
        for fec_t in (1e-4, 2e-3, 1e-2):
            for k in (1, 2, 4, 8):
                for oh in (0.0, 2e-5):
                    # incl. the serial HBM-bound permute legs (dispatch
                    # fronts the pipeline, combine tails it)
                    for td, tc in ((0.0, 0.0), (3e-4, 5e-4)):
                        tl = _sched.chunked_makespan(
                            a2a_t, fec_t, k, chunk_overhead=oh,
                            t_dispatch=td, t_combine=tc)
                        cf = _PM.chunked_path_time(
                            a2a_t, fec_t, k, chunk_overhead=oh,
                            t_dispatch=td, t_combine=tc)
                        cerrs.append(abs(cf - tl) / tl)
    rows.append(("perfmodel/chunked_overlap_err", 0.0,
                 float(np.mean(cerrs))))

    # --- token-permutation terms vs the kernels' modeled bytes ---------
    # PerfModel.t_dispatch/t_combine must price exactly the traffic the
    # token_permute kernels model (dispatch_modeled_bytes /
    # combine_modeled_bytes) over the HBM bandwidth, for both the Pallas
    # and jnp paths.  Target: < 1e-12 relative (same closed forms, float
    # association noise only).
    from repro.core.perfmodel import HardwareSpec as _HW
    from repro.core.perfmodel import PerfModel as _PM2
    from repro.kernels.token_permute import (combine_modeled_bytes,
                                             dispatch_modeled_bytes)
    perrs = []
    for d_model in (256, 1024):
        hw2 = _HW(bandwidth=1e9, throughput=1e9,
                  input_bytes=d_model * 2, expert_param_bytes=1e6)
        pm2 = _PM2(hw2, 8)
        for n in (2048, 8192):
            for k in (1, 2, 4):
                slots = int(1.25 * n * k)
                for pallas in (True, False):
                    pairs = (
                        (pm2.t_dispatch(n, slots, top_k=k, pallas=pallas),
                         dispatch_modeled_bytes(n, slots, d_model, top_k=k,
                                                pallas=pallas)),
                        (pm2.t_combine(n, slots, top_k=k, pallas=pallas),
                         combine_modeled_bytes(n, slots, d_model, top_k=k,
                                               pallas=pallas)))
                    for t, b in pairs:
                        perrs.append(abs(t * hw2.hbm_bandwidth - b) / b)
    assert max(perrs) < 1e-12, max(perrs)
    rows.append(("perfmodel/permute_bytes_err", 0.0, float(max(perrs))))

    # --- A2A stand-in: token permutation, linear in max R_i (eq. 1) ----
    perm = jax.jit(lambda x, i: x[i])
    nref = 8192
    xref = jax.random.normal(key, (nref, d), jnp.float32)
    iref = jax.random.permutation(key, nref)
    tref = _t(perm, xref, iref)
    per_tok = tref / nref
    errs = []
    for n in (2048, 4096, 16384, 32768):
        x = jax.random.normal(key, (n, d), jnp.float32)
        i = jax.random.permutation(key, n)
        meas = _t(perm, x, i)
        errs.append(abs(n * per_tok - meas) / meas)
    rows.append(("perfmodel/a2a_mean_err", tref * 1e6, float(np.mean(errs))))
    return rows
