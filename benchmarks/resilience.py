"""Resilience benchmark: the plan watchdog under injected faults.

Drives the production degradation path (repro.train.runtime.run_plan +
repro.core.guard + repro.testing.faults) inside the simulated planner
loop (simlib.fault_sweep) and quantifies the two numbers the self-healing
design rests on:

* **fallback cost** — ``resilience/sim/faulted`` vs ``fault_free``: a
  rejected plan leaves the next iteration on *stale* placements; under
  paper-like locality (the same property that lets Plan overlap the
  device step) the stale plan is near-optimal, so the slowdown should be
  ~1.0x even with several faults per run.  That ratio is the empirical
  license for fallback-to-last-good instead of blocking recovery.

* **watchdog overhead** — ``resilience/watchdog/plan`` vs ``raw_observe``:
  the per-plan wall-clock cost of sanitization + snapshot + invariant
  validation on top of the bare engine ingest.  It rides the host path
  that the async runtime already hides under the device step, but it must
  stay small enough not to widen the Plan window materially.
"""
import time

import numpy as np

from repro.core import (EngineConfig, GatingTrace, HardwareSpec,
                        ProProphetEngine)
from repro.train.runtime import run_plan

from .simlib import SimConfig, fault_sweep


def run(iters: int = 30):
    rows = []
    sim = SimConfig(model="moe-gpt-m", cluster="HPWNV", devices=16,
                    iters=iters)
    res = fault_sweep(sim)
    free, bad = res["fault_free"], res["faulted"]
    rows.append(("resilience/sim/fault_free", free["iter_s"] * 1e6, 1.0))
    rows.append(("resilience/sim/faulted", bad["iter_s"] * 1e6,
                 bad["slowdown"]))
    rows.append(("resilience/sim/fallbacks", 0.0,
                 bad["fallbacks"] / iters))
    rows.append(("resilience/sim/sanitized_layers", 0.0, bad["sanitized"]))
    rows.append(("resilience/sim/stale_frac", 0.0, bad["stale_frac"]))
    rows.extend(watchdog_rows(iters))
    return rows


def watchdog_rows(iters: int = 30):
    """Measured wall-clock cost of the watchdog wrapper (sanitize +
    snapshot + validate) vs the bare ``engine.observe`` ingest."""
    D = E = 16
    L = 8
    hw = HardwareSpec.from_model_dims(1024, 2048, bandwidth=10e9,
                                      flops_per_s=35e12, num_ffn_mats=2,
                                      t_fnec=1e-3, t_bnec=2e-3)

    def engine():
        ec = EngineConfig(num_experts=E, num_devices=D, num_moe_layers=L,
                          s_max=8, n=2, scheduled=True)
        return ProProphetEngine(ec, hw)

    traces = [GatingTrace(D, E, 1024, skew=0.25, drift=0.05, seed=li)
              for li in range(L)]
    counts = [np.stack([t.step() for t in traces]) for _ in range(iters)]

    eng = engine()
    t0 = time.perf_counter()
    for c in counts:
        eng.observe(list(c))
    raw = (time.perf_counter() - t0) / iters

    eng = engine()
    t0 = time.perf_counter()
    for c in counts:
        ev = run_plan(eng, c)
        assert ev.ok
    guarded = (time.perf_counter() - t0) / iters

    return [("resilience/watchdog/raw_observe", raw * 1e6, 1.0),
            ("resilience/watchdog/plan", guarded * 1e6,
             guarded / max(raw, 1e-12))]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived:.4f}")
