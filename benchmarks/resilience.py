"""Resilience benchmark: the plan watchdog under injected faults.

Drives the production degradation path (repro.train.runtime.run_plan +
repro.core.guard + repro.testing.faults) inside the simulated planner
loop (simlib.fault_sweep) and quantifies the two numbers the self-healing
design rests on:

* **fallback cost** — ``resilience/sim/faulted`` vs ``fault_free``: a
  rejected plan leaves the next iteration on *stale* placements; under
  paper-like locality (the same property that lets Plan overlap the
  device step) the stale plan is near-optimal, so the slowdown should be
  ~1.0x even with several faults per run.  That ratio is the empirical
  license for fallback-to-last-good instead of blocking recovery.

* **watchdog overhead** — ``resilience/watchdog/plan`` vs ``raw_observe``:
  the per-plan wall-clock cost of sanitization + snapshot + invariant
  validation on top of the bare engine ingest.  It rides the host path
  that the async runtime already hides under the device step, but it must
  stay small enough not to widen the Plan window materially.

* **degraded-mode cost** — ``resilience/health/*``: an EP rank stops
  reporting heartbeats mid-run; the health tracker classifies it *lost*
  after its patience window, the forced replan evacuates every resident
  expert (slot swaps + forced shadows), and the remaining fleet carries
  the remaining load.  ``steps_to_rebalance`` counts iterations from
  fault onset to the first all-layers-evacuated placement (detection
  patience + at most one plan cadence); ``faulted_settled`` is the
  modeled step time after settling vs the clean run — the acceptance
  bound is ≤ 1.05x (the dead rank's tokens leave with it, so the
  survivors' per-device load is essentially unchanged).
"""
import json
import os
import time

import numpy as np

from repro.core import (EngineConfig, GatingTrace, HardwareSpec,
                        ProProphetEngine)
from repro.train.runtime import run_plan

from .simlib import SimConfig, fault_sweep

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_resilience.json")


def run(iters: int = 30):
    rows = []
    sim = SimConfig(model="moe-gpt-m", cluster="HPWNV", devices=16,
                    iters=iters)
    res = fault_sweep(sim)
    free, bad = res["fault_free"], res["faulted"]
    rows.append(("resilience/sim/fault_free", free["iter_s"] * 1e6, 1.0))
    rows.append(("resilience/sim/faulted", bad["iter_s"] * 1e6,
                 bad["slowdown"]))
    rows.append(("resilience/sim/fallbacks", 0.0,
                 bad["fallbacks"] / iters))
    rows.append(("resilience/sim/sanitized_layers", 0.0, bad["sanitized"]))
    rows.append(("resilience/sim/stale_frac", 0.0, bad["stale_frac"]))
    rows.extend(watchdog_rows(iters))
    health = health_sweep(iters=max(iters, 24))
    rows.append(("resilience/health/steps_to_rebalance", 0.0,
                 health["steps_to_rebalance"]))
    rows.append(("resilience/health/evacuated_experts", 0.0,
                 health["evacuated"]))
    rows.append(("resilience/health/clean_step",
                 health["clean_step_s"] * 1e6, 1.0))
    rows.append(("resilience/health/faulted_settled",
                 health["faulted_step_s"] * 1e6,
                 health["step_ratio_settled"]))
    payload = json.dumps({"health": health}, indent=1)
    try:
        # idempotent write: deterministic seeded arithmetic, so re-runs
        # must not dirty the committed trajectory seed
        if (not os.path.exists(_JSON_PATH)
                or open(_JSON_PATH).read() != payload):
            with open(_JSON_PATH, "w") as fh:
                fh.write(payload)
    except OSError:
        pass                     # read-only checkout: rows still stand
    return rows


def health_sweep(iters: int = 30, *, fault_at: int = 8, lost: int = 3):
    """Device-loss episode on a 16-device engine with health tracking:
    seeded gating traces drive ``observe``; from ``fault_at`` on, device
    ``lost`` misses every heartbeat (NaN step time) and produces no
    tokens.  Returns the settled faulted-vs-clean modeled step-time
    ratio and the iterations from onset to full evacuation.

    The cluster profile uses NVLink/ICI-class links (100 GB/s): the
    settled-ratio bound only holds where the forced evacuation shadows'
    parameter broadcast hides under non-expert compute — on a 10 GB/s
    fabric the planner (correctly) prices the broadcast as unhideable
    and a lost rank costs ~1.5x, which is a property of the fabric, not
    of the evacuation machinery this sweep measures."""
    D, E, L = 16, 32, 4
    hw = HardwareSpec.from_model_dims(1024, 2048, bandwidth=100e9,
                                      flops_per_s=35e12, num_ffn_mats=2,
                                      t_fnec=1e-3, t_bnec=2e-3)

    def engine():
        ec = EngineConfig(num_experts=E, num_devices=D, num_moe_layers=L,
                          s_max=8, n=2, scheduled=True,
                          enable_health=True)
        return ProProphetEngine(ec, hw)

    traces = [GatingTrace(D, E, 1024, skew=0.25, drift=0.05, seed=li)
              for li in range(L)]
    counts = [np.stack([t.step() for t in traces]) for _ in range(iters)]

    def step_time(eng, c):
        t = 0.0
        for li in range(L):
            pl = eng.placements[li]
            H, R = pl.compute_loads(c[li])
            t += eng.perf.layer_time_scheduled(R, H, pl.num_shadowed,
                                               eng.cfg.n)
        return t

    clean = engine()
    t_clean = []
    for c in counts:
        clean.observe_timings(np.full(D, 1.0))
        clean.observe(list(c))
        t_clean.append(step_time(clean, c))

    bad = engine()
    t_bad = []
    rebalanced_at = None
    probe = np.ones((D, E))
    for i, c in enumerate(counts):
        times = np.full(D, 1.0)
        if i >= fault_at:
            times[lost] = np.nan      # missed heartbeat
            c = c.copy()
            c[:, lost, :] = 0.0       # the dead rank produces no tokens
        bad.observe_timings(times)
        bad.observe(list(c))
        t_bad.append(step_time(bad, c))
        if rebalanced_at is None and i >= fault_at and all(
                pl.compute_loads(probe)[1][lost] == 0.0
                for pl in bad.placements):
            rebalanced_at = i
    assert rebalanced_at is not None, "lost rank was never evacuated"
    settle = rebalanced_at + 1
    clean_s = float(np.mean(t_clean[settle:]))
    bad_s = float(np.mean(t_bad[settle:]))
    return {
        "devices": D, "experts": E, "layers": L, "iters": iters,
        "fault_at": fault_at, "lost_device": lost,
        "detected_summary": bad.health_summary(),
        "steps_to_rebalance": float(rebalanced_at - fault_at),
        "evacuated": float(bad.evacuations),
        "clean_step_s": clean_s,
        "faulted_step_s": bad_s,
        "step_ratio_settled": bad_s / max(clean_s, 1e-12),
    }


def watchdog_rows(iters: int = 30):
    """Measured wall-clock cost of the watchdog wrapper (sanitize +
    snapshot + validate) vs the bare ``engine.observe`` ingest."""
    D = E = 16
    L = 8
    hw = HardwareSpec.from_model_dims(1024, 2048, bandwidth=10e9,
                                      flops_per_s=35e12, num_ffn_mats=2,
                                      t_fnec=1e-3, t_bnec=2e-3)

    def engine():
        ec = EngineConfig(num_experts=E, num_devices=D, num_moe_layers=L,
                          s_max=8, n=2, scheduled=True)
        return ProProphetEngine(ec, hw)

    traces = [GatingTrace(D, E, 1024, skew=0.25, drift=0.05, seed=li)
              for li in range(L)]
    counts = [np.stack([t.step() for t in traces]) for _ in range(iters)]

    eng = engine()
    t0 = time.perf_counter()
    for c in counts:
        eng.observe(list(c))
    raw = (time.perf_counter() - t0) / iters

    eng = engine()
    t0 = time.perf_counter()
    for c in counts:
        ev = run_plan(eng, c)
        assert ev.ok
    guarded = (time.perf_counter() - t0) / iters

    return [("resilience/watchdog/raw_observe", raw * 1e6, 1.0),
            ("resilience/watchdog/plan", guarded * 1e6,
             guarded / max(raw, 1e-12))]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived:.4f}")
