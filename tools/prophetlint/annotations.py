"""Annotation grammar shared by all prophetlint rules.

Annotations are ordinary comments starting with ``# prophetlint:``.  A
directive may continue across the following lines of the *same
contiguous comment block*; continuation lines are joined with their
leading ``#`` and whitespace stripped.  Three directives exist:

``allow(<rule>): <reason>``
    Suppress ``<rule>`` violations on the annotated code.  The reason is
    mandatory — an allow without one is itself reported.  Coverage: the
    comment's own line(s) plus the next statement after the comment
    block (through its last line), or — for a trailing comment — the
    statement on that line.

``shared(<field>, ...): owner=<method>, ...`` or ``lock=<attr>``
    Class-body registry of concurrency-sensitive fields (rule R4).  In
    ``owner`` mode the listed methods (plus ``__init__``) are the only
    code allowed to touch the fields; in ``lock`` mode every access must
    sit inside ``with self.<attr>:``.

``bounded(<name>): <kind-or-provenance>``
    R3 boundedness. Covering a ``jax.jit`` call it *declares* the static
    argument's candidate set — kind must be ``bool``, a literal set like
    ``{1, 2, 4, 8}``, ``shape-derived`` or ``config`` (free text may
    follow).  Covering a call of a jitted function it documents the
    *provenance* of a non-literal static argument (free text).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Dict, List, Optional, Set, Tuple

DIRECTIVE_RE = re.compile(r"#\s*prophetlint:\s*(.*)$")
ALLOW_RE = re.compile(r"allow\(([\w-]+)\)\s*:\s*(.*)", re.S)
SHARED_RE = re.compile(r"shared\(([^)]*)\)\s*:\s*(.*)", re.S)
BOUNDED_RE = re.compile(r"bounded\(([\w.]+)\)\s*:\s*(.*)", re.S)


@dataclasses.dataclass
class Allow:
    rule: str
    reason: str
    line: int               # first comment line of the directive
    lines: Set[int] = dataclasses.field(default_factory=set)  # coverage
    used: bool = False


@dataclasses.dataclass
class SharedRegistry:
    fields: Tuple[str, ...]
    mode: str               # "owner" | "lock"
    owners: Tuple[str, ...]  # owner mode: allowed methods
    lock: str               # lock mode: attribute name
    line: int


@dataclasses.dataclass
class Bounded:
    name: str
    text: str               # kind (declaration) or provenance (call site)
    line: int
    lines: Set[int] = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class FileAnnotations:
    allows: List[Allow]
    registries: List[SharedRegistry]
    bounded: List[Bounded]
    errors: List[Tuple[int, str]]   # malformed directives

    def allowed(self, rule: str, line: int) -> Optional[Allow]:
        for a in self.allows:
            if a.rule == rule and line in a.lines:
                a.used = True
                return a
        return None

    def bounded_at(self, name: str, line: int) -> Optional[Bounded]:
        for b in self.bounded:
            if line in b.lines and (b.name == name
                                    or b.name.endswith("." + name)):
                return b
        return None


def _comment_blocks(source: str):
    """Yield contiguous comment runs as lists of (line, text).  A
    trailing comment (code on the same line) forms its own block."""
    toks = list(tokenize.generate_tokens(io.StringIO(source).readline))
    blocks: List[List[Tuple[int, str]]] = []
    cur: List[Tuple[int, str]] = []
    lines = source.splitlines()
    prev_line = -2
    for tok in toks:
        if tok.type != tokenize.COMMENT:
            continue
        line = tok.start[0]
        standalone = lines[line - 1][: tok.start[1]].strip() == ""
        if standalone and cur and line == prev_line + 1:
            cur.append((line, tok.string))
        else:
            if cur:
                blocks.append(cur)
            cur = [(line, tok.string)]
        prev_line = line if standalone else -2
    if cur:
        blocks.append(cur)
    return blocks


def _statement_spans(tree: ast.AST) -> List[Tuple[int, int]]:
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, ast.stmt):
            start = node.lineno
            # a decorated def/class starts at its first decorator, so a
            # comment above the decorators annotates the whole thing
            for dec in getattr(node, "decorator_list", []):
                start = min(start, dec.lineno)
            spans.append((start, node.end_lineno or node.lineno))
    return sorted(spans)


def _coverage(first_comment: int, last_comment: int,
              spans: List[Tuple[int, int]], same_line: bool) -> Set[int]:
    """Lines a directive applies to: its own comment lines plus the
    statement it annotates (trailing comment: the statement on that
    line; block comment: the next statement after the block)."""
    cov = set(range(first_comment, last_comment + 1))
    if same_line:
        # trailing comment — cover the statement ending on this line
        for a, b in spans:
            if a <= first_comment <= b:
                cov.update(range(a, b + 1))
        return cov
    nxt = None
    for a, b in spans:
        if a > last_comment:
            nxt = (a, b)
            break
    if nxt is not None:
        cov.update(range(nxt[0], nxt[1] + 1))
    return cov


def _split_fields(s: str) -> Tuple[str, ...]:
    return tuple(x.strip() for x in s.split(",") if x.strip())


def collect(source: str, tree: ast.AST) -> FileAnnotations:
    ann = FileAnnotations([], [], [], [])
    spans = _statement_spans(tree)
    src_lines = source.splitlines()
    for block in _comment_blocks(source):
        # split the block into directives: a new directive starts at any
        # line matching DIRECTIVE_RE; lines between belong to the
        # previous directive (continuations)
        i = 0
        while i < len(block):
            line_no, text = block[i]
            m = DIRECTIVE_RE.search(text)
            if not m:
                i += 1
                continue
            body = m.group(1)
            last = line_no
            j = i + 1
            while j < len(block) and not DIRECTIVE_RE.search(block[j][1]):
                cont = block[j][1].lstrip("#").strip()
                body += " " + cont
                last = block[j][0]
                j += 1
            i = j
            same_line = src_lines[line_no - 1].lstrip()[0] != "#"
            cov = _coverage(line_no, last, spans, same_line)
            _parse_directive(ann, body.strip(), line_no, cov)
    return ann


def _parse_directive(ann: FileAnnotations, body: str, line: int,
                     cov: Set[int]) -> None:
    m = ALLOW_RE.match(body)
    if m:
        rule, reason = m.group(1), m.group(2).strip()
        if not reason:
            ann.errors.append(
                (line, f"allow({rule}) without a reason — the reason "
                       f"is mandatory"))
            return
        ann.allows.append(Allow(rule, reason, line, cov))
        return
    m = SHARED_RE.match(body)
    if m:
        fields = _split_fields(m.group(1))
        rhs = m.group(2).strip()
        if rhs.startswith("owner="):
            ann.registries.append(SharedRegistry(
                fields, "owner", _split_fields(rhs[len("owner="):]),
                "", line))
        elif rhs.startswith("lock="):
            ann.registries.append(SharedRegistry(
                fields, "lock", (), rhs[len("lock="):].strip(), line))
        else:
            ann.errors.append(
                (line, f"shared(...) needs 'owner=<methods>' or "
                       f"'lock=<attr>', got {rhs!r}"))
        return
    m = BOUNDED_RE.match(body)
    if m:
        ann.bounded.append(Bounded(m.group(1), m.group(2).strip(),
                                   line, cov))
        return
    ann.errors.append((line, f"unrecognized prophetlint directive: "
                             f"{body[:60]!r}"))
