"""Seeded R1 violations — every construct here must be flagged when the
file is linted as a hot module (tests pass ``hot=True``)."""
import jax
import numpy as np


def leaky_dispatch(step_fn, state, batch, metrics):
    state, metrics = step_fn(state, batch)
    loss = float(metrics["loss"])              # host-sync: blocking fetch
    host = np.asarray(metrics["counts"])       # host-sync: D2H copy
    scalar = metrics["aux"].item()             # host-sync: .item()
    fetched = jax.device_get(state)            # host-sync: device_get
    jax.block_until_ready(state)               # host-sync: barrier
    metrics["counts"].block_until_ready()      # host-sync: barrier method
    return loss, host, scalar, fetched


def annotated_ok(metrics):
    # prophetlint: allow(host-sync): fixture — deferred consumption
    return float(metrics["loss"])
