"""Seeded R5 purity violations — BlockSpec index maps that are not pure
functions of the grid indices."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OFFSET = 3


def _table():
    return [0, 1, 2]


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def impure_maps(x, bt=128):
    shift = 2
    out = pl.pallas_call(
        _kernel,
        grid=(4,),
        in_specs=[
            pl.BlockSpec((bt, bt), lambda i: (i + shift, 0)),    # capture
            pl.BlockSpec((bt, bt), lambda i: (_table()[i], 0)),  # call
        ],
        out_specs=pl.BlockSpec((bt, bt), lambda i: (i, 0)),      # fine
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x, x)
    return out
