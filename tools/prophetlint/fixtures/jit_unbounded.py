"""Seeded R3 violations — statics without declarations, out-of-set
literals, computed set-statics without provenance."""
import jax


def step(state, batch, chunks=1):
    return state


# no bounded() declaration for 'chunks' → violation
undeclared = jax.jit(step, static_argnames=("chunks",))

# static_argnums dodges by-name declarations → violation
positional = jax.jit(step, static_argnums=(2,))


def make_step():
    # prophetlint: bounded(chunks): {1, 2, 4, 8}
    return jax.jit(step, static_argnames=("chunks",))


def train(state, batch, profiled_k):
    fn = make_step()
    fn(state, batch, chunks=16)           # literal outside {1, 2, 4, 8}
    fn(state, batch, chunks=profiled_k)   # computed, no provenance note
    fn(state, batch, chunks=4)            # fine: in-set literal
    # prophetlint: bounded(chunks): fixture — quantized upstream
    fn(state, batch, chunks=profiled_k)   # fine: documented provenance


def make_bad_kind():
    # prophetlint: bounded(chunks): whatever-goes
    return jax.jit(step, static_argnames=("chunks",))   # unknown kind
