"""Seeded R5 VMEM violations — a tile set that blows the 16 MiB/core
budget, and a block dim the linter cannot bound."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)
    o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def oversized_matmul(x, w, bt=4096, bf=4096, bd=4096):
    """(4096·4096)·3 tiles · 4 B · double-buffered + f32 scratch
    ≈ 448 MiB — nowhere near fitting."""
    out = pl.pallas_call(
        _kernel,
        grid=(1, 1, 1, 1),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda g, t, f, d: (g, t, d)),
            pl.BlockSpec((1, bd, bf), lambda g, t, f, d: (g, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bt, bf), lambda g, t, f, d: (g, t, f)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bf), jnp.float32)],
    )(x, w)
    return out


def unbounded_panel(x, bd=128):
    out = pl.pallas_call(
        _kernel,
        grid=(1, 1),
        in_specs=[pl.BlockSpec((x.shape[0], bd), lambda d, r: (0, d))],
        out_specs=pl.BlockSpec((x.shape[0], bd), lambda d, r: (0, d)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
    return out
