"""Seeded R2 violations — env reads outside flags.py/launch/ (tests
pass ``env_exempt=False``)."""
import os

MODE = os.environ.get("REPRO_MODE", "fast")        # env-read
LEVEL = os.getenv("REPRO_LEVEL")                   # env-read
HAS = "REPRO_DEBUG" in os.environ                  # env-read (membership)
DIRECT = os.environ["HOME"]                        # env-read (subscript)

os.environ["REPRO_SEEDED"] = "1"                   # write: allowed
del os.environ["REPRO_SEEDED"]                     # delete: allowed

# prophetlint: allow(env-read): fixture — documented exception
ANNOTATED = os.environ.get("REPRO_ANNOTATED")
