"""Seeded R5 branching violations — Python control flow on
tracer-derived values inside a kernel body."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _branchy_kernel(x_ref, o_ref, *, bt: int):
    t = pl.program_id(0)
    row = x_ref[0, 0]
    if t == 0:                       # violation: branch on program_id
        o_ref[...] = jnp.zeros_like(o_ref)
    if row > 0:                      # violation: branch on a ref value
        o_ref[...] = x_ref[...]
    while t < bt:                    # violation: loop on program_id
        t = t + 1


def _clean_kernel(x_ref, o_ref, *, bt: int, causal: bool):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    if causal:                       # fine: keyword-only static config
        o_ref[...] = x_ref[...]
    for i in range(bt):              # fine: static unroll
        pass


def run(x, bt=128):
    bad = pl.pallas_call(
        _branchy_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((bt, bt), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, bt), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
    good = pl.pallas_call(
        _clean_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((bt, bt), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, bt), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
    return bad, good
