"""Seeded R4 violations — a PlanPipeline-shaped class whose shared
fields are touched outside the registry's owner list, and a lock-mode
class with an unlocked access."""
import threading
from concurrent.futures import ThreadPoolExecutor


class MiniPlanPipeline:
    """Mirror of repro.train.runtime.PlanPipeline's registry shape."""

    # prophetlint: shared(_future, _closed, worker_restarts):
    #   owner=submit, wait, close

    def __init__(self, engine):
        self._engine = engine
        self._exec = ThreadPoolExecutor(max_workers=1)
        self._future = None
        self._closed = False
        self.worker_restarts = 0

    def submit(self, counts):
        self._future = self._exec.submit(lambda: counts)

    def wait(self):
        f, self._future = self._future, None
        return f.result() if f is not None else None

    def close(self):
        self._closed = True

    def peek(self):
        return self._future          # violation: not in owner list

    def sneaky_reset(self):
        self._closed = False         # violation: not in owner list
        self.worker_restarts += 1    # violation: not in owner list

    def annotated_peek(self):
        # prophetlint: allow(shared-state): fixture — read-only debug probe
        return self._future


class LockedCounter:
    # prophetlint: shared(count): lock=_lock

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        with self._lock:
            self.count += 1          # fine: under the declared lock

    def racy_bump(self):
        self.count += 1              # violation: no lock held
