import sys

from tools.prophetlint.cli import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
