"""prophetlint — repo-specific static analysis for the Pro-Prophet repro.

Five rule families, each encoding an invariant the runtime relies on but
Python cannot express:

* ``host-sync``   (R1) — no host synchronization on the dispatch hot path
  (``.item()``, ``float(x[...])``, ``np.asarray``, ``jax.device_get``,
  ``block_until_ready``) in the hot modules.
* ``env-read``    (R2) — ``os.environ`` / ``os.getenv`` reads only in
  ``repro/flags.py`` and ``repro/launch/``.
* ``jit-bounded`` (R3) — every ``jax.jit`` static argument draws from a
  statically bounded candidate set, declared next to the jit site.
* ``shared-state``(R4) — fields named in a class's ``shared(...)``
  registry are only touched under the declared lock or inside the
  declared owner methods.
* ``pallas-*``    (R5) — ``pl.pallas_call`` contracts: pure BlockSpec
  index maps, block tiles inside the per-core VMEM budget, no
  tracer-dependent Python branching in kernel bodies.

Escape hatch: ``# prophetlint: allow(<rule>): <reason>`` on the line or
in the contiguous comment block above the statement; the reason is
mandatory.  See tools/prophetlint/annotations.py for the full grammar
and README.md §Static analysis & sanitizers for usage.

Run: ``python -m tools.prophetlint src`` (or ``scripts/ci.sh --lint``).
"""
from tools.prophetlint.cli import Violation, lint_file, lint_paths, main

__all__ = ["Violation", "lint_file", "lint_paths", "main"]
