"""prophetlint driver: collect files, run rules, print violations.

``python -m tools.prophetlint [paths...]`` — paths default to ``src``.
Exit status 1 when any violation is found.  Output format::

    path/to/file.py:123: [host-sync] .item() on the dispatch hot path ...

Which rules apply where:

* R1 host-sync runs only on the *hot modules* (``HOT_PATHS``) — the
  model/kernel code and the trainer dispatch path.
* R2 env-read runs on everything under ``src/`` except
  ``repro/flags.py`` and ``repro/launch/`` (``ENV_EXEMPT``).
* R3/R4/R5 are self-scoping: jit sites, ``shared(...)`` registries and
  ``pallas_call`` sites are checked wherever they appear.

``tools/prophetlint/fixtures/`` holds files with *seeded* violations for
the self-tests; the walker skips them (tests lint them explicitly with
``lint_file(path, hot=True, env_exempt=False)``).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import sys
from typing import List, Optional, Sequence

from tools.prophetlint import annotations as ann_mod
from tools.prophetlint.rules import (envdiscipline, hostsync, jitcache,
                                     lockset, pallas)

# Paths (relative, '/'-separated) where R1 host-sync applies.
HOT_PATHS = (
    "src/repro/models/",
    "src/repro/kernels/",
    "src/repro/train/runtime.py",
    "src/repro/train/trainer.py",
)

# Paths where R2 env-read does NOT apply (the sanctioned env readers).
ENV_EXEMPT = (
    "src/repro/flags.py",
    "src/repro/launch/",
)

SKIP_DIRS = {"__pycache__", ".git", "fixtures"}


@dataclasses.dataclass
class Violation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _relpath(path: str) -> str:
    rel = os.path.relpath(path).replace(os.sep, "/")
    return rel


def lint_file(path: str, text: Optional[str] = None, *,
              hot: Optional[bool] = None,
              env_exempt: Optional[bool] = None) -> List[Violation]:
    """Lint one file.  ``hot``/``env_exempt`` override the path-based
    scoping (the self-tests force fixtures into scope this way)."""
    rel = _relpath(path)
    if text is None:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as e:
        return [Violation(rel, e.lineno or 0, "parse",
                          f"syntax error: {e.msg}")]
    ann = ann_mod.collect(text, tree)
    out: List[Violation] = []
    for line, msg in ann.errors:
        out.append(Violation(rel, line, "annotation", msg))

    if hot is None:
        hot = any(rel == p or rel.startswith(p) for p in HOT_PATHS)
    if env_exempt is None:
        env_exempt = (not rel.startswith("src/")) \
            or any(rel == p or rel.startswith(p) for p in ENV_EXEMPT)

    def emit(rule: str, line: int, msg: str) -> None:
        if ann.allowed(rule, line) is None:
            out.append(Violation(rel, line, rule, msg))

    if hot:
        hostsync.check(tree, emit)
    if not env_exempt:
        envdiscipline.check(tree, emit)
    jitcache.check(tree, ann, emit)
    lockset.check(tree, ann, emit)
    pallas.check(tree, emit)
    return out


def _walk(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in SKIP_DIRS)
            for n in sorted(names):
                if n.endswith(".py"):
                    files.append(os.path.join(root, n))
    return files


def lint_paths(paths: Sequence[str]) -> List[Violation]:
    out: List[Violation] = []
    for f in _walk(paths):
        out.extend(lint_file(f))
    return out


def main(argv: Sequence[str]) -> int:
    paths = list(argv) or ["src"]
    violations = lint_paths(paths)
    for v in violations:
        print(v)
    n = len(violations)
    if n:
        print(f"prophetlint: {n} violation{'s' if n != 1 else ''}")
        return 1
    print("prophetlint: clean")
    return 0
