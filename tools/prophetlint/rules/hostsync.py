"""R1 ``host-sync`` — no host synchronization on the dispatch hot path.

Every construct flagged here forces the host to block on (or copy from)
the device — the exact serialization Pro-Prophet's async runtime exists
to avoid.  In the hot modules they are errors unless annotated with
``# prophetlint: allow(host-sync): <reason>``:

* ``x.item()``, ``x.block_until_ready()``
* ``jax.device_get(...)``, ``jax.block_until_ready(...)``
* ``np.asarray(...)`` / ``numpy.asarray(...)`` (``jnp.asarray`` is fine
  — it stays on device)
* ``float(x[...])`` / ``int(x[...])`` / ``bool(x[...])`` — the classic
  ``float(metrics["loss"])`` blocking fetch.  Only subscript arguments
  are flagged: coercions of plain names/calls are overwhelmingly host
  scalars already, and the dynamic twin (``REPRO_SANITIZE``'s transfer
  guard) backstops anything this heuristic misses.
"""
from __future__ import annotations

import ast

RULE = "host-sync"

_SYNC_METHODS = {"item", "block_until_ready"}
_JAX_FUNCS = {"device_get", "block_until_ready"}
_NUMPY_NAMES = {"np", "numpy"}
_COERCIONS = {"float", "int", "bool"}


def check(tree: ast.AST, emit) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr in _SYNC_METHODS and not (
                    isinstance(f.value, ast.Name)
                    and f.value.id in ("jax",)):
                emit(RULE, node.lineno,
                     f".{f.attr}() blocks the host on the device — "
                     f"not allowed on the dispatch hot path")
            elif (isinstance(f.value, ast.Name) and f.value.id == "jax"
                  and f.attr in _JAX_FUNCS):
                emit(RULE, node.lineno,
                     f"jax.{f.attr}() is a host sync — not allowed on "
                     f"the dispatch hot path")
            elif (isinstance(f.value, ast.Name)
                  and f.value.id in _NUMPY_NAMES and f.attr == "asarray"):
                emit(RULE, node.lineno,
                     f"{f.value.id}.asarray() copies device→host — use "
                     f"jnp.asarray or move off the hot path")
        elif isinstance(f, ast.Name) and f.id in _COERCIONS:
            if len(node.args) == 1 and isinstance(node.args[0],
                                                  ast.Subscript):
                emit(RULE, node.lineno,
                     f"{f.id}(...[...]) forces a blocking device fetch "
                     f"of the subscripted value")
