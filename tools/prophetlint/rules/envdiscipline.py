"""R2 ``env-read`` — environment reads only in flags.py / launch/.

Scattered ``os.environ`` reads make a run's behavior depend on ambient
process state with no single place to audit it.  The repo's contract:
``repro/flags.py`` owns every tunable (one accessor per variable,
re-read per call) and ``repro/launch/`` may read topology variables at
process start.  Everything else must go through a flags accessor.

Flagged: ``os.environ[...]`` loads, ``os.environ.get/…``,
``"X" in os.environ``, ``os.getenv(...)``.  Writes
(``os.environ["X"] = ...``, ``del os.environ["X"]``) are *not* flagged —
tests and launchers legitimately seed the environment.
"""
from __future__ import annotations

import ast

RULE = "env-read"


def _is_os_environ(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "environ"
            and isinstance(node.value, ast.Name) and node.value.id == "os")


def check(tree: ast.AST, emit) -> None:
    writes = set()   # id() of os.environ Attribute nodes used as write targets
    for node in ast.walk(tree):
        if isinstance(node, ast.Subscript) and \
                isinstance(node.ctx, (ast.Store, ast.Del)) and \
                _is_os_environ(node.value):
            writes.add(id(node.value))
    for node in ast.walk(tree):
        if _is_os_environ(node) and id(node) not in writes:
            emit(RULE, node.lineno,
                 "os.environ read outside repro/flags.py and "
                 "repro/launch/ — add an accessor to repro.flags")
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr == "getenv"
              and isinstance(node.func.value, ast.Name)
              and node.func.value.id == "os"):
            emit(RULE, node.lineno,
                 "os.getenv read outside repro/flags.py and "
                 "repro/launch/ — add an accessor to repro.flags")
