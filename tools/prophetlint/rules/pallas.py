"""R5 ``pallas-*`` — contracts every ``pl.pallas_call`` site must hold.

Three checks, each a TPU-Pallas failure mode that surfaces as silent
mis-compiles or hard-to-attribute runtime faults rather than nice
Python errors:

* ``pallas-purity`` — BlockSpec index maps must be pure functions of the
  grid indices: free names, calls or attribute reads inside the lambda
  make the block→HBM mapping depend on Python state captured at trace
  time.
* ``pallas-vmem`` — the per-grid-step working set (all BlockSpec tiles,
  double-buffered by the pipeline, plus VMEM scratch) must fit the
  per-core budget (~16 MiB).  Tile dims are resolved statically through
  literals, enclosing-function locals/defaults, module-wide consistent
  parameter defaults and module constants; a dim the linter cannot bound
  (e.g. ``x.shape[0]``) is itself a violation — annotate with
  ``allow(pallas-vmem)`` and say why the runtime value stays small.
  Blocks are costed at 4 B/element (conservative for bf16 inputs).
* ``pallas-branch`` — Python ``if``/``while`` in a kernel body on values
  derived from refs or ``pl.program_id`` is a trace-time decision on a
  runtime value; use ``@pl.when`` / ``jnp.where`` / ``fori_loop``.
  Keyword-only kernel params are static configuration and may branch.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

PURITY = "pallas-purity"
VMEM = "pallas-vmem"
BRANCH = "pallas-branch"

VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # per TPU core
BLOCK_ELEM_BYTES = 4                   # conservative f32 costing
DOUBLE_BUFFER = 2                      # Pallas pipelines tiles twice

_DTYPE_BYTES = {
    "float64": 8, "int64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2,
    "int8": 1, "uint8": 1, "bool_": 1,
}


def _attr_is(node: ast.AST, attr: str) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == attr


# -- static dim resolution ---------------------------------------------------

class _Resolver:
    def __init__(self, tree: ast.AST, enclosing):
        self.tree = tree
        self.enclosing = enclosing
        self.module_consts: Dict[str, int] = {}
        for node in getattr(tree, "body", []):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Constant) \
                    and isinstance(node.value.value, int):
                self.module_consts[node.targets[0].id] = node.value.value
        # module-wide consistent parameter defaults (e.g. bt=128 on every
        # function that declares a default for bt)
        seen: Dict[str, Set[int]] = {}
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            a = fn.args
            for params, defaults in ((a.args, a.defaults),
                                     (a.kwonlyargs, a.kw_defaults)):
                pad = len(params) - len(defaults)
                for p, d in zip(params[pad:], defaults):
                    if d is not None and isinstance(d, ast.Constant) \
                            and isinstance(d.value, int) \
                            and not isinstance(d.value, bool):
                        seen.setdefault(p.arg, set()).add(d.value)
        self.param_defaults = {k: next(iter(v))
                               for k, v in seen.items() if len(v) == 1}
        self.local_consts: Dict[str, int] = {}
        self.fn_defaults: Dict[str, int] = {}
        if enclosing is not None:
            for node in ast.walk(enclosing):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name) \
                        and isinstance(node.value, ast.Constant) \
                        and isinstance(node.value.value, int):
                    self.local_consts[node.targets[0].id] = node.value.value
            a = enclosing.args
            for params, defaults in ((a.args, a.defaults),
                                     (a.kwonlyargs, a.kw_defaults)):
                pad = len(params) - len(defaults)
                for p, d in zip(params[pad:], defaults):
                    if d is not None and isinstance(d, ast.Constant) \
                            and isinstance(d.value, int) \
                            and not isinstance(d.value, bool):
                        self.fn_defaults[p.arg] = d.value

    def resolve(self, node: ast.AST) -> Optional[int]:
        if node is None:
            return 1
        if isinstance(node, ast.Constant):
            if node.value is None:
                return 1          # squeezed dim
            if isinstance(node.value, int):
                return node.value
            return None
        if isinstance(node, ast.Name):
            for table in (self.local_consts, self.fn_defaults,
                          self.param_defaults, self.module_consts):
                if node.id in table:
                    return table[node.id]
            return None
        if isinstance(node, ast.BinOp):
            lhs, rhs = self.resolve(node.left), self.resolve(node.right)
            if lhs is None or rhs is None:
                return None
            if isinstance(node.op, ast.Mult):
                return lhs * rhs
            if isinstance(node.op, ast.Add):
                return lhs + rhs
            if isinstance(node.op, ast.Sub):
                return lhs - rhs
            if isinstance(node.op, ast.FloorDiv) and rhs:
                return lhs // rhs
            return None
        return None


# -- site discovery ----------------------------------------------------------

def _enclosing_map(tree: ast.AST):
    """call node id → innermost enclosing function def."""
    out: Dict[int, ast.AST] = {}

    def walk(node, fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn = node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.Call):
                out[id(child)] = fn
            walk(child, fn)

    walk(tree, None)
    return out


def _resolve_grid_spec(call: ast.Call, enclosing) -> Optional[ast.Call]:
    """The GridSpec constructor call for ``grid_spec=<name-or-call>``."""
    for kw in call.keywords:
        if kw.arg != "grid_spec":
            continue
        v = kw.value
        if isinstance(v, ast.Call):
            return v
        if isinstance(v, ast.Name) and enclosing is not None:
            for node in ast.walk(enclosing):
                if isinstance(node, ast.Assign) \
                        and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name) \
                        and node.targets[0].id == v.id \
                        and isinstance(node.value, ast.Call):
                    return node.value
    return None


def _block_specs(call: ast.Call, grid_spec: Optional[ast.Call]):
    """All BlockSpec constructor calls reachable from the site."""
    sources = [call] + ([grid_spec] if grid_spec is not None else [])
    specs: List[ast.Call] = []
    for src in sources:
        for kw in src.keywords:
            if kw.arg not in ("in_specs", "out_specs"):
                continue
            vals = kw.value.elts if isinstance(
                kw.value, (ast.Tuple, ast.List)) else [kw.value]
            for v in vals:
                if isinstance(v, ast.Call) and _attr_is(v.func, "BlockSpec"):
                    specs.append(v)
    return specs


def _scratch_shapes(call: ast.Call, grid_spec: Optional[ast.Call]):
    out: List[ast.Call] = []
    for src in [call] + ([grid_spec] if grid_spec is not None else []):
        for kw in src.keywords:
            if kw.arg == "scratch_shapes" and isinstance(
                    kw.value, (ast.Tuple, ast.List)):
                for v in kw.value.elts:
                    if isinstance(v, ast.Call):
                        out.append(v)
    return out


# -- the three checks --------------------------------------------------------

def _check_purity(spec: ast.Call, emit) -> None:
    if len(spec.args) < 2:
        return
    lam = spec.args[1]
    if not isinstance(lam, ast.Lambda):
        if not isinstance(lam, ast.Constant):   # e.g. a named helper fn
            emit(PURITY, spec.lineno,
                 "BlockSpec index map is not an inline lambda — the "
                 "linter cannot verify it is pure in the grid indices")
        return
    params = {a.arg for a in lam.args.args}
    for node in ast.walk(lam.body):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id not in params:
            emit(PURITY, spec.lineno,
                 f"BlockSpec index map captures '{node.id}' from the "
                 f"enclosing scope — index maps must be pure functions "
                 f"of the grid indices")
        elif isinstance(node, ast.Call):
            emit(PURITY, spec.lineno,
                 "BlockSpec index map calls a function — the mapping "
                 "must be a pure index expression")
        elif isinstance(node, ast.Attribute):
            emit(PURITY, spec.lineno,
                 f"BlockSpec index map reads attribute '.{node.attr}' — "
                 f"index maps must not touch external state")


def _dim_names(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return "<expr>"


def _check_vmem(call: ast.Call, specs, scratch, res: _Resolver,
                emit) -> None:
    total = 0
    unresolved: List[str] = []
    for spec in specs:
        if not spec.args or not isinstance(spec.args[0],
                                           (ast.Tuple, ast.List)):
            continue
        elems = 1
        for dim in spec.args[0].elts:
            v = res.resolve(dim)
            if v is None:
                unresolved.append(_dim_names(dim))
            else:
                elems *= max(v, 1)
        total += elems * BLOCK_ELEM_BYTES * DOUBLE_BUFFER
    for sc in scratch:
        if not (_attr_is(sc.func, "VMEM") and sc.args
                and isinstance(sc.args[0], (ast.Tuple, ast.List))):
            continue
        elems = 1
        for dim in sc.args[0].elts:
            v = res.resolve(dim)
            if v is None:
                unresolved.append(_dim_names(dim))
            else:
                elems *= max(v, 1)
        nbytes = 4
        if len(sc.args) > 1 and isinstance(sc.args[1], ast.Attribute):
            nbytes = _DTYPE_BYTES.get(sc.args[1].attr, 4)
        total += elems * nbytes
    if unresolved:
        emit(VMEM, call.lineno,
             f"cannot bound the VMEM working set: block dims "
             f"{sorted(set(unresolved))} are not statically resolvable "
             f"— annotate allow(pallas-vmem) with the runtime bound")
    elif total > VMEM_BUDGET_BYTES:
        emit(VMEM, call.lineno,
             f"per-step VMEM working set ≈{total / 2**20:.1f} MiB "
             f"(tiles double-buffered + scratch) exceeds the "
             f"{VMEM_BUDGET_BYTES / 2**20:.0f} MiB/core budget")


def _kernel_def(call: ast.Call, enclosing, tree):
    """FunctionDef of the kernel (first arg, through functools.partial)
    and the set of names bound statically by partial keywords."""
    if not call.args:
        return None
    k = call.args[0]
    if isinstance(k, ast.Call) and (_attr_is(k.func, "partial")
                                    or (isinstance(k.func, ast.Name)
                                        and k.func.id == "partial")):
        k = k.args[0] if k.args else None
    if not isinstance(k, ast.Name):
        return None
    scopes = ([enclosing] if enclosing is not None else []) + [tree]
    for scope in scopes:
        for node in ast.walk(scope):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == k.id:
                return node
    return None


def _check_branching(kernel, emit) -> None:
    tainted: Set[str] = {a.arg for a in
                         kernel.args.posonlyargs + kernel.args.args}
    # fixpoint taint propagation through simple assignments and
    # pl.program_id results
    changed = True
    while changed:
        changed = False
        for node in ast.walk(kernel):
            if not isinstance(node, (ast.Assign, ast.AugAssign)):
                continue
            src_tainted = False
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Name) and sub.id in tainted:
                    src_tainted = True
                elif isinstance(sub, ast.Call) and \
                        _attr_is(sub.func, "program_id"):
                    src_tainted = True
            if not src_tainted:
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name) and sub.id not in tainted:
                        tainted.add(sub.id)
                        changed = True
    for node in ast.walk(kernel):
        if not isinstance(node, (ast.If, ast.While)):
            continue
        for sub in ast.walk(node.test):
            hit = None
            if isinstance(sub, ast.Name) and sub.id in tainted:
                hit = sub.id
            elif isinstance(sub, ast.Call) and _attr_is(sub.func,
                                                        "program_id"):
                hit = "pl.program_id(...)"
            if hit:
                kind = "if" if isinstance(node, ast.If) else "while"
                emit(BRANCH, node.lineno,
                     f"Python '{kind}' on tracer-derived value "
                     f"'{hit}' inside kernel '{kernel.name}' — use "
                     f"@pl.when / jnp.where instead")
                break


def check(tree: ast.AST, emit) -> None:
    enclosing = _enclosing_map(tree)
    seen_kernels: Set[int] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _attr_is(node.func, "pallas_call")):
            continue
        fn = enclosing.get(id(node))
        grid_spec = _resolve_grid_spec(node, fn)
        specs = _block_specs(node, grid_spec)
        for spec in specs:
            _check_purity(spec, emit)
        res = _Resolver(tree, fn)
        _check_vmem(node, specs, _scratch_shapes(node, grid_spec), res,
                    emit)
        kernel = _kernel_def(node, fn, tree)
        if kernel is not None and id(kernel) not in seen_kernels:
            seen_kernels.add(id(kernel))
            _check_branching(kernel, emit)
