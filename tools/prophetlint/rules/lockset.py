"""R4 ``shared-state`` — registry-driven lock/ownership discipline.

Classes shared between the dispatch thread and the planner worker
declare their concurrency-sensitive fields in a class-body registry::

    # prophetlint: shared(_future, _closed): owner=submit, wait, close

``owner`` mode: only the listed methods (plus ``__init__``, which runs
before the object escapes its creating thread) may touch the fields —
the repo's runtime classes synchronize by *phase* (the submit→wait
happens-before edge), so ownership is a method list, not a mutex.

``lock`` mode: every access must sit inside ``with self.<lock>:``.

Any other access is a violation unless annotated
``# prophetlint: allow(shared-state): <reason>`` — the point is that
adding a method that touches planner state is a conscious concurrency
decision, reviewed either by extending the registry or by justifying
the exception inline.
"""
from __future__ import annotations

import ast
from typing import List

RULE = "shared-state"


def _self_attr(node: ast.AST, name: str = None):
    """The attribute name if node is ``self.<attr>`` (any attr when
    ``name`` is None and it matches otherwise), else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        if name is None or node.attr == name:
            return node.attr
    return None


class _MethodWalker(ast.NodeVisitor):
    """Collect ``self.<field>`` accesses in a method, tracking whether
    each sits under ``with self.<lock>:``."""

    def __init__(self, fields, lock):
        self.fields = fields
        self.lock = lock
        self.hits: List[tuple] = []   # (attr, lineno, under_lock)
        self._lock_depth = 0

    def visit_With(self, node: ast.With) -> None:
        locked = any(
            _self_attr(item.context_expr, self.lock) is not None
            or (isinstance(item.context_expr, ast.Call)
                and _self_attr(item.context_expr.func, self.lock))
            for item in node.items) if self.lock else False
        if locked:
            self._lock_depth += 1
            self.generic_visit(node)
            self._lock_depth -= 1
        else:
            self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr in self.fields:
            self.hits.append((attr, node.lineno, self._lock_depth > 0))
        self.generic_visit(node)


def check(tree: ast.AST, ann, emit) -> None:
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        regs = [r for r in ann.registries
                if cls.lineno <= r.line <= (cls.end_lineno or cls.lineno)]
        if not regs:
            continue
        # innermost class wins: skip registries owned by a nested class
        nested = [c for c in ast.walk(cls)
                  if isinstance(c, ast.ClassDef) and c is not cls]
        regs = [r for r in regs
                if not any(n.lineno <= r.line <= (n.end_lineno or 0)
                           for n in nested)]
        for reg in regs:
            fields = set(reg.fields)
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if meth.name == "__init__":
                    continue
                walker = _MethodWalker(fields,
                                       reg.lock if reg.mode == "lock"
                                       else None)
                walker.visit(meth)
                for attr, line, under_lock in walker.hits:
                    if reg.mode == "owner":
                        if meth.name in reg.owners:
                            continue
                        emit(RULE, line,
                             f"'{cls.name}.{meth.name}' touches shared "
                             f"field '{attr}' but is not in the "
                             f"registry's owner list "
                             f"({', '.join(reg.owners)})")
                    else:
                        if under_lock:
                            continue
                        emit(RULE, line,
                             f"'{cls.name}.{meth.name}' touches shared "
                             f"field '{attr}' outside 'with "
                             f"self.{reg.lock}:'")
