"""R3 ``jit-bounded`` — every jit static argument is statically bounded.

A ``jax.jit(..., static_argnames=...)`` site recompiles per distinct
static value; an unbounded static (a raw profiled count, a float) turns
the jit cache into a compile-per-step leak.  The repo's discipline
(established by the chunked-a2a work, K ∈ {1, 2, 4, 8}): every static
argument must carry a boundedness declaration next to the jit site::

    # prophetlint: bounded(a2a_chunks): {1, 2, 4, 8}
    return jax.jit(step, static_argnames=("a2a_chunks",))

Declared kinds:

* ``{v1, v2, ...}`` — a literal candidate set.  Call sites passing a
  literal are checked for membership; call sites passing a computed
  value must document provenance with a call-site annotation
  ``# prophetlint: bounded(<name>): <where the quantization happens>``.
* ``bool`` — two values, trivially bounded.
* ``shape-derived`` — takes values from array shapes already specialized
  by tracing (no extra cache growth beyond the shape key).
* ``config`` — fixed per process by construction (config dataclass /
  flags accessor), not data-dependent.

Free text may follow the kind (e.g. ``config — tile sizes``).  Also
flagged: ``static_argnums`` (positional statics dodge the by-name
discipline) and jit sites whose ``static_argnames`` the linter cannot
read statically.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

RULE = "jit-bounded"

_KIND_RE = re.compile(r"^(bool|shape-derived|config)\b")
_SET_RE = re.compile(r"^\{([^}]*)\}")


def _is_jit_func(f: ast.AST) -> bool:
    if isinstance(f, ast.Name) and f.id in ("jit", "pjit"):
        return True
    return (isinstance(f, ast.Attribute) and f.attr in ("jit", "pjit")
            and isinstance(f.value, ast.Name)
            and f.value.id in ("jax", "pjit"))


def _is_jit_site(call: ast.Call) -> bool:
    """Direct ``jax.jit(...)`` or the decorator idiom
    ``functools.partial(jax.jit, static_argnames=...)``."""
    if _is_jit_func(call.func):
        return True
    f = call.func
    is_partial = (isinstance(f, ast.Name) and f.id == "partial") or \
        (isinstance(f, ast.Attribute) and f.attr == "partial")
    return is_partial and bool(call.args) and _is_jit_func(call.args[0])


def _static_names(call: ast.Call) -> Optional[Tuple[List[str], bool]]:
    """(names, readable) from a jit call's static_argnames; None if the
    call has no statics.  readable=False when the kwarg exists but is
    not a literal."""
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return [v.value], True
            if isinstance(v, (ast.Tuple, ast.List)):
                names = []
                for elt in v.elts:
                    if isinstance(elt, ast.Constant) and \
                            isinstance(elt.value, str):
                        names.append(elt.value)
                    else:
                        return [], False
                return names, True
            return [], False
    return None


def _parse_kind(text: str):
    """('set', {values}) | ('kind', name) | None for a declaration."""
    m = _SET_RE.match(text)
    if m:
        vals = set()
        for part in m.group(1).split(","):
            part = part.strip()
            if not part:
                continue
            try:
                vals.add(int(part))
            except ValueError:
                vals.add(part.strip("'\""))
        return ("set", vals)
    m = _KIND_RE.match(text)
    if m:
        return ("kind", m.group(1))
    return None


class _JitIndex:
    """Map callables (names / self-attributes) to their static specs."""

    def __init__(self):
        self.by_name: Dict[str, Dict[str, object]] = {}
        self.by_attr: Dict[str, Dict[str, object]] = {}


def check(tree: ast.AST, ann, emit) -> None:
    # -- pass 1: jit sites → declaration check; factory index ------------
    sites: List[Tuple[ast.Call, Dict[str, object]]] = []
    factories: Dict[str, Dict[str, object]] = {}

    funcs = [n for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_jit_site(node)):
            continue
        for kw in node.keywords:
            if kw.arg == "static_argnums":
                emit(RULE, node.lineno,
                     "static_argnums is positional — use static_argnames "
                     "so boundedness declarations can attach by name")
        res = _static_names(node)
        if res is None:
            continue
        names, readable = res
        if not readable:
            emit(RULE, node.lineno,
                 "static_argnames is not a string/tuple literal — the "
                 "linter cannot verify the static set is bounded")
            continue
        spec: Dict[str, object] = {}
        for name in names:
            b = ann.bounded_at(name, node.lineno)
            if b is None:
                emit(RULE, node.lineno,
                     f"static arg '{name}' has no boundedness "
                     f"declaration — add '# prophetlint: "
                     f"bounded({name}): <kind>' at the jit site")
                continue
            kind = _parse_kind(b.text)
            if kind is None:
                emit(RULE, b.line,
                     f"bounded({name}): unknown kind {b.text[:40]!r} — "
                     f"use bool, {{literal, set}}, shape-derived or "
                     f"config")
                continue
            spec[name] = kind
        sites.append((node, spec))

    # factory pattern: a function whose return value is a jit call
    for fn in funcs:
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Return) and \
                    isinstance(stmt.value, ast.Call) and \
                    _is_jit_site(stmt.value):
                for call, spec in sites:
                    if call is stmt.value and spec:
                        factories[fn.name] = spec

    # -- pass 2: alias the jitted callables ------------------------------
    idx = _JitIndex()

    # decorator idiom: @functools.partial(jax.jit, static_argnames=...)
    for fn in funcs:
        for dec in fn.decorator_list:
            if isinstance(dec, ast.Call):
                for call, spec in sites:
                    if call is dec and spec:
                        idx.by_name[fn.name] = spec

    def record(target: ast.AST, spec: Dict[str, object]) -> None:
        if isinstance(target, ast.Name):
            idx.by_name[target.id] = spec
        elif isinstance(target, ast.Attribute):
            idx.by_attr[target.attr] = spec

    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or \
                not isinstance(node.value, ast.Call):
            continue
        call = node.value
        spec = None
        if _is_jit_site(call):
            for c, s in sites:
                if c is call:
                    spec = s
        elif isinstance(call.func, ast.Name) and \
                call.func.id in factories:
            spec = factories[call.func.id]
        if spec:
            for t in node.targets:
                record(t, spec)

    # -- pass 3: call-site discipline for literal-set statics ------------
    jit_calls_seen = {id(c) for c, _ in sites}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or id(node) in jit_calls_seen:
            continue
        f = node.func
        spec = None
        if isinstance(f, ast.Name):
            spec = idx.by_name.get(f.id)
        elif isinstance(f, ast.Attribute):
            spec = idx.by_attr.get(f.attr)
        if not spec:
            continue
        for kw in node.keywords:
            kind = spec.get(kw.arg)
            if kind is None or kind[0] != "set":
                continue
            v = kw.value
            if isinstance(v, ast.Constant):
                if v.value not in kind[1]:
                    emit(RULE, node.lineno,
                         f"static arg '{kw.arg}'={v.value!r} is outside "
                         f"its declared candidate set {sorted(kind[1])}")
            elif ann.bounded_at(kw.arg, node.lineno) is None:
                emit(RULE, node.lineno,
                     f"computed value for set-bounded static "
                     f"'{kw.arg}' — annotate the call with "
                     f"'# prophetlint: bounded({kw.arg}): <provenance>'")
