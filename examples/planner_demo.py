"""Planner walkthrough: watch Algorithm 1 balance a skewed routing trace
and compare against DeepSpeed-MoE / FasterMoE / top-k policies.

  PYTHONPATH=src python examples/planner_demo.py
"""
import numpy as np

from repro.core import (GatingTrace, GreedyPlanner, HardwareSpec, PerfModel,
                        balance_degree, traditional)
from repro.core.baselines import fastermoe_plan, topk_policy

D = E = 16
hw = HardwareSpec.from_model_dims(1024, 2048, bandwidth=10e9,
                                  flops_per_s=35e12, num_ffn_mats=2,
                                  t_fnec=1e-3, t_bnec=2e-3)
perf = PerfModel(hw, D)
trace = GatingTrace(D, E, 1024, skew=0.25, drift=0.05, seed=0)

print(f"{'iter':>4} {'base(ms)':>9} {'pro(ms)':>8} {'spd':>5} "
      f"{'s':>2} {'fm(ms)':>7} {'top2(ms)':>8} {'RB':>5}")
planner = GreedyPlanner(perf, n=2, alpha=0.25, s_max=8, scheduled=True)
for it in range(8):
    g = trace.step()
    res = planner.plan(g)
    fm = fastermoe_plan(perf, g, max_shadows=8)
    t2 = topk_policy(g, 2)
    t_t2 = perf.layer_time_for(t2, g)
    H0, _ = traditional(E, D).compute_loads(g)
    H1, _ = res.placement.compute_loads(g)
    rb = balance_degree(H0) / max(balance_degree(H1), 1e-9)
    print(f"{it:>4} {res.baseline_time*1e3:>9.2f} "
          f"{res.predicted_time*1e3:>8.2f} "
          f"{res.predicted_speedup:>5.2f} {res.placement.num_shadowed:>2} "
          f"{fm.predicted_time*1e3:>7.2f} {t_t2*1e3:>8.2f} {rb:>5.2f}")

print("\nFinal placement (expert -> shadow devices):")
for e, devs in sorted(res.placement.shadows.items()):
    print(f"  expert {e:2d} -> {sorted(devs)}")
