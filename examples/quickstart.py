"""Quickstart: train a tiny MoE-GPT with Pro-Prophet load balancing on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config, reduced
from repro.data import SyntheticLM
from repro.optim import adamw, cosine
from repro.parallel import local_ctx
from repro.train import Trainer
from repro.train.trainer import make_engine_for


def main():
    cfg = reduced(get_config("moe-gpt-s"))
    ctx = local_ctx()
    engine = make_engine_for(cfg, ctx)             # the paper's planner
    trainer = Trainer(cfg, ctx, adamw(cosine(3e-3, 10, 100)),
                      attn_impl="naive", remat=False, engine=engine)
    state = trainer.init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=8, seq=64)
    state, hist = trainer.run(state, data, num_steps=60, log_every=10)
    print(f"\nloss {hist[0]:.3f} -> {hist[-1]:.3f}")
    pt = engine.predicted_times()
    print(f"planner's predicted MoE-layer speedup this step: "
          f"{pt['speedup']:.2f}x")


if __name__ == "__main__":
    main()
