"""Serve a small model with batched requests: prefill a batch of prompts,
then decode continuations with the KV cache (greedy).

  PYTHONPATH=src python examples/serve_decode.py [--arch qwen2-1.5b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.kvcache import decode_cache_bytes
from repro.parallel import local_ctx
from repro.train import decode_tokens, make_serve_step, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    ctx = local_ctx()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen
    print(f"{cfg.name}: cache ≈ "
          f"{decode_cache_bytes(cfg, args.batch, max_len)/1e6:.2f} MB "
          f"for batch={args.batch}, len={max_len}")

    caches = M.init_cache(cfg, batch=args.batch, max_len=max_len)
    ss = make_serve_step(cfg, ctx)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)
    logits, caches = prefill(params, caches, prompts, cfg, ctx, serve_step=ss)
    toks, _ = decode_tokens(params, caches, logits, args.prompt_len,
                            args.gen, cfg, ctx, serve_step=ss)
    for i in range(args.batch):
        print(f"req{i}: prompt={np.asarray(prompts[i]).tolist()} "
              f"-> {np.asarray(toks[i]).tolist()}")


if __name__ == "__main__":
    main()
