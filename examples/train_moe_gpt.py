"""End-to-end driver (deliverable b): train a ~100M-param MoE-GPT for a few
hundred steps with the full stack — synthetic pipeline, AdamW+cosine,
Pro-Prophet engine in the loop, periodic checkpointing.

  PYTHONPATH=src python examples/train_moe_gpt.py [--steps 300]

~100M params: moe-gpt-s at full width (d=512, 12 layers, 16 experts,
d_ff=1024) has ≈ 12·16·2·512·1024·≈ 200M total / ≈ 38M active; we trim
experts to 8 to keep a CPU step tractable while staying >100M total.
"""
import argparse
import dataclasses

import jax

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.configs.moe_gpt import with_experts
from repro.data import SyntheticLM
from repro.optim import adamw, cosine
from repro.parallel import local_ctx
from repro.train import Trainer
from repro.train.runtime import OverlapTelemetry
from repro.train.trainer import make_engine_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="artifacts/moe_gpt_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100,
                    help="atomic retained checkpoint cadence (0 = final "
                         "save only; last 3 kept)")
    ap.add_argument("--async-plan", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="pipelined runtime (default on; --no-async-plan "
                         "forces the serial baseline)")
    args = ap.parse_args()

    cfg = with_experts(get_config("moe-gpt-s"), num_experts=8, top_k=1)
    ctx = local_ctx()
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n/1e6:.0f}M "
          f"(active {cfg.active_param_count()/1e6:.0f}M)")

    engine = make_engine_for(cfg, ctx)
    trainer = Trainer(cfg, ctx, adamw(cosine(1e-3, 20, args.steps)),
                      attn_impl="auto", remat=False, engine=engine,
                      async_plan=args.async_plan)
    state = trainer.init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq)
    telemetry = OverlapTelemetry()
    state, hist = trainer.run(state, data, num_steps=args.steps,
                              log_every=20, telemetry=telemetry,
                              ckpt_dir=args.ckpt,
                              ckpt_every=args.ckpt_every)
    save_checkpoint(state, args.ckpt, step=args.steps,
                    extra={"arch": cfg.name, "final_loss": hist[-1],
                           "expert_layout": "home"})
    s = telemetry.summary()
    print(f"\nloss {hist[0]:.3f} -> {hist[-1]:.3f}; checkpoint at "
          f"{args.ckpt}")
    print(f"overlap: plan {s['mean_plan_s'] * 1e3:.2f}ms/step "
          f"({s['hidden_frac']:.0%} hidden under device execution), "
          f"host overhead {s['host_overhead_s'] * 1e3:.2f}ms/step vs "
          f"{s['serial_overhead_s'] * 1e3:.2f}ms serial")


if __name__ == "__main__":
    main()
