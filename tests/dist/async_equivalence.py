"""8-host-device check: the async pipelined runtime must be bit-identical
to the serial baseline on a (data=2, model=4) mesh — same loss history,
same per-step placement arrays.  Run by tests/test_distributed.py in a
subprocess so the XLA device count is set before jax initializes."""
import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.core import EngineConfig, HardwareSpec, ProProphetEngine
from repro.data import SyntheticLM
from repro.optim import adamw, cosine
from repro.parallel import make_ctx
from repro.train import Trainer
from jax.sharding import Mesh


def make_engine(cfg, ctx):
    """Engine that plans aggressively: compute-bound hardware profile
    (cheap Trans, expensive FEC) and zero balance tolerance, so the
    greedy search shadows on any routing imbalance and the run actually
    exercises the placement-change → re-upload machinery."""
    hw = HardwareSpec.from_model_dims(cfg.d_model, cfg.moe.d_expert,
                                      bandwidth=1e12, flops_per_s=1e12,
                                      num_ffn_mats=3)
    ec = EngineConfig(num_experts=cfg.moe.num_experts, num_devices=ctx.ep_size,
                      num_moe_layers=cfg.num_moe_layers,
                      s_max=cfg.moe.s_max, alpha=0.0)
    return ProProphetEngine(ec, hw)


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    ctx = make_ctx(mesh)
    cfg = reduced(get_config("moe-gpt-s"))   # 4 experts over EP=4
    steps = 8
    tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 3, steps)), attn_impl="naive",
                 remat=False, engine=make_engine(cfg, ctx))

    def run(async_mode):
        tr.engine = make_engine(cfg, ctx)
        tr.async_plan = async_mode
        state = tr.init_state(jax.random.PRNGKey(0))
        data = SyntheticLM(cfg, batch=4, seq=32)
        sink = []
        with mesh:
            _, hist = tr.run(state, data, num_steps=steps, log_every=0,
                             stats_sink=sink)
        shadows = sum(p.num_shadowed for p in tr.engine.placements)
        return hist, [s.placements_fingerprint for s in sink], shadows

    hist_sync, fps_sync, shadows_sync = run(False)
    hist_async, fps_async, shadows_async = run(True)
    assert hist_sync == hist_async, (hist_sync, hist_async)
    assert fps_sync == fps_async, (fps_sync, fps_async)
    # the run exercised the plan/upload machinery: the planner moved off
    # the traditional placement, so the per-step arrays changed mid-run
    assert len(set(fps_sync)) > 1, fps_sync
    assert shadows_sync == shadows_async > 0, (shadows_sync, shadows_async)
    print("ASYNC_EQUIVALENCE_MESH_PASS")


if __name__ == "__main__":
    main()
