"""8-host-device check of dynamic expert migration on a (2, 4) mesh.

Part 1 — layer level: a migrated placement (expert_slot permutation +
the matching physical weight re-layout from ``relocation_gather``) must
be bit-identical to the identity layout in outputs, routing counts,
drop telemetry, and (row-permuted) expert gradients — the owner
re-layout is a pure re-homing of compute, never a numerical change.

Part 2 — trainer level (the acceptance criterion): on a persistent-skew
workload (router biased toward two experts co-resident on one EP
member) with a comm-bound engine profile, the migration-enabled trainer
selects ≥1 migration and executes the relocation on-device, while its
loss history stays bit-identical to the migration-disabled run (ample
capacity, no grad clipping ⇒ the whole trajectory is
permutation-equivariant).  The disabled run's placements and losses are
in turn bit-identical to a run with the engine's migration flag forced
off via REPRO_MIGRATION=0 — the flag and config paths agree.

Run by tests/test_distributed.py in a subprocess so the XLA device
count is set before jax initializes.
"""
import dataclasses
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core import EngineConfig, HardwareSpec, ProProphetEngine
from repro.data import SyntheticLM
from repro.models import moe
from repro.optim import adamw, cosine
from repro.parallel import make_ctx
from repro.train import Trainer
from repro.train import relocate
from jax.sharding import Mesh


def layer_equivalence(mesh):
    ctx = make_ctx(mesh)
    E, d, f = 8, 16, 32
    kw = dict(num_experts=E, top_k=2, d_expert=f, ffn_kind="swiglu",
              capacity_factor=4.0, shadow_capacity_factor=4.0, s_max=2)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    params = moe.moe_init(ks[0], d, f, E, ffn_kind="swiglu")
    params["router"]["w"] = (params["router"]["w"]
                             + 2.0 * jax.random.normal(ks[2], (E,)))
    x = 0.5 * jax.random.normal(ks[1], (2, 16, d))

    def run(p, pl):
        y, aux = moe.moe_apply(p, x, pl, ctx, **kw)

        def loss(pp):
            yy, _ = moe.moe_apply(pp, x, pl, ctx, **kw)
            return jnp.sum(yy ** 2)

        return y, aux, jax.grad(loss)(p)

    with mesh:
        # A migrated layout: swap experts 0↔4 and 2↔6 (cross-EP-member
        # moves on the 4-way model axis) with one live shadow slot.
        slot_of = np.arange(E)
        for a, b in ((0, 4), (2, 6)):
            slot_of[a], slot_of[b] = slot_of[b], slot_of[a]
        inv = np.empty(E, int)
        inv[slot_of] = np.arange(E)          # slot -> expert
        p2 = {k: v for k, v in params.items()}
        for nm in ("wi", "wg", "wo"):
            p2[nm] = params[nm][inv]         # physical re-layout
        # Shadow one unmigrated expert (3, owner dev 1 in both layouts)
        # and one *migrated* expert (0: owner dev 0 at identity, dev 2
        # after the swap) — shadow devs {1, 3} exclude both owners, so
        # the same placement is valid in both layouts and the Trans psum
        # must source the migrated expert from its new home slot.
        placement = {
            "shadow_idx": jnp.array([3, 0], jnp.int32),
            "shadow_valid": jnp.array([1.0, 1.0], jnp.float32),
            "shadow_devs": jnp.array([[0.0, 0.0, 1.0, 1.0],
                                      [0.0, 1.0, 0.0, 1.0]], jnp.float32),
            "expert_slot": jnp.asarray(slot_of, jnp.int32),
        }
        base_pl = {**placement,
                   "expert_slot": jnp.arange(E, dtype=jnp.int32)}
        yb, auxb, gb = run(params, base_pl)   # shadows, identity layout
        y2, aux2, g2 = run(p2, placement)     # shadows + migration

    np.testing.assert_array_equal(np.asarray(yb), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(auxb["counts"]),
                                  np.asarray(aux2["counts"]))
    assert float(auxb["dropped"]) == float(aux2["dropped"])
    for nm in ("wi", "wg", "wo"):
        # g2's rows are in slot order: row slot_of[e] is expert e's grad.
        np.testing.assert_array_equal(np.asarray(gb[nm]),
                                      np.asarray(g2[nm])[slot_of])
    np.testing.assert_array_equal(np.asarray(gb["router"]["w"]),
                                  np.asarray(g2["router"]["w"]))
    print("MIGRATION_LAYER_EQUIVALENCE_PASS")


def make_engine(cfg, ctx, migration):
    """Comm-bound profile (expensive per-step Trans vs compute) with a
    long amortization window and zero balance tolerance: any persistent
    imbalance makes the one-time migration beat per-step shadowing."""
    hw = HardwareSpec.from_model_dims(cfg.d_model, cfg.moe.d_expert,
                                      bandwidth=1e9, flops_per_s=200e12,
                                      num_ffn_mats=3)
    ec = EngineConfig(num_experts=cfg.moe.num_experts,
                      num_devices=ctx.ep_size,
                      num_moe_layers=cfg.num_moe_layers,
                      s_max=cfg.moe.s_max, alpha=0.0, scheduled=False,
                      enable_migration=migration, migrate_window=500.0)
    return ProProphetEngine(ec, hw)


def trainer_equivalence(mesh):
    ctx = make_ctx(mesh)
    cfg = reduced(get_config("moe-gpt-s"), max_experts=8)  # 8 experts, EP=4
    # Ample capacity: placements must not change drop behavior, so the
    # migrated and non-migrated trajectories stay bit-identical.
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     shadow_capacity_factor=8.0))
    steps = 8

    def run(migration, flag=None):
        if flag is not None:
            os.environ["REPRO_MIGRATION"] = flag
        try:
            # clip_norm=None: global-norm clipping sums over permuted rows
            # and would re-associate the reduction — everything else in
            # the step is exactly permutation-equivariant.
            tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 3, steps),
                                         clip_norm=None),
                         attn_impl="naive", remat=False,
                         engine=make_engine(cfg, ctx, migration))
            state = tr.init_state(jax.random.PRNGKey(0))
            # Persistent skew: bias every router toward experts 0 and 1 —
            # both live on EP member 0 (e_loc = 2), so the heavy device
            # owns two hot experts and re-homing one balances the load.
            bias = np.zeros(cfg.moe.num_experts, np.float32)
            bias[:2] = 3.0
            params = jax.tree.map(lambda a: a, state.params)
            for st in params["stages"]:
                for lp in st.values():
                    if "moe" in lp:
                        lp["moe"]["router"]["w"] = (
                            lp["moe"]["router"]["w"] + bias)
            state = type(state)(params, state.opt)
            data = SyntheticLM(cfg, batch=4, seq=32)
            sink = []
            with mesh:
                _, hist = tr.run(state, data, num_steps=steps, log_every=0,
                                 stats_sink=sink)
            migrated = sum(p.num_migrated for p in tr.engine.placements)
            relocations = sum(s.relocations for s in sink)
            return hist, sink, migrated, relocations
        finally:
            os.environ.pop("REPRO_MIGRATION", None)

    hist_off, sink_off, mig_off, rel_off = run(False)
    hist_on, sink_on, mig_on, rel_on = run(True)
    hist_flag, sink_flag, _, _ = run(True, flag="0")  # flag forces off

    # The enabled run actually migrated and executed the exchange …
    assert mig_on >= 1, mig_on
    assert rel_on >= 1, rel_on
    assert mig_off == rel_off == 0, (mig_off, rel_off)
    # … without any loss divergence: bit-identical trajectories.
    assert hist_on == hist_off, (hist_on, hist_off)
    # REPRO_MIGRATION=0 ≡ enable_migration=False, placements included.
    assert hist_flag == hist_off
    assert [s.placements_fingerprint for s in sink_flag] == \
        [s.placements_fingerprint for s in sink_off]
    print("MIGRATION_TRAINER_EQUIVALENCE_PASS")


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    layer_equivalence(mesh)
    trainer_equivalence(mesh)


if __name__ == "__main__":
    main()
