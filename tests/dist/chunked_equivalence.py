"""8-host-device check of the chunked a2a↔FEC pipeline on a (2, 4) mesh.

Part 1 — layer level: moe_apply with K ∈ {2, 4} capacity chunks must be
bit-identical to K=1 in the forward (chunking only re-tiles the capacity
axis; per-token math is untouched), with identical routing counts and
dropped-token telemetry, and gradients equal to summation round-off —
including the shadow (Trans/Agg) path.

Part 2 — trainer level (the acceptance criterion): ≥8 steps with
REPRO_A2A_CHUNKS=1 are bit-identical to the engine-driven default (which
resolves to K=1 on this hardware profile) in losses, placements, and
drop telemetry; a forced K=2 run keeps identical placements, tracks the
K=1 losses, and reports a modeled hidden-comm fraction > 0 with a
strictly lower chunked timeline makespan.

Run by tests/test_distributed.py in a subprocess so the XLA device count
is set before jax initializes.
"""
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core import EngineConfig, HardwareSpec, ProProphetEngine
from repro.data import SyntheticLM
from repro.models import moe
from repro.optim import adamw, cosine
from repro.parallel import make_ctx
from repro.train import Trainer
from repro.train.runtime import OverlapTelemetry
from jax.sharding import Mesh


def layer_equivalence(mesh):
    ctx = make_ctx(mesh)
    E, d, f = 8, 16, 32
    placement = {
        "shadow_idx": jnp.array([2, E], jnp.int32),
        "shadow_valid": jnp.array([1.0, 0.0], jnp.float32),
        "shadow_devs": jnp.array([[0.0, 1.0, 1.0, 0.0],
                                  [0.0, 0.0, 0.0, 0.0]], jnp.float32),
    }
    kw = dict(num_experts=E, top_k=2, d_expert=f, ffn_kind="swiglu",
              capacity_factor=2.0, shadow_capacity_factor=4.0, s_max=2)

    def run(k, params, x, pl):
        y, aux = moe.moe_apply(params, x, pl, ctx, a2a_chunks=k, **kw)

        def loss(p):
            yy, _ = moe.moe_apply(p, x, pl, ctx, a2a_chunks=k, **kw)
            return jnp.sum(yy ** 2)

        return y, aux, jax.grad(loss)(params)

    for seed, pl in ((0, None), (1, placement)):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        params = moe.moe_init(ks[0], d, f, E, ffn_kind="swiglu")
        # bias the router so chunks see skewed, ragged occupancy
        params["router"]["w"] = (params["router"]["w"]
                                 + 2.0 * jax.random.normal(ks[2], (E,)))
        x = 0.5 * jax.random.normal(ks[1], (2, 16, d))
        y1, aux1, g1 = run(1, params, x, pl)
        for k in (2, 4):
            yk, auxk, gk = run(k, params, x, pl)
            np.testing.assert_array_equal(np.asarray(y1), np.asarray(yk))
            np.testing.assert_array_equal(np.asarray(aux1["counts"]),
                                          np.asarray(auxk["counts"]))
            assert float(aux1["dropped"]) == float(auxk["dropped"])
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gk)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-6)
    print("CHUNKED_LAYER_EQUIVALENCE_PASS")


def make_engine(cfg, ctx):
    """Compute-bound profile with zero balance tolerance: the planner
    shadows aggressively (placements actually change mid-run) while the
    scheduler's chunk chooser resolves to K=1 (tiny a2a vs the per-chunk
    overhead) — so the engine-driven default is the K=1 path."""
    hw = HardwareSpec.from_model_dims(cfg.d_model, cfg.moe.d_expert,
                                      bandwidth=1e12, flops_per_s=1e12,
                                      num_ffn_mats=3)
    ec = EngineConfig(num_experts=cfg.moe.num_experts,
                      num_devices=ctx.ep_size,
                      num_moe_layers=cfg.num_moe_layers,
                      s_max=cfg.moe.s_max, alpha=0.0)
    return ProProphetEngine(ec, hw)


def trainer_equivalence(mesh):
    ctx = make_ctx(mesh)
    cfg = reduced(get_config("moe-gpt-s"))   # 4 experts over EP=4
    steps = 8
    tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 3, steps)), attn_impl="naive",
                 remat=False, engine=make_engine(cfg, ctx))

    def run(k_env):
        if k_env is not None:
            os.environ["REPRO_A2A_CHUNKS"] = str(k_env)
        try:
            tr.engine = make_engine(cfg, ctx)
            state = tr.init_state(jax.random.PRNGKey(0))
            data = SyntheticLM(cfg, batch=4, seq=32)
            sink, tel = [], OverlapTelemetry()
            with mesh:
                _, hist = tr.run(state, data, num_steps=steps, log_every=0,
                                 stats_sink=sink, telemetry=tel)
            return hist, sink, tel
        finally:
            os.environ.pop("REPRO_A2A_CHUNKS", None)

    hist_d, sink_d, _ = run(None)     # engine-driven default
    hist_1, sink_1, _ = run(1)        # forced bit-identical path
    hist_2, sink_2, tel_2 = run(2)    # forced chunked path

    # K=1 ≡ the engine-driven path, bit-identical over 8 steps
    assert [s.a2a_chunks for s in sink_d] == [1] * steps
    assert hist_d == hist_1, (hist_d, hist_1)
    assert [s.placements_fingerprint for s in sink_d] == \
        [s.placements_fingerprint for s in sink_1]

    # K=2: identical placements (planning sees identical integer counts),
    # losses within float round-off drift of the K=1 history
    assert [s.a2a_chunks for s in sink_2] == [2] * steps
    assert [s.placements_fingerprint for s in sink_2] == \
        [s.placements_fingerprint for s in sink_1]
    np.testing.assert_allclose(hist_1, hist_2, rtol=5e-2)
    # the run exercised real replanning (not a static placement)
    assert len(set(s.placements_fingerprint for s in sink_1)) > 1

    # modeled overlap telemetry: chunking hides comm, K=1 hides none
    s2 = tel_2.summary()
    assert s2["comm_hidden_frac"] > 0.0, s2
    assert s2["mean_a2a_gbytes"] > 0.0, s2
    assert all(s.comm_hidden_frac == 0.0 for s in sink_1)
    # strictly lower chunked timeline makespan for the skewed loads
    stats = tr.engine.chunk_stats([2] * cfg.num_moe_layers)
    assert stats["chunked_s"] < stats["serial_s"], stats
    print("CHUNKED_TRAINER_EQUIVALENCE_PASS")


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    layer_equivalence(mesh)
    trainer_equivalence(mesh)


if __name__ == "__main__":
    main()
