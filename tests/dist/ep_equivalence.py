"""8-host-device check: moe_apply on a (data=2, model=4) mesh must match
the single-device reference bit-for-bit (forward) and to f32 noise
(grads).  Run by tests/test_distributed.py in a subprocess so the XLA
device count is set before jax initializes."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.models import moe
from repro.parallel import local_ctx, make_ctx
from jax.sharding import Mesh


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    ctx_m, ctx_l = make_ctx(mesh), local_ctx()

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    E, d, f, B, S = 8, 16, 32, 2, 16
    params = moe.moe_init(ks[0], d, f, E, ffn_kind="swiglu")
    x = 0.5 * jax.random.normal(ks[1], (B, S, d))
    # capacity factors high enough that neither layout drops tokens —
    # otherwise per-shard capacities differ and parity is not expected.
    kw = dict(num_experts=E, top_k=2, d_expert=f, ffn_kind="swiglu",
              capacity_factor=8.0, shadow_capacity_factor=8.0, s_max=2)

    y_l, aux_l = moe.moe_apply(params, x, None, ctx_l, **kw)
    y_m, aux_m = moe.moe_apply(params, x, None, ctx_m, **kw)
    np.testing.assert_allclose(np.asarray(y_l), np.asarray(y_m),
                               rtol=2e-5, atol=2e-6)
    assert int(jnp.asarray(aux_l["counts"]).sum()) == \
        int(jnp.asarray(aux_m["counts"]).sum())
    print("EP_EQUIVALENCE_PASS")

    def loss(p, ctx):
        y, _ = moe.moe_apply(p, x, None, ctx, **kw)
        return jnp.sum(y ** 2)

    g_l = jax.grad(lambda p: loss(p, ctx_l))(params)
    g_m = jax.grad(lambda p: loss(p, ctx_m))(params)
    for a, b in zip(jax.tree.leaves(g_l), jax.tree.leaves(g_m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-5)
    print("TRAINING_PARITY_PASS")


if __name__ == "__main__":
    main()
