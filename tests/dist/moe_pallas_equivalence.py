"""8-host-device check: REPRO_MOE_PALLAS on vs off must be numerically
identical through shard_map — the ragged Pallas expert FFN (interpret
mode on CPU) against the dense einsum, over skewed routing
distributions, forward and backward."""
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import moe
from repro.parallel import make_ctx
from jax.sharding import Mesh


def run(flag, params, x, ctx, kw):
    os.environ["REPRO_MOE_PALLAS"] = flag
    try:
        y, aux = moe.moe_apply(params, x, None, ctx, **kw)

        def loss(p):
            yy, _ = moe.moe_apply(p, x, None, ctx, **kw)
            return jnp.sum(yy ** 2)

        return y, aux, jax.grad(loss)(params)
    finally:
        del os.environ["REPRO_MOE_PALLAS"]


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    ctx = make_ctx(mesh)
    E, d, f = 8, 16, 32
    kw = dict(num_experts=E, top_k=2, d_expert=f, ffn_kind="swiglu",
              capacity_factor=2.0, shadow_capacity_factor=4.0, s_max=2)
    for seed in range(3):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        params = moe.moe_init(ks[0], d, f, E, ffn_kind="swiglu")
        # bias the router so each seed exercises a different load skew
        params["router"]["w"] = (params["router"]["w"]
                                 + 2.0 * jax.random.normal(ks[2], (E,)))
        x = 0.5 * jax.random.normal(ks[1], (2, 16, d))
        y0, aux0, g0 = run("0", params, x, ctx, kw)
        y1, aux1, g1 = run("1", params, x, ctx, kw)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(aux0["counts"]),
                                      np.asarray(aux1["counts"]))
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)
    print("MOE_PALLAS_MESH_EQUIVALENCE_PASS")


if __name__ == "__main__":
    main()
