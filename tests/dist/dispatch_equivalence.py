"""8-host-device check: REPRO_DISPATCH_PALLAS on vs off must be
numerically identical through shard_map — the Pallas token-permutation
kernels (sorted-gather dispatch + fused gate combine, interpret mode on
CPU) against the jnp scatter/gather, over skewed routing, forward and
backward, for both the serial (K=1) and chunked (K=2) a2a pipelines and
with live shadow placements so the shadow buffer permutes too."""
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import moe
from repro.parallel import make_ctx
from jax.sharding import Mesh


def run(flag, params, x, placement, ctx, kw, chunks):
    os.environ["REPRO_DISPATCH_PALLAS"] = flag
    try:
        y, aux = moe.moe_apply(params, x, placement, ctx,
                               a2a_chunks=chunks, **kw)

        def loss(p):
            yy, _ = moe.moe_apply(p, x, placement, ctx,
                                  a2a_chunks=chunks, **kw)
            return jnp.sum(yy ** 2)

        return y, aux, jax.grad(loss)(params)
    finally:
        del os.environ["REPRO_DISPATCH_PALLAS"]


def make_placement(E, ep, s_max):
    """One live shadow (expert 0 everywhere) so the shadow dispatch /
    combine path carries real traffic."""
    sidx = np.full((s_max,), E, np.int32)
    svalid = np.zeros((s_max,), np.float32)
    sdevs = np.zeros((s_max, ep), np.float32)
    sidx[0], svalid[0] = 0, 1.0
    sdevs[0, :] = 1.0
    return {"shadow_idx": jnp.asarray(sidx),
            "shadow_valid": jnp.asarray(svalid),
            "shadow_devs": jnp.asarray(sdevs)}


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    ctx = make_ctx(mesh)
    E, d, f = 8, 16, 32
    kw = dict(num_experts=E, top_k=2, d_expert=f, ffn_kind="swiglu",
              capacity_factor=2.0, shadow_capacity_factor=4.0, s_max=2)
    placement = make_placement(E, ctx.ep_size, 2)
    for seed in range(2):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        params = moe.moe_init(ks[0], d, f, E, ffn_kind="swiglu")
        # bias the router so each seed exercises a different load skew
        params["router"]["w"] = (params["router"]["w"]
                                 + 2.0 * jax.random.normal(ks[2], (E,)))
        x = 0.5 * jax.random.normal(ks[1], (2, 16, d))
        for chunks in (1, 2):
            y0, aux0, g0 = run("0", params, x, placement, ctx, kw, chunks)
            y1, aux1, g1 = run("1", params, x, placement, ctx, kw, chunks)
            np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_array_equal(np.asarray(aux0["counts"]),
                                          np.asarray(aux1["counts"]))
            assert float(aux0["dropped"]) == float(aux1["dropped"])
            for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-6)
    print("DISPATCH_MESH_EQUIVALENCE_PASS")


if __name__ == "__main__":
    main()
