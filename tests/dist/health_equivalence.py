"""8-host-device acceptance check of degraded-mode training on a
(2, 4) mesh: injected device loss on the EP axis.

A trainer with health tracking enabled runs twice on identical data and
seeds: once clean, once with a ``device_loss`` fault injected on EP rank
2 mid-run.  The faulted run must

  1. classify rank 2 *lost* after the tracker's patience window of
     missed heartbeats,
  2. evacuate every expert off the lost rank within one plan cadence of
     the classification (slot swaps drain the hot residents, forced
     shadows cover the stranded cold experts — remote load on rank 2
     drops to exactly zero), and
  3. keep the loss history — including the final loss, computed on the
     evacuated placement — **bit-identical** to the clean run: health
     actions only re-home compute (ample capacity, no grad clipping, a
     single a2a chunk), they never change the forward math.

The fault lands so that the evacuating plan reaches the *final*
dispatch.  Forward compute on the evacuated placement is exactly
bit-identical (same weights, same tokens, only re-homed); the
*backward* pass of a forced shadow reduces each replica's parameter
gradient with a psum whose summation order differs from the clean
run's single-owner matmul, so once an evacuated backward feeds an
optimizer update, last-ulp reassociation noise enters and the top-k
router amplifies it a couple of steps later.  Pinning evacuation to
the last dispatch makes the whole 12-step history — including the
final, fully-evacuated loss — an exact bitwise assertion; the
ulp-reassociation horizon beyond it is a property of floating-point
shadow gradients, not of the evacuation machinery.

Run by tests/test_distributed.py in a subprocess so the XLA device
count is set before jax initializes.
"""
import dataclasses
import os

os.environ.setdefault("REPRO_A2A_CHUNKS", "1")  # noqa: E402 — before jax

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.core import EngineConfig, HardwareSpec, ProProphetEngine
from repro.data import SyntheticLM
from repro.optim import adamw, cosine
from repro.parallel import make_ctx
from repro.testing import faults
from repro.testing.faults import Fault, FaultInjector
from repro.train import Trainer
from jax.sharding import Mesh

STEPS = 12
LOST = 2
# Fault onset: patience-3 detection at step 10, evacuating plan lands at
# the final dispatch — the last loss is computed fully evacuated.
FAULT_AT = 7


def make_engine(cfg, ctx):
    hw = HardwareSpec.from_model_dims(cfg.d_model, cfg.moe.d_expert,
                                      bandwidth=1e9, flops_per_s=200e12,
                                      num_ffn_mats=3)
    ec = EngineConfig(num_experts=cfg.moe.num_experts,
                      num_devices=ctx.ep_size,
                      num_moe_layers=cfg.num_moe_layers,
                      s_max=cfg.moe.s_max, scheduled=False,
                      enable_health=True, health_patience=3)
    return ProProphetEngine(ec, hw)


def run(cfg, ctx, mesh, injector=None):
    # clip_norm=None: evacuation permutes expert rows and global-norm
    # clipping would re-associate the reduction; everything else in the
    # step is exactly permutation-equivariant.
    tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 3, STEPS), clip_norm=None),
                 attn_impl="naive", remat=False,
                 engine=make_engine(cfg, ctx))
    state = tr.init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=4, seq=32)
    sink = []
    with mesh:
        if injector is not None:
            with faults.injected(injector):
                _, hist = tr.run(state, data, num_steps=STEPS,
                                 log_every=0, stats_sink=sink)
        else:
            _, hist = tr.run(state, data, num_steps=STEPS,
                             log_every=0, stats_sink=sink)
    return hist, sink, tr.engine


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    ctx = make_ctx(mesh)
    cfg = reduced(get_config("moe-gpt-s"), max_experts=8)  # 8 experts, EP=4
    # Ample capacity: evacuation must not change drop behavior, so the
    # faulted and clean trajectories stay bit-identical.
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     shadow_capacity_factor=8.0))

    hist_clean, sink_clean, _ = run(cfg, ctx, mesh)
    inj = FaultInjector([Fault("device_loss", at=FAULT_AT,
                               payload={"device": LOST})], seed=0)
    hist_fault, sink_fault, engine = run(cfg, ctx, mesh, injector=inj)

    # The fault fired and the tracker declared the rank lost.
    assert ("device_loss", FAULT_AT) in inj.fired, inj.fired
    assert LOST in engine.lost_devices(), engine.health_summary()

    # Clean run never leaves the healthy state (uniform step-time
    # broadcast cannot trip the relative-ratio classifier).
    assert all(s.health_state == "healthy" for s in sink_clean)
    assert all(s.evacuations == 0 for s in sink_clean)

    # Evacuation happened within one plan cadence of the classification:
    # the forced replan on the lost transition fires in the very next
    # engine observe, so at most one step separates detection from the
    # evacuating plan (plus one dispatch for the plan to land).
    lost_steps = [s.step for s in sink_fault if s.lost_devices > 0]
    evac_steps = [s.step for s in sink_fault if s.evacuations > 0]
    assert lost_steps, [s.health_state for s in sink_fault]
    assert evac_steps, "lost rank was never evacuated"
    cadence = max(1, engine.cfg.replan_interval)
    assert evac_steps[0] - lost_steps[0] <= cadence + 1, (
        lost_steps[0], evac_steps[0], cadence)

    # The evacuating relocation executed and the final step actually
    # dispatched on the evacuated placement (the bit-identity below is
    # vacuous if the run ends before the plan lands).
    reloc_steps = [s.step for s in sink_fault if s.relocations > 0]
    assert reloc_steps, "evacuation never reached the dispatch path"
    assert reloc_steps[0] <= STEPS - 1, reloc_steps

    # All experts are off the lost rank: remote load on it is exactly
    # zero for any routing (hot residents swapped out, stranded cold
    # experts shadowed on every healthy rank).
    ones = np.ones((ctx.ep_size, cfg.moe.num_experts))
    for pl in engine.placements:
        _, R = pl.compute_loads(ones)
        assert R[LOST] == 0.0, R
        for e, devs in pl.shadows.items():
            assert LOST not in devs, (e, devs)
    assert engine.evacuations >= 1, engine.evacuations

    # The acceptance criterion: degraded-mode actions re-home compute
    # without perturbing a single bit of the loss trajectory.
    assert hist_fault == hist_clean, (hist_fault, hist_clean)
    print("HEALTH_EQUIVALENCE_PASS")


if __name__ == "__main__":
    main()
