"""Layer-level numerics: attention impl equivalence, decode-vs-forward
consistency for every mixer family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import ssm, xlstm

KEY = jax.random.PRNGKey(0)


def rand(key, shape, scale=0.5):
    return scale * jax.random.normal(key, shape, jnp.float32)


class TestAttentionImpls:
    @pytest.mark.parametrize("window", [None, 16])
    @pytest.mark.parametrize("S", [64, 96])
    def test_chunked_matches_naive(self, S, window):
        B, H, K, dh = 2, 4, 2, 16
        p = attn.attention_init(KEY, 32, H, K, dh)
        x = rand(jax.random.PRNGKey(1), (B, S, 32))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        kw = dict(num_heads=H, num_kv_heads=K, head_dim=dh, window=window)
        y0 = attn.multihead_attention(p, x, pos, impl="naive", **kw)
        y1 = attn.multihead_attention(p, x, pos, impl="chunked",
                                      q_block=32, kv_block=32, **kw)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-4, atol=2e-5)

    def test_banded_matches_naive(self):
        B, H, K, dh, S, W = 1, 2, 1, 16, 128, 24
        p = attn.attention_init(KEY, 32, H, K, dh)
        x = rand(jax.random.PRNGKey(2), (B, S, 32))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        kw = dict(num_heads=H, num_kv_heads=K, head_dim=dh, window=W)
        y0 = attn.multihead_attention(p, x, pos, impl="naive", **kw)
        y1 = attn.multihead_attention(p, x, pos, impl="banded",
                                      q_block=32, kv_block=32, **kw)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-4, atol=2e-5)

    def test_pallas_matches_naive(self):
        B, H, K, dh, S = 1, 2, 2, 64, 128
        p = attn.attention_init(KEY, 64, H, K, dh)
        x = rand(jax.random.PRNGKey(3), (B, S, 64))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        kw = dict(num_heads=H, num_kv_heads=K, head_dim=dh)
        y0 = attn.multihead_attention(p, x, pos, impl="naive", **kw)
        y1 = attn.multihead_attention(p, x, pos, impl="pallas", **kw)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-3, atol=2e-3)

    def test_qkv_bias(self):
        p = attn.attention_init(KEY, 32, 2, 2, 16, qkv_bias=True)
        assert "bq" in p and "bk" in p and "bv" in p
        x = rand(KEY, (1, 8, 32))
        pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
        y = attn.multihead_attention(p, x, pos, num_heads=2, num_kv_heads=2,
                                     head_dim=16, impl="naive")
        assert not bool(jnp.any(jnp.isnan(y)))

    @pytest.mark.parametrize("window", [None, 8])
    def test_decode_matches_forward(self, window):
        """Token-by-token decode reproduces the full forward's last rows."""
        B, H, K, dh, S = 2, 4, 2, 16, 24
        d = 32
        p = attn.attention_init(KEY, d, H, K, dh)
        x = rand(jax.random.PRNGKey(4), (B, S, d))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        full = attn.multihead_attention(p, x, pos, num_heads=H,
                                        num_kv_heads=K, head_dim=dh,
                                        window=window, impl="naive")
        ck = jnp.zeros((B, S, K, dh))
        cv = jnp.zeros((B, S, K, dh))
        outs = []
        for t in range(S):
            y, ck, cv = attn.decode_attention(
                p, x[:, t:t + 1], ck, cv, t, num_heads=H, num_kv_heads=K,
                head_dim=dh, window=window)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   rtol=2e-4, atol=2e-5)


class TestMLA:
    KW = dict(num_heads=4, kv_rank=32, nope_dim=16, rope_dim=8, v_dim=16)

    def _params(self, d=64):
        return mla_mod.mla_init(KEY, d, 4, q_rank=48, kv_rank=32,
                                nope_dim=16, rope_dim=8, v_dim=16)

    def test_forward_shapes(self):
        d, B, S = 64, 2, 16
        p = self._params(d)
        x = rand(KEY, (B, S, d))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        y = mla_mod.mla_attention(p, x, pos, impl="naive", **self.KW)
        assert y.shape == (B, S, d)

    def test_decode_matches_forward(self):
        """Absorbed-latent decode == materialized training attention."""
        d, B, S = 64, 2, 12
        p = self._params(d)
        x = rand(jax.random.PRNGKey(5), (B, S, d))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        full = mla_mod.mla_attention(p, x, pos, impl="naive", **self.KW)
        ckv = jnp.zeros((B, S, 32))
        kr = jnp.zeros((B, S, 8))
        outs = []
        for t in range(S):
            y, ckv, kr = mla_mod.mla_decode(p, x[:, t:t + 1], ckv, kr, t,
                                            **self.KW)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   rtol=3e-4, atol=3e-5)

    def test_chunked_matches_naive(self):
        d, B, S = 64, 1, 64
        p = self._params(d)
        x = rand(jax.random.PRNGKey(6), (B, S, d))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        y0 = mla_mod.mla_attention(p, x, pos, impl="naive", **self.KW)
        y1 = mla_mod.mla_attention(p, x, pos, impl="chunked", q_block=16,
                                   **self.KW)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-4, atol=2e-5)


class TestMamba:
    def test_decode_matches_scan(self):
        d, B, S = 32, 2, 10
        p = ssm.mamba_init(KEY, d)
        x = rand(jax.random.PRNGKey(7), (B, S, d))
        full = ssm.mamba(p, x)
        st = ssm.mamba_init_state(B, d)
        outs = []
        for t in range(S):
            y, st = ssm.mamba_decode(p, x[:, t:t + 1], st)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   rtol=3e-4, atol=3e-5)


class TestXLSTM:
    def test_mlstm_parallel_matches_recurrent(self):
        d, B, S, H = 32, 2, 32, 4
        p = xlstm.mlstm_init(KEY, d, H)
        x = rand(jax.random.PRNGKey(8), (B, S, d))
        y0 = xlstm.mlstm(p, x, num_heads=H, impl="parallel")
        y1 = xlstm.mlstm(p, x, num_heads=H, impl="recurrent")
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=3e-4, atol=3e-4)

    def test_mlstm_decode_matches_recurrent(self):
        d, B, S, H = 32, 1, 8, 4
        p = xlstm.mlstm_init(KEY, d, H)
        x = rand(jax.random.PRNGKey(9), (B, S, d))
        full = xlstm.mlstm(p, x, num_heads=H, impl="recurrent")
        st = xlstm.mlstm_init_state(B, d, H)
        outs = []
        for t in range(S):
            y, st = xlstm.mlstm_decode(p, x[:, t:t + 1], st, num_heads=H)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   rtol=3e-4, atol=3e-4)

    def test_slstm_decode_matches_scan(self):
        d, B, S, H = 32, 2, 8, 4
        p = xlstm.slstm_init(KEY, d, H)
        x = rand(jax.random.PRNGKey(10), (B, S, d))
        full = xlstm.slstm(p, x, num_heads=H)
        st = xlstm.slstm_init_state(B, d, H)
        outs = []
        for t in range(S):
            y, st = xlstm.slstm_decode(p, x[:, t:t + 1], st, num_heads=H)
            outs.append(y)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                                   rtol=3e-4, atol=3e-4)
