"""Self-healing placement runtime: watchdog, transactional relocation,
atomic checkpoints, and the deterministic fault-injection harness.

The invariant under test everywhere: placements decide *where* compute
happens, never the math — so every degradation path (rejected plan,
rolled-back relocation, restored checkpoint) must keep the loss
trajectory bit-identical to the fault-free run.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core import ProProphetEngine
from repro.core import guard
from repro.core.engine import EngineConfig
from repro.core.perfmodel import HardwareSpec
from repro.core.placement import ExpertPlacement
from repro.testing import Fault, FaultInjector, faults
from repro.train.runtime import (OverlapTelemetry, PlanPipeline,
                                 counts_to_layers, run_plan)


def _hw(bw=25e9, fl=70e12):
    return HardwareSpec.from_model_dims(512, 1024, bandwidth=bw,
                                        flops_per_s=fl)


def _engine(layers=2, d=4, e=8, **kw):
    cfg = EngineConfig(num_experts=e, num_devices=d, num_moe_layers=layers,
                       s_max=4, **kw)
    return ProProphetEngine(cfg, _hw())


def _skewed(d=4, e=8, hot=0, tokens=300.0):
    g = np.full((d, e), 10.0)
    g[:, hot] = tokens
    return g


def _counts(layers=2, d=4, e=8, hot=0):
    return np.stack([_skewed(d, e, hot)] * layers)


# ---------------------------------------------------------------------------
# Guards: routing-count ingestion + placement invariants
# ---------------------------------------------------------------------------

class TestCountGuards:
    def test_check_counts_accepts_clean(self):
        guard.check_counts(_skewed(), (4, 8))

    @pytest.mark.parametrize("poison", [np.nan, np.inf, -1.0])
    def test_check_counts_rejects_poison(self, poison):
        g = _skewed()
        g[1, 3] = poison
        with pytest.raises(guard.CountsError):
            guard.check_counts(g, (4, 8))

    def test_check_counts_rejects_shape_and_dtype(self):
        with pytest.raises(guard.CountsError, match="shape"):
            guard.check_counts(np.ones((3, 8)), (4, 8))
        with pytest.raises(guard.CountsError, match="dtype"):
            guard.check_counts(np.full((4, 8), "x"), (4, 8))

    def test_sanitize_passthrough_clean(self):
        c = _counts()
        layers, rep = guard.sanitize_counts(c)
        assert rep.num_sanitized == 0 and not rep and len(layers) == 2
        assert rep.repaired == [] and rep.uniform == []
        np.testing.assert_array_equal(layers[0], c[0])

    def test_sanitize_replaces_dirty_layer_with_fallback(self):
        c = _counts().astype(np.float64)
        c[1, 0, 0] = np.nan
        fb = [_skewed(hot=2), _skewed(hot=3)]
        layers, rep = guard.sanitize_counts(c, fallback=fb)
        assert rep.num_sanitized == 1
        assert rep.repaired == [1] and rep.uniform == []
        np.testing.assert_array_equal(layers[0], c[0])   # clean layer kept
        np.testing.assert_array_equal(layers[1], fb[1])  # dirty → fallback

    def test_sanitize_uniform_without_fallback(self):
        c = _counts().astype(np.float64)
        c[0, 2, :] = -5.0
        layers, rep = guard.sanitize_counts(c, fallback=[None, None])
        assert rep.num_sanitized == 1
        assert rep.repaired == [0] and rep.uniform == [0]
        np.testing.assert_array_equal(layers[0], np.ones((4, 8)))

    def test_sanitize_ignores_dirty_fallback(self):
        c = _counts().astype(np.float64)
        c[0, 0, 0] = np.inf
        bad_fb = _skewed()
        bad_fb[0, 0] = np.nan
        layers, rep = guard.sanitize_counts(c, fallback=[bad_fb, None])
        assert rep.num_sanitized == 1
        assert rep.uniform == [0]   # dirty fallback is no fallback
        np.testing.assert_array_equal(layers[0], np.ones((4, 8)))

    def test_sanitize_first_observation_path(self):
        # Regression: the very first watchdog plan has no last-good
        # history (fallback=None entries) — every dirty layer must land
        # on the uniform prior and be reported as such, clean layers
        # must pass through untouched.
        c = _counts(layers=3).astype(np.float64)
        c[0, 1, 2] = np.nan
        c[2, 0, 0] = -3.0
        layers, rep = guard.sanitize_counts(c, fallback=None)
        assert rep.repaired == [0, 2]
        assert rep.uniform == [0, 2]
        np.testing.assert_array_equal(layers[0], np.ones((4, 8)))
        np.testing.assert_array_equal(layers[1], c[1])
        np.testing.assert_array_equal(layers[2], np.ones((4, 8)))

    def test_sanitize_rejects_wrong_rank(self):
        with pytest.raises(guard.CountsError):
            guard.sanitize_counts(np.ones((4, 8)))
        with pytest.raises(guard.CountsError):
            counts_to_layers(np.ones((4, 8)))


class TestPlacementGuards:
    def test_valid_engine_passes(self):
        eng = _engine()
        eng.observe([_skewed(), _skewed(hot=3)])
        guard.validate_engine(eng)

    def test_rejects_wrong_device_width(self):
        with pytest.raises(guard.PlacementInvariantError, match="devices"):
            guard.validate_placement(ExpertPlacement(8, 2, {}, None),
                                     num_experts=8, num_devices=4)

    def test_rejects_shadow_on_owner(self):
        # the constructor asserts this; model post-construction corruption
        pl = ExpertPlacement(8, 4, {}, None)
        object.__setattr__(pl, "shadows", {0: frozenset({0, 2})})
        with pytest.raises(guard.PlacementInvariantError, match="owner"):
            guard.validate_placement(pl, num_experts=8, num_devices=4)

    def test_rejects_out_of_range_shadow_device(self):
        pl = ExpertPlacement(8, 4, {}, None)
        object.__setattr__(pl, "shadows", {0: frozenset({7})})
        with pytest.raises(guard.PlacementInvariantError, match="outside"):
            guard.validate_placement(pl, num_experts=8, num_devices=4)

    def test_rejects_nonfinite_modeled_time(self):
        eng = _engine()
        eng.observe([_skewed(), _skewed()])
        eng.predicted_times = lambda: {"predicted": float("nan")}
        with pytest.raises(guard.PlacementInvariantError, match="finite"):
            guard.validate_engine(eng)


# ---------------------------------------------------------------------------
# Engine ingestion guard (observe is the backstop behind the sanitizer)
# ---------------------------------------------------------------------------

class TestObserveIngestionGuard:
    def test_observe_rejects_nan(self):
        eng = _engine()
        g = _skewed()
        g[0, 0] = np.nan
        with pytest.raises(guard.CountsError):
            eng.observe([g, _skewed()])

    def test_observe_rejects_negative(self):
        eng = _engine()
        g = _skewed()
        g[2, 1] = -3.0
        with pytest.raises(guard.CountsError):
            eng.observe([_skewed(), g])

    def test_observe_rejects_layer_count_mismatch(self):
        with pytest.raises(guard.CountsError, match="layer"):
            _engine(layers=2).observe([_skewed()])

    def test_rejected_observe_leaves_engine_clean(self):
        eng = _engine()
        eng.observe([_skewed(), _skewed()])
        v, obs = eng.placements_version, eng._obs_count
        g = _skewed()
        g[0, 0] = np.inf
        with pytest.raises(guard.CountsError):
            eng.observe([g, _skewed()])
        assert eng.placements_version == v and eng._obs_count == obs


# ---------------------------------------------------------------------------
# Engine snapshot/restore + migration cancel (the watchdog's rollback)
# ---------------------------------------------------------------------------

class TestEngineRollback:
    def test_snapshot_restore_roundtrip(self):
        eng = _engine()
        eng.observe([_skewed(hot=0), _skewed(hot=1)])
        snap = eng.snapshot()
        v = eng.placements_version
        pls = eng.placements
        eng.observe([_skewed(hot=5), _skewed(hot=6)])
        assert eng.placements_version != v
        eng.restore(snap)
        assert eng.placements_version == v
        assert eng.placements == pls
        # the planner cadence state rolled back too: re-observing the
        # original distribution reproduces the pre-snapshot trajectory
        eng.observe([_skewed(hot=5), _skewed(hot=6)])
        after = eng.placements
        eng.restore(snap)
        eng.observe([_skewed(hot=5), _skewed(hot=6)])
        assert eng.placements == after

    def test_last_counts_copies(self):
        eng = _engine()
        assert eng.last_counts() == [None, None]
        eng.observe([_skewed(), _skewed(hot=2)])
        lc = eng.last_counts()
        lc[0][0, 0] = -99.0
        assert eng._last_g[0][0, 0] != -99.0

    def test_cancel_migrations_resets_slots(self):
        ec = EngineConfig(num_experts=8, num_devices=4, num_moe_layers=2,
                          s_max=4, alpha=0.0, scheduled=False,
                          enable_migration=True, migrate_window=500.0)
        eng = ProProphetEngine(ec, _hw(bw=1e9, fl=200e12))
        g = np.full((4, 8), 10.0)
        g[:, 0] = 300.0
        g[:, 1] = 250.0      # persistent two-expert skew ⇒ migration wins
        eng.observe([g, g])
        assert any(p.num_migrated for p in eng.placements)
        v = eng.placements_version
        n = eng.cancel_migrations()
        assert n >= 1
        assert eng.placements_version == v + 1
        assert all(p.slot_of is None for p in eng.placements)
        assert eng.pending_relocation() is None
        guard.validate_engine(eng)


# ---------------------------------------------------------------------------
# Watchdog: run_plan fallback semantics
# ---------------------------------------------------------------------------

class TestPlanWatchdog:
    def test_injected_planner_exception_falls_back(self):
        eng = _engine()
        eng.observe([_skewed(), _skewed()])
        v, pls = eng.placements_version, eng.placements
        with faults.injected(FaultInjector([Fault("planner_exception", 0)])):
            ev = run_plan(eng, _counts(hot=5))
        assert not ev.ok and ev.failure == "planner_exception"
        assert eng.placements_version == v and eng.placements == pls
        # next plan is healthy again
        ev = run_plan(eng, _counts(hot=5))
        assert ev.ok

    def test_invariant_violation_rolls_back(self):
        eng = _engine()
        eng.observe([_skewed(), _skewed()])
        v = eng.placements_version
        orig = eng.observe

        def poisoned(per_layer_g, pool=None):
            orig(per_layer_g, pool=pool)
            # planner bug: placement for a 2-wide mesh on a 4-wide engine
            eng._placements[0] = ExpertPlacement(8, 2, {}, None)
        eng.observe = poisoned
        ev = run_plan(eng, _counts(hot=5))
        assert not ev.ok and ev.failure == "invariant"
        assert eng.placements_version == v
        assert eng.placements[0].num_devices == 4

    def test_corrupted_counts_sanitized(self):
        eng = _engine()
        clean = _counts()
        run_plan(eng, clean)                       # last-good observation
        with faults.injected(FaultInjector(
                [Fault("corrupt_counts", 0, {"mode": "mixed"})], seed=7)):
            ev = run_plan(eng, _counts(hot=5))
        assert ev.ok and ev.sanitized_layers >= 1
        for g in eng._last_g:
            assert np.isfinite(g).all() and (g >= 0).all()

    def test_deadline_overrun_falls_back(self, monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_DEADLINE_MS", "5")
        eng = _engine()
        eng.observe([_skewed(), _skewed()])
        v = eng.placements_version
        with faults.injected(FaultInjector(
                [Fault("slow_plan", 0, {"delay_s": 0.05})])):
            ev = run_plan(eng, _counts(hot=5))
        assert not ev.ok and ev.failure == "deadline"
        assert eng.placements_version == v
        monkeypatch.delenv("REPRO_PLAN_DEADLINE_MS")
        assert run_plan(eng, _counts(hot=5)).ok

    def test_bad_counts_rank_is_fallback_not_crash(self):
        eng = _engine()
        ev = run_plan(eng, np.ones((4, 8)))
        assert not ev.ok and ev.failure == "bad_counts"
        assert eng._obs_count == 0


# ---------------------------------------------------------------------------
# PlanPipeline lifecycle (satellite: close/__exit__)
# ---------------------------------------------------------------------------

class TestPipelineLifecycle:
    def test_close_idempotent(self):
        pipe = PlanPipeline(_engine())
        pipe.close()
        pipe.close()

    def test_close_with_unconsumed_plan(self):
        pipe = PlanPipeline(_engine())
        pipe.submit(_counts())
        pipe.close()        # drains or cancels; must not hang or raise
        pipe.close()

    def test_submit_after_close_raises(self):
        pipe = PlanPipeline(_engine())
        pipe.close()
        with pytest.raises(RuntimeError, match="closed"):
            pipe.submit(_counts())

    def test_exit_after_exception_closes(self):
        pipe = PlanPipeline(_engine())
        with pytest.raises(ValueError, match="boom"):
            with pipe:
                pipe.submit(_counts())
                raise ValueError("boom")
        assert pipe._closed
        with pytest.raises(RuntimeError):
            pipe.submit(_counts())

    def test_injected_fault_inside_pipeline(self):
        eng = _engine()
        eng.observe([_skewed(), _skewed()])
        v = eng.placements_version
        with faults.injected(FaultInjector([Fault("planner_exception", 0)])):
            with PlanPipeline(eng) as pipe:
                pipe.submit(_counts(hot=5))
                ev = pipe.wait()
                assert not ev.ok and ev.failure == "planner_exception"
                assert eng.placements_version == v
                pipe.submit(_counts(hot=5))       # restarted worker
                assert pipe.wait().ok


# ---------------------------------------------------------------------------
# Transactional relocation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def reloc_setup():
    from repro.configs import get_config, reduced
    from repro.optim import adamw
    from repro.parallel import local_ctx
    from repro.train import Trainer
    cfg = reduced(get_config("moe-gpt-s"))
    tr = Trainer(cfg, local_ctx(), adamw(1e-3), attn_impl="naive",
                 remat=False)
    state = tr.init_state(jax.random.PRNGKey(0))
    E, L = cfg.moe.num_experts, cfg.num_moe_layers
    slot_of = np.arange(E)
    slot_of[0], slot_of[-1] = slot_of[-1], slot_of[0]
    gather = np.tile(np.argsort(slot_of).astype(np.int32), (L, 1))
    return cfg, state, gather


class TestTransactionalRelocation:
    def test_success_matches_plain_exchange(self, reloc_setup):
        from repro.train import relocate
        cfg, state, gather = reloc_setup
        plain = relocate.apply_relocation(
            state, cfg, gather,
            relocate_fn=relocate.make_relocate_fn(cfg, donate=False))
        tx, ok = relocate.apply_relocation_transactional(state, cfg, gather)
        assert ok
        for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(tx)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_identity_is_noop_success(self, reloc_setup):
        from repro.train import relocate
        cfg, state, _ = reloc_setup
        E, L = cfg.moe.num_experts, cfg.num_moe_layers
        ident = np.tile(np.arange(E, dtype=np.int32), (L, 1))
        out, ok = relocate.apply_relocation_transactional(state, cfg, ident)
        assert ok and out is state

    @pytest.mark.parametrize("mode", ["corrupt", "raise"])
    def test_injected_failure_rolls_back(self, reloc_setup, mode):
        from repro.train import relocate
        cfg, state, gather = reloc_setup
        before = [np.asarray(a) for a in jax.tree.leaves(state)]
        inj = FaultInjector([Fault("fail_relocation", 0, {"mode": mode})])
        with faults.injected(inj):
            out, ok = relocate.apply_relocation_transactional(state, cfg,
                                                              gather)
        assert not ok
        assert ("fail_relocation", 0) in inj.fired
        for a, b in zip(before, jax.tree.leaves(out)):
            np.testing.assert_array_equal(a, np.asarray(b))

    @staticmethod
    def _trainer_with_pending(reloc_setup, **trainer_kw):
        from repro.optim import adamw
        from repro.parallel import local_ctx
        from repro.train import Trainer
        cfg, state, _ = reloc_setup
        E, L = cfg.moe.num_experts, cfg.num_moe_layers
        ec = EngineConfig(num_experts=E, num_devices=1, num_moe_layers=L,
                          s_max=cfg.moe.s_max, enable_migration=True)
        eng = ProProphetEngine(ec, _hw())
        slot_of = list(range(E))
        slot_of[0], slot_of[1] = slot_of[1], slot_of[0]
        eng._placements[0] = ExpertPlacement(E, 1, {}, tuple(slot_of))
        eng._dirty.add(0)
        eng._version += 1
        assert eng.pending_relocation() is not None
        tr = Trainer(cfg, local_ctx(), adamw(1e-3), attn_impl="naive",
                     remat=False, engine=eng, **trainer_kw)
        return cfg, state, eng, tr

    def test_trainer_transient_failure_retries_once(self, reloc_setup):
        """One rolled-back exchange is transient: the plan survives, the
        dispatch holds the old arrays, and the next attempt succeeds."""
        cfg, state, eng, tr = self._trainer_with_pending(reloc_setup)
        before = [np.asarray(a) for a in jax.tree.leaves(state)]
        with faults.injected(FaultInjector(
                [Fault("fail_relocation", 0, {"mode": "corrupt"})])):
            out, reloc = tr._maybe_relocate(state)
        assert reloc.failures == 1 and reloc.retries == 1
        assert reloc.persistent == 0 and reloc.moved == 0
        assert tr._reloc_hold          # dispatch pins the old arrays
        assert eng.pending_relocation() is not None   # plan kept
        for a, b in zip(before, jax.tree.leaves(out)):
            np.testing.assert_array_equal(a, np.asarray(b))
        # Retry at the next dispatch succeeds (the fault fired once).
        out2, reloc2 = tr._maybe_relocate(out)
        assert reloc2.failures == 0 and not tr._reloc_hold
        assert eng.pending_relocation() is None

    def test_trainer_persistent_failure_cancels_migrations(self,
                                                           reloc_setup):
        """Two consecutive rollbacks are persistent: state untouched,
        device at home, planned migrations cancelled."""
        cfg, state, eng, tr = self._trainer_with_pending(reloc_setup)
        before = [np.asarray(a) for a in jax.tree.leaves(state)]
        with faults.injected(FaultInjector(
                [Fault("fail_relocation", 0, {"mode": "corrupt"}),
                 Fault("fail_relocation", 1, {"mode": "corrupt"})])):
            out, reloc = tr._maybe_relocate(state)
            assert reloc.retries == 1
            out, reloc = tr._maybe_relocate(out)
        assert reloc.moved == 0 and reloc.failures == 1
        assert reloc.persistent == 1 and reloc.retries == 0
        assert not tr._reloc_hold
        assert eng.pending_relocation() is None
        assert all(p.slot_of is None for p in eng.placements)
        for a, b in zip(before, jax.tree.leaves(out)):
            np.testing.assert_array_equal(a, np.asarray(b))

    def test_trainer_prefetch_stages_then_commits(self, reloc_setup):
        """Prefetch mode: first sighting holds + requests a stage, the
        post-dispatch stage issues the exchange, the next sighting
        commits the pre-staged slabs bit-identically to the synchronous
        exchange."""
        from repro.train import relocate
        cfg, state, eng, tr = self._trainer_with_pending(
            reloc_setup, reloc_prefetch=True)
        gather = eng.pending_relocation()
        expect = relocate.apply_relocation(
            state, cfg, gather,
            relocate_fn=relocate.make_relocate_fn(cfg, donate=False))
        out, reloc = tr._maybe_relocate(state)
        assert out is state and reloc.moved == 0    # held, nothing moved
        assert tr._reloc_hold and tr._want_stage is not None
        tr._maybe_stage(state)                       # "after the dispatch"
        assert tr._staged is not None
        out2, reloc2 = tr._maybe_relocate(state)
        assert reloc2.failures == 0 and not tr._reloc_hold
        assert eng.pending_relocation() is None
        for a, b in zip(jax.tree.leaves(expect), jax.tree.leaves(out2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_trainer_prefetch_faulted_stage_rolls_back(self, reloc_setup):
        """A fault injected at stage time surfaces at the commit exactly
        like the synchronous path: transient first, retry next."""
        cfg, state, eng, tr = self._trainer_with_pending(
            reloc_setup, reloc_prefetch=True)
        before = [np.asarray(a) for a in jax.tree.leaves(state)]
        with faults.injected(FaultInjector(
                [Fault("fail_relocation", 0, {"mode": "raise"})])):
            out, _ = tr._maybe_relocate(state)       # hold + request stage
            tr._maybe_stage(out)                     # fault fires here
            out, reloc = tr._maybe_relocate(out)     # commit → rollback
        assert reloc.failures == 1 and reloc.retries == 1
        assert eng.pending_relocation() is not None
        for a, b in zip(before, jax.tree.leaves(out)):
            np.testing.assert_array_equal(a, np.asarray(b))
        # Retry: stage cleanly, commit succeeds.
        tr._maybe_stage(out)
        out2, reloc2 = tr._maybe_relocate(out)
        assert reloc2.failures == 0 and eng.pending_relocation() is None


# ---------------------------------------------------------------------------
# Atomic, verifiable checkpoints
# ---------------------------------------------------------------------------

def _tree(seed=0, n=64):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (n,)),
            "b": {"inner": jnp.arange(n, dtype=jnp.int32)}}


class TestAtomicCheckpoint:
    def test_save_verify_restore(self, tmp_path):
        p = str(tmp_path / "ck")
        ckpt.save_train_state(_tree(), p, step=7, extra={"tag": "x"})
        ok, reason = ckpt.verify_checkpoint(p)
        assert ok, reason
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree())
        state, meta = ckpt.restore_train_state(like, p)
        assert meta["step"] == 7 and meta["tag"] == "x"
        assert "digest" in meta
        for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_no_temp_dirs_left_behind(self, tmp_path):
        root = str(tmp_path)
        ckpt.save_checkpoint(_tree(), root, step=1)
        assert not [n for n in os.listdir(root) if n.startswith(".tmp-")]

    def test_overwrite_is_atomic(self, tmp_path):
        p = str(tmp_path / "ck")
        ckpt.save_train_state(_tree(0), p, step=1)
        ckpt.save_train_state(_tree(1), p, step=2)
        ok, _ = ckpt.verify_checkpoint(p)
        assert ok
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree())
        _, meta = ckpt.restore_train_state(like, p)
        assert meta["step"] == 2

    def test_retention_prunes(self, tmp_path):
        root = str(tmp_path)
        for s in range(1, 6):
            ckpt.save_checkpoint(_tree(s), root, step=s, keep=2)
        assert [s for s, _ in ckpt.list_checkpoints(root)] == [4, 5]

    def test_detects_bit_rot(self, tmp_path):
        p = str(tmp_path / "ck")
        ckpt.save_train_state(_tree(), p, step=1)
        sf = os.path.join(p, "state.npz")
        data = bytearray(open(sf, "rb").read())
        data[len(data) // 2] ^= 0xFF
        open(sf, "wb").write(bytes(data))
        ok, reason = ckpt.verify_checkpoint(p)
        assert not ok and "digest" in reason

    def test_torn_truncate_detected_and_skipped(self, tmp_path):
        root = str(tmp_path)
        ckpt.save_checkpoint(_tree(0), root, step=3)
        with faults.injected(FaultInjector(
                [Fault("torn_checkpoint", 0, {"mode": "truncate"})])):
            ckpt.save_checkpoint(_tree(1), root, step=6)
        ok, reason = ckpt.verify_checkpoint(
            os.path.join(root, "step-00000006"))
        assert not ok
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree())
        _, meta, path = ckpt.restore_latest(like, root)
        assert meta["step"] == 3 and path.endswith("step-00000003")

    def test_torn_abort_never_published(self, tmp_path):
        root = str(tmp_path)
        ckpt.save_checkpoint(_tree(0), root, step=3)
        with faults.injected(FaultInjector(
                [Fault("torn_checkpoint", 0, {"mode": "abort"})])):
            ckpt.save_checkpoint(_tree(1), root, step=6)
        assert [s for s, _ in ckpt.list_checkpoints(root)] == [3]
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree())
        _, meta, _ = ckpt.restore_latest(like, root)
        assert meta["step"] == 3

    def test_restore_latest_empty_root_raises(self, tmp_path):
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree())
        with pytest.raises(ckpt.CheckpointError, match="no intact"):
            ckpt.restore_latest(like, str(tmp_path / "nowhere"))

    def test_unreadable_meta_skipped(self, tmp_path):
        root = str(tmp_path)
        ckpt.save_checkpoint(_tree(0), root, step=1)
        ckpt.save_checkpoint(_tree(1), root, step=2)
        with open(os.path.join(root, "step-00000002", "meta.json"),
                  "w") as f:
            f.write("{not json")
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree())
        _, meta, _ = ckpt.restore_latest(like, root)
        assert meta["step"] == 1


class TestLoadPytreeErrors:
    def test_missing_leaf_names_keypath(self, tmp_path):
        p = str(tmp_path / "t.npz")
        ckpt.save_pytree({"a": jnp.ones((2,))}, p)
        like = {"a": jax.ShapeDtypeStruct((2,), jnp.float32),
                "missing": jax.ShapeDtypeStruct((2,), jnp.float32)}
        with pytest.raises(ckpt.CheckpointError, match="missing"):
            ckpt.load_pytree(like, p)

    def test_shape_mismatch_names_keypath(self, tmp_path):
        p = str(tmp_path / "t.npz")
        ckpt.save_pytree({"a": {"b": jnp.ones((2, 3))}}, p)
        like = {"a": {"b": jax.ShapeDtypeStruct((3, 2), jnp.float32)}}
        with pytest.raises(ckpt.CheckpointError, match=r"a::b.*shape"):
            ckpt.load_pytree(like, p)

    def test_dtype_mismatch_rejected(self, tmp_path):
        p = str(tmp_path / "t.npz")
        ckpt.save_pytree({"a": jnp.ones((4,), jnp.float32)}, p)
        like = {"a": jax.ShapeDtypeStruct((4,), jnp.int32)}
        with pytest.raises(ckpt.CheckpointError, match="dtype"):
            ckpt.load_pytree(like, p)

    def test_bf16_requires_bf16_target(self, tmp_path):
        p = str(tmp_path / "t.npz")
        ckpt.save_pytree({"a": jnp.ones((4,), jnp.bfloat16)}, p)
        with pytest.raises(ckpt.CheckpointError, match="bfloat16"):
            ckpt.load_pytree({"a": jax.ShapeDtypeStruct((4,), jnp.float32)},
                             p)
        back = ckpt.load_pytree(
            {"a": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}, p)
        assert back["a"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Fault injector determinism
# ---------------------------------------------------------------------------

class TestFaultInjector:
    def test_schedule_and_log(self):
        inj = FaultInjector([Fault("planner_exception", 1)])
        inj.planner_fault()                       # occurrence 0: clean
        with pytest.raises(faults.InjectedFault):
            inj.planner_fault()                   # occurrence 1: fires
        inj.planner_fault()                       # occurrence 2: clean
        assert inj.fired == [("planner_exception", 1)]

    def test_corruption_deterministic(self):
        c = _counts()
        a = FaultInjector([Fault("corrupt_counts", 0)],
                          seed=3).corrupt_counts(c)
        b = FaultInjector([Fault("corrupt_counts", 0)],
                          seed=3).corrupt_counts(c)
        np.testing.assert_array_equal(np.isnan(a), np.isnan(b))
        assert not np.array_equal(a, c) or not np.isfinite(a).all()

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            Fault("cosmic_ray", 0)

    def test_install_scoping(self):
        assert faults.active() is None
        inj = FaultInjector([])
        with faults.injected(inj):
            assert faults.active() is inj
        assert faults.active() is None


# ---------------------------------------------------------------------------
# End-to-end acceptance: faulted 12-step run ≡ fault-free, bit-identical
# ---------------------------------------------------------------------------

class TestResilienceEndToEnd:
    def _forced_swap_engine(self, cfg, ctx, at_obs=6):
        """Real engine whose observe force-plans an expert swap on layer 0
        at the ``at_obs``-th observation — a deterministic migration on a
        1-device mesh (the planner alone won't migrate there)."""
        from repro.train.trainer import make_engine_for
        eng = make_engine_for(cfg, ctx, migration=True)
        E = cfg.moe.num_experts
        orig = eng.observe

        def observe(per_layer_g, pool=None):
            orig(per_layer_g, pool=pool)
            if eng._obs_count == at_obs:
                slot_of = list(range(E))
                slot_of[0], slot_of[-1] = slot_of[-1], slot_of[0]
                pl = ExpertPlacement(E, 1, {}, tuple(slot_of))
                if eng._placements[0] != pl:
                    eng._placements[0] = pl
                    eng._dirty.add(0)
                    eng._version += 1
        eng.observe = observe
        return eng

    def _run(self, steps, ckpt_root, injector, monkeypatch):
        from repro.configs import get_config, reduced
        from repro.data import SyntheticLM
        from repro.optim import adamw, cosine
        from repro.parallel import local_ctx
        from repro.train import Trainer
        # K=1 chunks: K>1 changes backward reduction order, and this test
        # is about bit-identity under faults, not chunking.
        monkeypatch.setenv("REPRO_A2A_CHUNKS", "1")
        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        eng = self._forced_swap_engine(cfg, ctx)
        # clip_norm=None: global-norm clipping breaks exact permutation
        # equivariance of the relocated optimizer step.
        tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 2, steps),
                                     clip_norm=None),
                     attn_impl="naive", remat=False, engine=eng,
                     async_plan=True)
        state = tr.init_state(jax.random.PRNGKey(0))
        data = SyntheticLM(cfg, batch=2, seq=16)
        sink, tel = [], OverlapTelemetry()
        if injector is not None:
            with faults.injected(injector):
                state, hist = tr.run(state, data, num_steps=steps,
                                     log_every=0, stats_sink=sink,
                                     telemetry=tel, ckpt_dir=ckpt_root,
                                     ckpt_every=3, ckpt_keep=3)
        else:
            state, hist = tr.run(state, data, num_steps=steps, log_every=0,
                                 stats_sink=sink, telemetry=tel,
                                 ckpt_dir=ckpt_root, ckpt_every=3,
                                 ckpt_keep=3)
        return state, hist, sink, tel

    def test_faulted_run_bit_identical_and_recoverable(self, tmp_path,
                                                       monkeypatch):
        steps = 12
        inj = FaultInjector([
            Fault("planner_exception", 3),
            Fault("corrupt_counts", 5, {"mode": "mixed"}),
            Fault("fail_relocation", 0, {"mode": "corrupt"}),
            Fault("torn_checkpoint", 2, {"mode": "truncate"}),
        ], seed=0)
        clean_root = str(tmp_path / "clean")
        fault_root = str(tmp_path / "faulted")
        _, hist_clean, _, _ = self._run(steps, clean_root, None, monkeypatch)
        state, hist_fault, sink, tel = self._run(steps, fault_root, inj,
                                                 monkeypatch)

        # 1. every scheduled fault actually fired
        fired = {k for k, _ in inj.fired}
        assert fired == {"planner_exception", "corrupt_counts",
                         "fail_relocation", "torn_checkpoint"}

        # 2. loss trajectory is bit-identical to the fault-free run
        assert hist_fault == hist_clean

        # 3. telemetry recorded ≥1 fallback per fault class
        assert tel.fault_fallbacks.get("planner_exception", 0) >= 1
        assert tel.fault_fallbacks.get("relocation", 0) >= 1
        assert tel.sanitized_counts >= 1
        assert tel.fallbacks >= 2
        s = tel.summary()
        assert s["plan_failures"] >= 1 and s["relocation_failures"] >= 1

        # 4. the torn step-9 checkpoint is detected; restore_latest
        #    recovers the last intact one (step 6)
        saved = [st for st, _ in ckpt.list_checkpoints(fault_root)]
        assert 9 in saved
        ok, _ = ckpt.verify_checkpoint(
            os.path.join(fault_root, "step-00000009"))
        assert not ok
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.asarray(x).shape,
                                           np.asarray(x).dtype), state)
        _, meta, path = ckpt.restore_latest(like, fault_root)
        assert meta["step"] == 6
        assert meta["expert_layout"] == "home"

        # 5. the fault-free root's step-9 checkpoint is intact
        ok, reason = ckpt.verify_checkpoint(
            os.path.join(clean_root, "step-00000009"))
        assert ok, reason
