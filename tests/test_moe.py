"""MoE dispatch/combine correctness + router + shadow-path invariants
(single device; the multi-device equivalence lives in test_distributed)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback shim — see requirements-dev.txt
    from _hypothesis_compat import given, settings, strategies as st

from repro.models import moe
from repro.parallel import local_ctx

KEY = jax.random.PRNGKey(0)


class TestDispatch:
    @given(st.integers(1, 40), st.integers(1, 3), st.integers(2, 8),
           st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_positions_are_dense_ranks(self, n, k, e, seed):
        rng = np.random.default_rng(seed)
        expert = jnp.asarray(rng.integers(0, e, size=(n * k,)), jnp.int32)
        pos = np.asarray(moe.capacity_positions(expert, e))
        for b in range(e):
            sel = pos[np.asarray(expert) == b]
            assert sorted(sel.tolist()) == list(range(len(sel)))

    def test_dispatch_combine_roundtrip(self):
        """With no drops, dispatch→identity-experts→combine == gate-sum."""
        n, k, e, d, cap = 16, 2, 4, 8, 32
        x = jax.random.normal(KEY, (n, d))
        expert = jax.random.randint(jax.random.PRNGKey(1), (n, k), 0, e)
        gate = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (n, k)))
        buf, pos = moe.capacity_dispatch(x, expert, cap, e)
        y = moe.capacity_combine(buf, expert, pos, gate)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(x * gate.sum(-1, keepdims=True)),
                                   rtol=1e-5, atol=1e-6)

    def test_capacity_drop(self):
        n, d = 8, 4
        x = jnp.ones((n, d))
        expert = jnp.zeros((n, 1), jnp.int32)       # all to expert 0
        gate = jnp.ones((n, 1))
        buf, pos = moe.capacity_dispatch(x, expert, 4, 2)
        assert float(buf[0].sum()) == 4 * d          # only 4 kept
        y = moe.capacity_combine(buf, expert, pos, gate)
        assert float((y.sum(-1) > 0).sum()) == 4     # dropped → zero output

    def test_sentinel_bucket_dropped(self):
        n, d, e = 6, 4, 3
        x = jnp.ones((n, d))
        expert = jnp.full((n, 1), e, jnp.int32)      # sentinel == e
        buf, pos = moe.capacity_dispatch(x, expert, 8, e + 1)
        assert float(buf[:e].sum()) == 0


class TestRouter:
    def test_topk_renormalized(self):
        p = moe.router_init(KEY, 16, 8)
        x = jax.random.normal(jax.random.PRNGKey(3), (5, 16))
        gate, idx, probs = moe.router_topk(p, x, 2)
        np.testing.assert_allclose(np.asarray(gate.sum(-1)), 1.0, rtol=1e-5)
        assert idx.shape == (5, 2) and probs.shape == (5, 8)

    def test_load_balance_loss_uniform_is_one(self):
        probs = jnp.full((100, 4), 0.25)
        idx = jnp.tile(jnp.arange(4), 25)[:, None]
        lb = moe.load_balance_loss(probs, idx, 4)
        assert float(lb) == pytest.approx(1.0, rel=1e-5)


class TestShadowInvariance:
    """Shadowing must change WHERE compute happens, never the math."""

    def _setup(self, e=4, k=2, n=32, d=16, f=32, s_max=2):
        ks = jax.random.split(KEY, 3)
        params = moe.moe_init(ks[0], d, f, e, ffn_kind="swiglu")
        x = 0.5 * jax.random.normal(ks[1], (2, n // 2, d))
        return params, x

    def _apply(self, params, x, placement, s_max=2, e=4):
        ctx = local_ctx()
        y, aux = moe.moe_apply(params, x, placement, ctx, num_experts=e,
                               top_k=2, d_expert=32, ffn_kind="swiglu",
                               capacity_factor=float(e),
                               shadow_capacity_factor=4.0, s_max=s_max)
        return y, aux

    def test_shadow_noop_numerics(self):
        params, x = self._setup()
        y0, aux0 = self._apply(params, x, None)
        placement = {
            "shadow_idx": jnp.array([1, 4], jnp.int32),
            "shadow_valid": jnp.array([1.0, 0.0], jnp.float32),
            "shadow_devs": jnp.array([[1.0], [0.0]], jnp.float32),
        }
        y1, aux1 = self._apply(params, x, placement)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(aux0["counts"]),
                                      np.asarray(aux1["counts"]))

    def test_gradients_match_with_shadow(self):
        params, x = self._setup()
        placement = {
            "shadow_idx": jnp.array([0, 4], jnp.int32),
            "shadow_valid": jnp.array([1.0, 0.0], jnp.float32),
            "shadow_devs": jnp.array([[1.0], [0.0]], jnp.float32),
        }

        def loss(p, pl):
            y, _ = self._apply(p, x, pl)
            return jnp.sum(y ** 2)

        g0 = jax.grad(loss)(params, None)
        g1 = jax.grad(loss)(params, placement)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=1e-5)

    def test_counts_reported(self):
        params, x = self._setup()
        _, aux = self._apply(params, x, None)
        counts = np.asarray(aux["counts"])
        assert counts.shape == (1, 4)
        assert counts.sum() == x.shape[0] * x.shape[1] * 2  # n tokens × k

    def test_shared_expert(self):
        ks = jax.random.split(KEY, 2)
        params = moe.moe_init(ks[0], 16, 32, 4, ffn_kind="swiglu",
                              num_shared=1, shared_d_ff=32)
        assert "shared" in params
        x = 0.5 * jax.random.normal(ks[1], (2, 8, 16))
        y, _ = self._apply(params, x, None)
        assert y.shape == x.shape


class TestChunkedA2aPipeline:
    """Chunked a2a↔FEC software pipeline (single device; the mesh run
    lives in tests/dist/chunked_equivalence.py).  Chunking only re-tiles
    the capacity axis — per-token math is untouched — so the forward is
    bit-identical for every K and the backward matches to summation
    round-off (per-chunk dw partials accumulate in a different order)."""

    E, D, F = 4, 16, 32

    def _setup(self, seed=0, skew=2.0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        params = moe.moe_init(ks[0], self.D, self.F, self.E,
                              ffn_kind="swiglu")
        # router bias ⇒ skewed loads, so chunks have ragged occupancy
        params["router"]["w"] = (params["router"]["w"]
                                 + skew * jax.random.normal(ks[2], (self.E,)))
        x = 0.5 * jax.random.normal(ks[1], (2, 16, self.D))
        return params, x

    def _placement(self):
        return {
            "shadow_idx": jnp.array([1, self.E], jnp.int32),
            "shadow_valid": jnp.array([1.0, 0.0], jnp.float32),
            "shadow_devs": jnp.array([[1.0], [0.0]], jnp.float32),
        }

    def _run(self, params, x, placement, k):
        ctx = local_ctx()
        kw = dict(num_experts=self.E, top_k=2, d_expert=self.F,
                  ffn_kind="swiglu", capacity_factor=2.0,
                  shadow_capacity_factor=4.0, s_max=2, a2a_chunks=k)
        y, aux = moe.moe_apply(params, x, placement, ctx, **kw)

        def loss(p):
            yy, _ = moe.moe_apply(p, x, placement, ctx, **kw)
            return jnp.sum(yy ** 2)

        return y, aux, jax.grad(loss)(params)

    @pytest.mark.parametrize("k", [2, 3, 4])
    @pytest.mark.parametrize("shadowed", [False, True])
    def test_chunked_equivalent_to_serial(self, k, shadowed):
        params, x = self._setup()
        pl = self._placement() if shadowed else None
        y1, aux1, g1 = self._run(params, x, pl, 1)
        yk, auxk, gk = self._run(params, x, pl, k)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(yk))
        np.testing.assert_array_equal(np.asarray(aux1["counts"]),
                                      np.asarray(auxk["counts"]))
        assert float(aux1["dropped"]) == float(auxk["dropped"])
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gk)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_flag_overrides_chunk_count(self, monkeypatch):
        params, x = self._setup()
        y1, _, _ = self._run(params, x, None, 1)
        monkeypatch.setenv("REPRO_A2A_CHUNKS", "3")
        y3, _, _ = self._run(params, x, None, 1)   # flag wins over the arg
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y3))

    def test_chunk_bounds(self):
        assert moe._chunk_bounds(8, 1) == [(0, 8)]
        assert moe._chunk_bounds(8, 2) == [(0, 4), (4, 8)]
        assert moe._chunk_bounds(3, 8) == [(0, 1), (1, 2), (2, 3)]
        # exactly min(K, capacity) chunks, every row covered exactly
        # once, balanced sizes (differ by ≤ 1 row) — the device runs the
        # K the chooser scored
        for cap, k in [(17, 4), (5, 5), (9, 2), (9, 8), (8, 3)]:
            b = moe._chunk_bounds(cap, k)
            assert len(b) == min(k, cap)
            assert b[0][0] == 0 and b[-1][1] == cap
            assert all(x[1] == y[0] for x, y in zip(b, b[1:]))
            sizes = [hi - lo for lo, hi in b]
            assert max(sizes) - min(sizes) <= 1

    def test_chunk_occupancy_prefix_semantics(self):
        from repro.kernels.ragged_gmm import chunk_occupancy
        counts = jnp.array([0, 3, 5, 8], jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(chunk_occupancy(counts, 0, 4)), [0, 3, 4, 4])
        np.testing.assert_array_equal(
            np.asarray(chunk_occupancy(counts, 4, 8)), [0, 0, 1, 4])
