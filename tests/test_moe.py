"""MoE dispatch/combine correctness + router + shadow-path invariants
(single device; the multi-device equivalence lives in test_distributed)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback shim — see requirements-dev.txt
    from _hypothesis_compat import given, settings, strategies as st

from repro.models import moe
from repro.parallel import local_ctx

KEY = jax.random.PRNGKey(0)


class TestDispatch:
    @given(st.integers(1, 40), st.integers(1, 3), st.integers(2, 8),
           st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_positions_are_dense_ranks(self, n, k, e, seed):
        rng = np.random.default_rng(seed)
        expert = jnp.asarray(rng.integers(0, e, size=(n * k,)), jnp.int32)
        pos = np.asarray(moe.capacity_positions(expert, e))
        for b in range(e):
            sel = pos[np.asarray(expert) == b]
            assert sorted(sel.tolist()) == list(range(len(sel)))

    def test_dispatch_combine_roundtrip(self):
        """With no drops, dispatch→identity-experts→combine == gate-sum."""
        n, k, e, d, cap = 16, 2, 4, 8, 32
        x = jax.random.normal(KEY, (n, d))
        expert = jax.random.randint(jax.random.PRNGKey(1), (n, k), 0, e)
        gate = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (n, k)))
        buf, pos = moe.capacity_dispatch(x, expert, cap, e)
        y = moe.capacity_combine(buf, expert, pos, gate)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(x * gate.sum(-1, keepdims=True)),
                                   rtol=1e-5, atol=1e-6)

    def test_capacity_drop(self):
        n, d = 8, 4
        x = jnp.ones((n, d))
        expert = jnp.zeros((n, 1), jnp.int32)       # all to expert 0
        gate = jnp.ones((n, 1))
        buf, pos = moe.capacity_dispatch(x, expert, 4, 2)
        assert float(buf[0].sum()) == 4 * d          # only 4 kept
        y = moe.capacity_combine(buf, expert, pos, gate)
        assert float((y.sum(-1) > 0).sum()) == 4     # dropped → zero output

    def test_sentinel_bucket_dropped(self):
        n, d, e = 6, 4, 3
        x = jnp.ones((n, d))
        expert = jnp.full((n, 1), e, jnp.int32)      # sentinel == e
        buf, pos = moe.capacity_dispatch(x, expert, 8, e + 1)
        assert float(buf[:e].sum()) == 0


class TestRouter:
    def test_topk_renormalized(self):
        p = moe.router_init(KEY, 16, 8)
        x = jax.random.normal(jax.random.PRNGKey(3), (5, 16))
        gate, idx, probs = moe.router_topk(p, x, 2)
        np.testing.assert_allclose(np.asarray(gate.sum(-1)), 1.0, rtol=1e-5)
        assert idx.shape == (5, 2) and probs.shape == (5, 8)

    def test_load_balance_loss_uniform_is_one(self):
        probs = jnp.full((100, 4), 0.25)
        idx = jnp.tile(jnp.arange(4), 25)[:, None]
        lb = moe.load_balance_loss(probs, idx, 4)
        assert float(lb) == pytest.approx(1.0, rel=1e-5)


class TestShadowInvariance:
    """Shadowing must change WHERE compute happens, never the math."""

    def _setup(self, e=4, k=2, n=32, d=16, f=32, s_max=2):
        ks = jax.random.split(KEY, 3)
        params = moe.moe_init(ks[0], d, f, e, ffn_kind="swiglu")
        x = 0.5 * jax.random.normal(ks[1], (2, n // 2, d))
        return params, x

    def _apply(self, params, x, placement, s_max=2, e=4):
        ctx = local_ctx()
        y, aux = moe.moe_apply(params, x, placement, ctx, num_experts=e,
                               top_k=2, d_expert=32, ffn_kind="swiglu",
                               capacity_factor=float(e),
                               shadow_capacity_factor=4.0, s_max=s_max)
        return y, aux

    def test_shadow_noop_numerics(self):
        params, x = self._setup()
        y0, aux0 = self._apply(params, x, None)
        placement = {
            "shadow_idx": jnp.array([1, 4], jnp.int32),
            "shadow_valid": jnp.array([1.0, 0.0], jnp.float32),
            "shadow_devs": jnp.array([[1.0], [0.0]], jnp.float32),
        }
        y1, aux1 = self._apply(params, x, placement)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(aux0["counts"]),
                                      np.asarray(aux1["counts"]))

    def test_gradients_match_with_shadow(self):
        params, x = self._setup()
        placement = {
            "shadow_idx": jnp.array([0, 4], jnp.int32),
            "shadow_valid": jnp.array([1.0, 0.0], jnp.float32),
            "shadow_devs": jnp.array([[1.0], [0.0]], jnp.float32),
        }

        def loss(p, pl):
            y, _ = self._apply(p, x, pl)
            return jnp.sum(y ** 2)

        g0 = jax.grad(loss)(params, None)
        g1 = jax.grad(loss)(params, placement)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=1e-5)

    def test_counts_reported(self):
        params, x = self._setup()
        _, aux = self._apply(params, x, None)
        counts = np.asarray(aux["counts"])
        assert counts.shape == (1, 4)
        assert counts.sum() == x.shape[0] * x.shape[1] * 2  # n tokens × k

    def test_shared_expert(self):
        ks = jax.random.split(KEY, 2)
        params = moe.moe_init(ks[0], 16, 32, 4, ffn_kind="swiglu",
                              num_shared=1, shared_d_ff=32)
        assert "shared" in params
        x = 0.5 * jax.random.normal(ks[1], (2, 8, 16))
        y, _ = self._apply(params, x, None)
        assert y.shape == x.shape
