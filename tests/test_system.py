"""End-to-end system behaviour: the trainer learns, Pro-Prophet engages
under induced imbalance, checkpoints restore exactly."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_train_state, save_train_state
from repro.configs import get_config, reduced
from repro.data import SyntheticLM
from repro.models import model as M
from repro.optim import adamw, cosine
from repro.parallel import local_ctx
from repro.train import Trainer, decode_tokens, make_serve_step, prefill
from repro.train.trainer import TrainState, make_engine_for


@pytest.mark.slow
def test_training_decreases_loss_moe_gpt():
    """The paper's MoE-GPT-S family (reduced) learns on the synthetic LM
    (long end-to-end trainer run — the fast lane covers the same loop via
    tests/test_async_runtime.py's 22-step equivalence runs)."""
    cfg = reduced(get_config("moe-gpt-s"))
    ctx = local_ctx()
    tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 10, 200)), attn_impl="naive",
                 remat=False, engine=make_engine_for(cfg, ctx))
    state = tr.init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=8, seq=64)
    state, hist = tr.run(state, data, num_steps=30, log_every=0)
    assert hist[-1] < hist[0] - 0.2, hist[::10]


def test_engine_observes_and_plans():
    cfg = reduced(get_config("qwen3-moe-235b-a22b"))
    ctx = local_ctx()
    eng = make_engine_for(cfg, ctx)
    tr = Trainer(cfg, ctx, adamw(1e-3), attn_impl="naive", remat=False,
                 engine=eng)
    state = tr.init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=4, seq=32)
    tr.run(state, data, num_steps=3, log_every=0)
    # the engine saw 3 routing matrices per layer
    assert eng.planners[0].tracker.latest is not None
    assert eng.planners[0].tracker.latest.sum() == 4 * 32 * cfg.moe.top_k


def test_checkpoint_roundtrip_training_state(tmp_path):
    cfg = reduced(get_config("smollm-360m"))
    ctx = local_ctx()
    opt = adamw(1e-3)
    tr = Trainer(cfg, ctx, opt, attn_impl="naive", remat=False)
    state = tr.init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=4, seq=32)
    state, _ = tr.run(state, data, num_steps=2, log_every=0)
    save_train_state(state, str(tmp_path / "ck"), step=2)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored, meta = restore_train_state(like, str(tmp_path / "ck"))
    assert meta["step"] == 2
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # restored state continues training bit-identically
    b3 = {k: jnp.asarray(v) for k, v in data.at_step(2).items()}
    s1, m1 = tr._step_fn(state, b3, None)
    s2, m2 = tr._step_fn(restored, b3, None)
    assert float(m1["loss"]) == float(m2["loss"])


def test_generation_is_deterministic_and_cache_consistent():
    cfg = reduced(get_config("smollm-360m"))
    ctx = local_ctx()
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    ss = make_serve_step(cfg, ctx)
    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 6)),
        jnp.int32)

    def gen():
        caches = M.init_cache(cfg, batch=2, max_len=32)
        logits, caches = prefill(params, caches, prompt, cfg, ctx,
                                 serve_step=ss)
        toks, _ = decode_tokens(params, caches, logits, 6, 8, cfg, ctx,
                                serve_step=ss)
        return np.asarray(toks)

    t1, t2 = gen(), gen()
    np.testing.assert_array_equal(t1, t2)
    assert t1.shape == (2, 8)


def test_decode_matches_forward_full_model():
    """Teacher-forced decode logits == full-forward logits at every
    position (whole-model cache consistency)."""
    cfg = reduced(get_config("qwen2-1.5b"))
    ctx = local_ctx()
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    S = 10
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (1, S)),
        jnp.int32)
    fwd_logits, _ = M.forward(params, toks, cfg, ctx, attn_impl="naive",
                              remat=False)
    caches = M.init_cache(cfg, batch=1, max_len=S)
    ss = make_serve_step(cfg, ctx)
    dec = []
    for t in range(S):
        lg, caches = ss(params, caches, toks[:, t:t + 1],
                        jnp.asarray(t, jnp.int32))
        dec.append(lg)
    dec_logits = jnp.concatenate(dec, axis=1)
    np.testing.assert_allclose(np.asarray(fwd_logits), np.asarray(dec_logits),
                               rtol=2e-3, atol=2e-3)
