"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family (≤2–3 layers via pattern prefix, d_model ≤ 512, ≤4 experts)
runs one forward + one train step on CPU; output shapes asserted, no NaNs.
Decode-capable archs also run one serve step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.data import synthetic_batch
from repro.models import model as M
from repro.optim import adamw, constant
from repro.parallel import local_ctx
from repro.train import make_serve_step, make_train_step
from repro.train.trainer import TrainState

ASSIGNED = [
    "paligemma-3b", "jamba-v0.1-52b", "xlstm-350m", "qwen3-moe-235b-a22b",
    "minicpm-2b", "gemma3-27b", "smollm-360m", "hubert-xlarge",
    "qwen2-1.5b", "deepseek-v3-671b",
]


def _check_reduced(cfg):
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 8
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("name", ASSIGNED)
def test_full_config_registered(name):
    cfg = get_config(name)
    assert cfg.source
    assert cfg.param_count() > 0
    assert cfg.num_layers >= 18 or cfg.arch_type in ("ssm",) or \
        cfg.num_layers >= 24


# Exact dims from the assignment table.
EXPECT = {
    "paligemma-3b": dict(L=18, d=2048, H=8, kv=1, ff=16384, V=257216),
    "jamba-v0.1-52b": dict(L=32, d=4096, H=32, kv=8, ff=14336, V=65536),
    "xlstm-350m": dict(L=24, d=1024, H=4, kv=4, ff=0, V=50304),
    "qwen3-moe-235b-a22b": dict(L=94, d=4096, H=64, kv=4, ff=1536, V=151936),
    "minicpm-2b": dict(L=40, d=2304, H=36, kv=36, ff=5760, V=122753),
    "gemma3-27b": dict(L=62, d=5376, H=32, kv=16, ff=21504, V=262144),
    "smollm-360m": dict(L=32, d=960, H=15, kv=5, ff=2560, V=49152),
    "hubert-xlarge": dict(L=48, d=1280, H=16, kv=16, ff=5120, V=504),
    "qwen2-1.5b": dict(L=28, d=1536, H=12, kv=2, ff=8960, V=151936),
    "deepseek-v3-671b": dict(L=61, d=7168, H=128, kv=128, ff=2048, V=129280),
}


@pytest.mark.parametrize("name", ASSIGNED)
def test_exact_assigned_dims(name):
    cfg = get_config(name)
    e = EXPECT[name]
    assert cfg.num_layers == e["L"]
    assert cfg.d_model == e["d"]
    assert cfg.num_heads == e["H"]
    assert cfg.num_kv_heads == e["kv"]
    assert cfg.vocab_size == e["V"]
    ff = cfg.moe.d_expert if cfg.moe else cfg.d_ff
    assert ff == e["ff"]


@pytest.mark.parametrize("name", ASSIGNED)
def test_smoke_forward_and_train_step(name):
    cfg = reduced(get_config(name))
    _check_reduced(cfg)
    ctx = local_ctx()
    B, S = 2, 32
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_batch(cfg, B, S, step=0, seed=0).items()}
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    logits, aux = M.forward(params, batch.get("tokens"), cfg, ctx,
                            attn_impl="naive",
                            prefix_embeds=batch.get("prefix_embeds"),
                            frame_embeds=batch.get("frame_embeds"),
                            remat=False)
    exp_seq = S + (cfg.num_prefix_tokens if cfg.modality == "vlm" else 0)
    assert logits.shape == (B, exp_seq, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))

    optimizer = adamw(constant(1e-3))
    step = make_train_step(cfg, ctx, optimizer, attn_impl="naive",
                           remat=False, donate=False)
    state = TrainState(params, optimizer.init(params))
    state2, metrics = step(state, batch, None)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    diffs = [float(jnp.abs(a - b).max()) for a, b in
             zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params))]
    assert max(diffs) > 0


DECODE_ARCHS = [a for a in ASSIGNED if a != "hubert-xlarge"
                and a != "paligemma-3b"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_smoke_decode_step(name):
    cfg = reduced(get_config(name))
    ctx = local_ctx()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    caches = M.init_cache(cfg, batch=2, max_len=16)
    ss = make_serve_step(cfg, ctx)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, caches = ss(params, caches, tok, jnp.asarray(0, jnp.int32))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_hubert_has_no_decode():
    cfg = get_config("hubert-xlarge")
    assert not cfg.supports_decode


def test_long_context_eligibility():
    """DESIGN.md §5: only sub-quadratic archs run long_500k."""
    assert get_config("xlstm-350m").sub_quadratic
    assert get_config("jamba-v0.1-52b").sub_quadratic
    assert not get_config("qwen2-1.5b").sub_quadratic
    assert not get_config("deepseek-v3-671b").sub_quadratic
