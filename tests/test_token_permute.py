"""Token-permutation kernel invariants (repro.kernels.token_permute).

* capacity_positions: exact equality of the histogram-rank formulation
  to the old argsort+searchsorted oracle (the micro-opt must be a pure
  strength reduction).
* dispatch_tokens: bit-exact vs the jnp scatter path and the ref oracle
  (pure data movement), over-capacity drops, sentinel buckets, weighted
  scatter.
* combine_tokens: matches the ordered-f32 oracle (bit-exact at k = 1;
  ≤ ulp-per-add FP-contraction slack at k > 1), drop accounting.
* custom VJPs: dispatch/combine grads vs autodiff of the jnp path,
  including the gate cotangent (segment-sum) and the round trip.
* property suite (hypothesis, or the deterministic fallback shim):
  round-trip identity under capacity headroom, drop accounting at
  over-capacity, sentinel handling, grad-flow equivalence of the
  Pallas vs jnp paths.
* moe_apply REPRO_DISPATCH_PALLAS on/off equivalence for K ∈ {1, 2, 4}
  chunks (the mesh version lives in tests/dist/dispatch_equivalence.py).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback shim — see requirements-dev.txt
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.token_permute import (combine_modeled_bytes,
                                         dispatch_modeled_bytes)
from repro.models import moe
from repro.parallel import local_ctx

KEY = jax.random.PRNGKey(0)


def _capacity_positions_sorted(expert, num_buckets):
    """The pre-optimization implementation (argsort + searchsorted +
    scatter) — kept verbatim as the oracle the cumsum'd-histogram
    version must reproduce exactly."""
    nk = expert.shape[0]
    order = jnp.argsort(expert, stable=True)
    sorted_e = expert[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - first.astype(jnp.int32)
    return jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted)


def _case(seed, n, k, g, c, d, dtype=jnp.float32, sentinel=True):
    """Random (x, expert, pos, gate) with positions from the real layout
    (so (bucket, pos) pairs are unique, like the model produces)."""
    hi = g + 1 if sentinel else g
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d), dtype)
    expert = jax.random.randint(jax.random.PRNGKey(seed + 100), (n, k),
                                0, hi)
    pos = moe.capacity_positions(expert.reshape(-1), hi).reshape(n, k)
    gate = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(seed + 200), (n, k)))
    return x, expert, pos, gate


class TestCapacityPositions:
    @given(st.integers(1, 60), st.integers(1, 3), st.integers(1, 9),
           st.integers(0, 1000))
    @settings(max_examples=25, deadline=None)
    def test_matches_sorted_oracle_exactly(self, n, k, buckets, seed):
        """Histogram ranks ≡ the old two-pass sort formulation, bit for
        bit — including the sentinel id == num_buckets."""
        rng = np.random.default_rng(seed)
        expert = jnp.asarray(rng.integers(0, buckets + 1, size=(n * k,)),
                             jnp.int32)
        got = np.asarray(moe.capacity_positions(expert, buckets))
        want = np.asarray(_capacity_positions_sorted(expert, buckets))
        np.testing.assert_array_equal(got, want)

    def test_single_bucket_is_arange(self):
        e = jnp.zeros((7,), jnp.int32)
        np.testing.assert_array_equal(np.asarray(moe.capacity_positions(e, 1)),
                                      np.arange(7))


# (n, k, G, C, d) — capacity headroom, over-capacity, tiny and
# non-tile-multiple shapes all represented.
CASES = [
    (8, 1, 2, 8, 4),        # headroom, k=1 (bit-exact combine)
    (37, 2, 5, 6, 24),      # over-capacity drops + sentinel traffic
    (16, 4, 3, 4, 8),       # heavy over-capacity at k=4
    (130, 2, 4, 48, 33),    # > one row tile, odd d
]


class TestDispatchTokens:
    @pytest.mark.parametrize("case", CASES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_bit_exact_vs_ref_and_jnp(self, case, dtype):
        n, k, g, c, d = case
        x, expert, pos, _ = _case(1, n, k, g, c, d, dtype)
        got = ops.dispatch_tokens(x, expert, pos, num_buckets=g, capacity=c,
                                  bt=16, bd=16)
        want = ref.dispatch_tokens_ref(x, expert, pos, g, c)
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))
        # and vs the production jnp scatter (sentinel bucket sliced off)
        jnp_buf, jnp_pos = moe.capacity_dispatch(x, expert, c, g + 1)
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(jnp_buf[:g], np.float32))
        np.testing.assert_array_equal(np.asarray(pos), np.asarray(jnp_pos))

    def test_weighted_scatter(self):
        n, k, g, c, d = CASES[1]
        x, expert, pos, gate = _case(2, n, k, g, c, d)
        got = ops.dispatch_tokens(x, expert, pos, num_buckets=g, capacity=c,
                                  weights=gate, bt=16, bd=16)
        want = ref.dispatch_tokens_ref(x, expert, pos, g, c, weights=gate)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_empty_slots_are_zero(self):
        """Unoccupied capacity slots come out exactly zero."""
        x = jnp.full((4, 8), 7.5)
        expert = jnp.zeros((4, 1), jnp.int32)
        pos = jnp.arange(4, dtype=jnp.int32)[:, None]
        buf = np.asarray(ops.dispatch_tokens(x, expert, pos, num_buckets=3,
                                             capacity=8, bt=8, bd=8))
        assert np.abs(buf[0, 4:]).max() == 0.0
        assert np.abs(buf[1:]).max() == 0.0
        assert (buf[0, :4] == 7.5).all()

    def test_block_shape_invariance(self):
        n, k, g, c, d = CASES[3]
        x, expert, pos, _ = _case(3, n, k, g, c, d)
        a = ops.dispatch_tokens(x, expert, pos, num_buckets=g, capacity=c,
                                bt=16, bd=16)
        b = ops.dispatch_tokens(x, expert, pos, num_buckets=g, capacity=c,
                                bt=128, bd=32)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCombineTokens:
    @pytest.mark.parametrize("case", CASES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, case, dtype):
        n, k, g, c, d = case
        x, expert, pos, gate = _case(4, n, k, g, c, d, dtype)
        buf = ref.dispatch_tokens_ref(x, expert, pos, g, c)
        got = ops.combine_tokens(buf, expert, pos, gate, bt=16, bd=16)
        want = ref.combine_tokens_ref(buf, expert, pos, gate)
        if k == 1:
            # no adds ⇒ no FP-contraction slack: bit-exact
            np.testing.assert_array_equal(np.asarray(got, np.float32),
                                          np.asarray(want, np.float32))
        else:
            tol = 1e-6 if dtype == jnp.float32 else 1e-2
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(want, np.float32),
                                       rtol=tol, atol=tol)

    def test_matches_jnp_combine(self):
        n, k, g, c, d = CASES[1]
        x, expert, pos, gate = _case(5, n, k, g, c, d)
        buf = ref.dispatch_tokens_ref(x, expert, pos, g, c)
        got = ops.combine_tokens(buf, expert, pos, gate, bt=16, bd=16)
        want = moe.capacity_combine(buf, expert, pos, gate)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_dropped_choices_contribute_zero(self):
        """Sentinel buckets and over-capacity positions are skipped even
        when their gates are nonzero."""
        g, c, d = 2, 2, 4
        buf = jnp.ones((g, c, d))
        expert = jnp.array([[0, 2], [1, 0]], jnp.int32)   # 2 == sentinel
        pos = jnp.array([[0, 0], [5, 1]], jnp.int32)      # 5 ≥ capacity
        gate = jnp.full((2, 2), 0.5)
        y = np.asarray(ops.combine_tokens(buf, expert, pos, gate,
                                          bt=8, bd=8))
        np.testing.assert_allclose(y[0], 0.5)   # only (0, 0) lands
        np.testing.assert_allclose(y[1], 0.5)   # only (0, 1) lands


class TestCustomVJP:
    """The kernel backward (each leg reusing the other + the row-dot
    gate cotangent) must match autodiff of the jnp path."""

    @pytest.mark.parametrize("case", [CASES[1], CASES[2]])
    def test_roundtrip_grads_match_jnp_path(self, case):
        n, k, g, c, d = case
        x, expert, pos, gate = _case(6, n, k, g, c, d)

        def f_kernel(x, gate):
            buf = ops.dispatch_tokens(x, expert, pos, num_buckets=g,
                                      capacity=c, bt=16, bd=16)
            return jnp.sum(ops.combine_tokens(buf, expert, pos, gate,
                                              bt=16, bd=16) ** 2)

        def f_jnp(x, gate):
            buf, p = moe.capacity_dispatch(x, expert, c, g + 1)
            return jnp.sum(moe.capacity_combine(buf[:g], expert, p,
                                                gate) ** 2)

        gk = jax.grad(f_kernel, argnums=(0, 1))(x, gate)
        gj = jax.grad(f_jnp, argnums=(0, 1))(x, gate)
        for a, b in zip(gk, gj):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_weighted_dispatch_weight_grad(self):
        """dw through the weighted scatter == autodiff of the ref."""
        n, k, g, c, d = CASES[1]
        x, expert, pos, gate = _case(7, n, k, g, c, d)
        ct = jax.random.normal(jax.random.PRNGKey(9), (g, c, d))

        def f_kernel(w):
            return jnp.sum(ops.dispatch_tokens(
                x, expert, pos, num_buckets=g, capacity=c, weights=w,
                bt=16, bd=16) * ct)

        def f_ref(w):
            return jnp.sum(ref.dispatch_tokens_ref(
                x, expert, pos, g, c, weights=w) * ct)

        np.testing.assert_allclose(np.asarray(jax.grad(f_kernel)(gate)),
                                   np.asarray(jax.grad(f_ref)(gate)),
                                   rtol=1e-5, atol=1e-6)

    def test_combine_buf_and_gate_grads(self):
        n, k, g, c, d = CASES[2]
        x, expert, pos, gate = _case(8, n, k, g, c, d)
        buf = ref.dispatch_tokens_ref(x, expert, pos, g, c)
        ct = jax.random.normal(jax.random.PRNGKey(10), (n, d))

        def f_kernel(buf, gate):
            return jnp.sum(ops.combine_tokens(buf, expert, pos, gate,
                                              bt=16, bd=16) * ct)

        def f_jnp(buf, gate):
            return jnp.sum(moe.capacity_combine(buf, expert, pos,
                                                gate) * ct)

        gk = jax.grad(f_kernel, argnums=(0, 1))(buf, gate)
        gj = jax.grad(f_jnp, argnums=(0, 1))(buf, gate)
        for a, b in zip(gk, gj):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


class TestProperties:
    """Property suite over random shapes/routings (hypothesis or the
    deterministic fallback shim)."""

    @given(st.integers(2, 24), st.integers(1, 3), st.integers(2, 5),
           st.integers(0, 500))
    @settings(max_examples=10, deadline=None)
    def test_roundtrip_identity_under_headroom(self, n, k, g, seed):
        """With capacity ≥ all bucket loads and gates renormalized, the
        dispatch→combine round trip is the gate-sum-scaled input."""
        d = 8
        c = n * k  # can never overflow
        x, expert, pos, gate = _case(seed, n, k, g, c, d, sentinel=False)
        buf = ops.dispatch_tokens(x, expert, pos, num_buckets=g, capacity=c,
                                  bt=16, bd=16)
        y = ops.combine_tokens(buf, expert, pos, gate, bt=16, bd=16)
        want = np.asarray(x) * np.asarray(gate).sum(-1, keepdims=True)
        np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5,
                                   atol=1e-6)

    @given(st.integers(4, 24), st.integers(1, 3), st.integers(2, 4),
           st.integers(2, 6), st.integers(0, 500))
    @settings(max_examples=10, deadline=None)
    def test_drop_accounting_at_over_capacity(self, n, k, g, c, seed):
        """Exactly kept_counts slots are populated per bucket; dropped
        (token, choice)s read back zero through the gather."""
        d = 8
        x, expert, pos, _ = _case(seed, n, k, g, c, d, sentinel=False)
        x = jnp.abs(x) + 1.0    # strictly nonzero rows
        buf = np.asarray(ops.dispatch_tokens(x, expert, pos, num_buckets=g,
                                             capacity=c, bt=16, bd=16))
        kept = np.asarray(moe.kept_counts(expert, g, c))
        occupied = (np.abs(buf).max(-1) > 0)               # [g, c]
        np.testing.assert_array_equal(occupied.sum(-1), kept)
        # prefix-filled: occupancy is exactly the first kept[b] slots
        for b in range(g):
            assert occupied[b, :kept[b]].all()

    @given(st.integers(2, 20), st.integers(1, 2), st.integers(2, 4),
           st.integers(0, 500))
    @settings(max_examples=10, deadline=None)
    def test_sentinel_bucket_never_lands(self, n, k, g, seed):
        """Choices carrying the sentinel id G drop on dispatch and
        contribute zero on combine even with nonzero gates."""
        d = 8
        c = n * k
        x, expert, pos, gate = _case(seed, n, k, g, c, d, sentinel=False)
        sent = jax.random.bernoulli(jax.random.PRNGKey(seed + 300),
                                    0.5, (n, k))
        expert = jnp.where(sent, g, expert)
        pos = moe.capacity_positions(expert.reshape(-1), g + 1
                                     ).reshape(n, k)
        buf = ops.dispatch_tokens(x, expert, pos, num_buckets=g, capacity=c,
                                  bt=16, bd=16)
        y = ops.combine_tokens(buf, expert, pos, gate, bt=16, bd=16)
        want = (np.asarray(x)
                * (np.asarray(gate) * ~np.asarray(sent)).sum(-1,
                                                             keepdims=True))
        np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5,
                                   atol=1e-6)

    @given(st.integers(4, 16), st.integers(1, 2), st.integers(2, 4),
           st.integers(2, 8), st.integers(0, 500))
    @settings(max_examples=6, deadline=None)
    def test_grad_flow_equivalence(self, n, k, g, c, seed):
        """Pallas and jnp paths propagate the same gradients (to
        summation round-off) for arbitrary drop patterns."""
        d = 8
        x, expert, pos, gate = _case(seed, n, k, g, c, d)

        def f(use_pallas):
            def loss(x, gate):
                buf, p = moe.capacity_dispatch(x, expert, c, g + 1,
                                               use_pallas=use_pallas)
                return jnp.sum(moe.capacity_combine(
                    buf[:g], expert, p, gate,
                    use_pallas=use_pallas) ** 2)
            return jax.grad(loss, argnums=(0, 1))(x, gate)

        for a, b in zip(f(True), f(False)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


class TestMoeFlagEquivalence:
    """REPRO_DISPATCH_PALLAS on/off through the full layer, for the
    chunked pipeline's K grid — the permuted buffers must slice into
    identical per-chunk capacity windows."""

    def _run(self, flag, params, x, ctx, kw, chunks):
        os.environ["REPRO_DISPATCH_PALLAS"] = flag
        try:
            y, aux = moe.moe_apply(params, x, None, ctx,
                                   a2a_chunks=chunks, **kw)

            def loss(p):
                yy, _ = moe.moe_apply(p, x, None, ctx,
                                      a2a_chunks=chunks, **kw)
                return jnp.sum(yy ** 2)

            return y, aux, jax.grad(loss)(params)
        finally:
            del os.environ["REPRO_DISPATCH_PALLAS"]

    @pytest.mark.parametrize("chunks", [1, 2, 4])
    def test_flag_equivalence(self, chunks):
        ctx = local_ctx()
        E, d, f = 8, 16, 32
        params = moe.moe_init(jax.random.PRNGKey(0), d, f, E,
                              ffn_kind="swiglu")
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
        kw = dict(num_experts=E, top_k=2, d_expert=f, s_max=2)
        y0, a0, g0 = self._run("0", params, x, ctx, kw, chunks)
        y1, a1, g1 = self._run("1", params, x, ctx, kw, chunks)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(a0["counts"]),
                                      np.asarray(a1["counts"]))
        assert float(a0["dropped"]) == float(a1["dropped"])
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)


class TestShadowPlacementGrads:
    """With a live shadow placement the combine calls' dropped choices
    must carry the bucket *sentinel*, not a zero-gate clamp onto bucket
    0 — a clamped (0, pos) pair can collide with a genuine bucket-0
    slot, and the sorted-gather inversion in combine's backward (one
    source per slot) would then evict the genuine cotangent.  Eviction
    order is scatter-implementation-defined, so the hard regression pin
    is the mesh sweep (tests/dist/dispatch_equivalence.py, which caught
    it); this fast-lane test exercises the same live-shadow grad path
    single-device."""

    def test_live_shadow_grad_equivalence(self):
        ctx = local_ctx()
        E, d, f, s_max = 8, 16, 32, 2
        params = moe.moe_init(jax.random.PRNGKey(0), d, f, E,
                              ffn_kind="swiglu")
        # skew the router hard so shadowed expert 0 is hot
        params["router"]["w"] = (params["router"]["w"]
                                 + 2.0 * jax.random.normal(
                                     jax.random.PRNGKey(7), (E,)))
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
        sidx = jnp.full((s_max,), E, jnp.int32).at[0].set(0)
        placement = {
            "shadow_idx": sidx,
            "shadow_valid": jnp.zeros((s_max,), jnp.float32).at[0].set(1.0),
            "shadow_devs": jnp.ones((s_max, 1), jnp.float32),
        }
        kw = dict(num_experts=E, top_k=2, d_expert=f, s_max=s_max)

        def grads(flag):
            os.environ["REPRO_DISPATCH_PALLAS"] = flag
            try:
                def loss(p):
                    yy, _ = moe.moe_apply(p, x, placement, ctx, **kw)
                    return jnp.sum(yy ** 2)
                return jax.grad(loss)(params)
            finally:
                del os.environ["REPRO_DISPATCH_PALLAS"]

        for a, b in zip(jax.tree.leaves(grads("1")),
                        jax.tree.leaves(grads("0"))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)


class TestModeledBytes:
    """The memory-traffic table: the kernel wins ≥ k× on dispatch and
    never materializes the f32 [N, k, d] on combine; PerfModel mirrors
    the formulas exactly (the < 1e-12 pin lives in
    benchmarks/perfmodel_accuracy.py)."""

    @pytest.mark.parametrize("k", [1, 2, 4])
    def test_dispatch_win_at_least_k(self, k):
        # (at larger k the capacity buffer itself — which both paths
        # write once resp. thrice — dominates and the ratio saturates
        # near 3·cf·k / (1 + cf·k) ≈ 4.3×; the routed grid stops at 4)
        n, d = 8192, 512
        slots = int(1.25 * n * k)
        pallas = dispatch_modeled_bytes(n, slots, d, top_k=k)
        jnp_b = dispatch_modeled_bytes(n, slots, d, top_k=k, pallas=False)
        assert jnp_b / pallas >= k

    @pytest.mark.parametrize("k", [1, 2, 4])
    def test_combine_no_f32_blowup(self, k):
        n, d = 8192, 512
        slots = int(1.25 * n * k)
        pallas = combine_modeled_bytes(n, slots, d, top_k=k)
        jnp_b = combine_modeled_bytes(n, slots, d, top_k=k, pallas=False)
        # the jnp path's 8·d·N·k f32 copy term alone exceeds the whole
        # pallas traffic budget
        assert 8 * n * k * d > pallas
        assert pallas < jnp_b

    def test_perfmodel_agrees(self):
        from repro.core.perfmodel import HardwareSpec, PerfModel
        n, k, d = 4096, 2, 256
        slots = int(1.25 * n * k)
        hw = HardwareSpec(bandwidth=1e9, throughput=1e9,
                          input_bytes=d * 2, expert_param_bytes=1e6)
        pm = PerfModel(hw, 8)
        for pallas in (True, False):
            t = pm.t_dispatch(n, slots, top_k=k, pallas=pallas)
            b = dispatch_modeled_bytes(n, slots, d, top_k=k, pallas=pallas)
            assert abs(t * hw.hbm_bandwidth - b) / b < 1e-12
            t = pm.t_combine(n, slots, top_k=k, pallas=pallas)
            b = combine_modeled_bytes(n, slots, d, top_k=k, pallas=pallas)
            assert abs(t * hw.hbm_bandwidth - b) / b < 1e-12
