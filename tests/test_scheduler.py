"""Scheduler tests (paper §V): dependency-correct timelines, strategy
ordering, sub-operator splitting."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback shim — see requirements-dev.txt
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (BlockCosts, PerfModel, build_graph, choose_chunks,
                        chunked_expert_graph, chunked_makespan,
                        hidden_comm_fraction, iteration_time, list_schedule,
                        simulate, split_trans)

pos = st.floats(0.05, 5.0)


def costs_strategy():
    return st.builds(BlockCosts, a2a=pos, fec=pos, bec=pos, fnec=pos,
                     bnec=pos, trans=pos, agg=pos,
                     plan=st.floats(0.0, 0.5))


class TestTimeline:
    @given(costs_strategy(), st.integers(1, 6),
           st.sampled_from(["sequential", "operator", "blockwise"]))
    @settings(max_examples=40, deadline=None)
    def test_valid_schedule(self, c, nb, strategy):
        tl = simulate(nb, c, strategy)     # validate() runs inside
        assert tl.makespan > 0

    @given(costs_strategy(), st.integers(1, 6))
    @settings(max_examples=40, deadline=None)
    def test_strategy_ordering(self, c, nb):
        """Pro-Prophet's blockwise ≤ operator ≤ sequential (the paper's
        claim that finer scheduling only helps)."""
        t_seq = iteration_time(nb, c, "sequential")
        t_op = iteration_time(nb, c, "operator")
        t_bw = iteration_time(nb, c, "blockwise")
        assert t_bw <= t_op + 1e-9
        assert t_op <= t_seq + 1e-9

    @given(costs_strategy(), st.integers(2, 6))
    @settings(max_examples=20, deadline=None)
    def test_comm_lower_bound(self, c, nb):
        """No schedule can beat the pure computation critical path."""
        comp = nb * (c.fec + c.fnec + c.bec + c.bnec)
        for s in ("operator", "blockwise"):
            assert iteration_time(nb, c, s) >= comp - 1e-9

    def test_sequential_is_exact_sum(self):
        c = BlockCosts(a2a=1, fec=2, bec=4, fnec=1, bnec=2, trans=3, agg=3,
                       plan=0.5)
        nb = 3
        per_block = (0.5 + 3 + 1 + 2 + 1 + 1) + (2 + 1 + 4 + 1 + 3)
        assert iteration_time(nb, c, "sequential") == pytest.approx(
            nb * per_block)

    def test_blockwise_hides_trans_fully(self):
        # Trans smaller than the FEC window ⇒ fully hidden for blocks ≥ 1.
        c = BlockCosts(a2a=0.1, fec=5, bec=10, fnec=5, bnec=5, trans=1,
                       agg=1, plan=0.0)
        t_bw = iteration_time(4, c, "blockwise")
        t_seq = iteration_time(4, c, "sequential")
        # compute critical path + all comm that can't overlap itself
        comp = 4 * (c.fec + c.fnec + c.bec + c.bnec) + 16 * c.a2a
        # nearly all Trans/Agg hidden: within 2 un-hidden transfers of the
        # compute bound, and strictly better than blocked execution.
        assert t_bw <= comp + 2 * (c.trans + c.agg) + 1e-9
        assert t_bw < t_seq

    def test_plan_overlaps_a2a(self):
        c = BlockCosts(a2a=2, fec=1, bec=2, fnec=1, bnec=1, trans=0.0,
                       agg=0.0, plan=1.5)
        tl = simulate(2, c, "blockwise")
        p0 = tl.span("plan0")
        a0 = tl.span("a2a1_0")
        assert p0.start == pytest.approx(a0.start)   # runs under the a2a

    def test_split_trans(self):
        assert split_trans(3.0, 5.0, 1.0) == (3.0, 0.0)
        assert split_trans(7.0, 5.0, 1.0) == (5.0, 2.0)


class TestChunkedPipeline:
    """The chunked a2a↔FEC timeline that drives the device path's K
    (repro.models.moe) — §V realized on-device."""

    def test_k1_is_serial_chain(self):
        assert chunked_makespan(1.5, 2.0, 1) == pytest.approx(2 * 1.5 + 2.0)

    @given(pos, pos, st.integers(1, 8),
           st.floats(0.0, 0.2))
    @settings(max_examples=60, deadline=None)
    def test_closed_form_matches_timeline(self, a2a, fec, k, overhead):
        """PerfModel's eq.-8-style chunked term is the exact closed form
        of the list-scheduled timeline (same graph, same program order)."""
        tl = chunked_makespan(a2a, fec, k, chunk_overhead=overhead)
        cf = PerfModel.chunked_path_time(a2a, fec, k, chunk_overhead=overhead)
        assert tl == pytest.approx(cf, rel=1e-12, abs=1e-15)

    @given(pos, pos)
    @settings(max_examples=40, deadline=None)
    def test_chunking_monotone_without_overhead(self, a2a, fec):
        ts = [chunked_makespan(a2a, fec, k) for k in (1, 2, 4, 8)]
        for t0, t1 in zip(ts, ts[1:]):
            assert t1 <= t0 + 1e-12
        # never below the resource lower bounds
        assert ts[-1] >= max(2 * a2a, fec) - 1e-12

    def test_k2_strictly_lower_for_balanced_costs(self):
        """The acceptance shape: both a2a and FEC nonzero ⇒ chunking
        strictly beats the serial path."""
        assert chunked_makespan(1.0, 1.0, 2) < chunked_makespan(1.0, 1.0, 1)

    def test_choose_chunks_overhead_keeps_k1(self):
        # a2a far below the per-chunk launch cost ⇒ stay bit-identical
        assert choose_chunks(1e-7, 1e-2, chunk_overhead=2e-5) == 1
        # comm-heavy, free chunking ⇒ take the largest candidate
        assert choose_chunks(1.0, 2.0, candidates=(1, 2, 4)) == 4
        # zero-cost path ⇒ smallest K on ties
        assert choose_chunks(0.0, 0.0) == 1

    def test_hidden_comm_fraction(self):
        assert hidden_comm_fraction(1.0, 2.0, 1) == 0.0
        h2 = hidden_comm_fraction(1.0, 2.0, 2)
        h4 = hidden_comm_fraction(1.0, 2.0, 4)
        assert 0.0 < h2 <= h4 <= 1.0
        assert hidden_comm_fraction(0.0, 2.0, 4) == 0.0

    def test_graph_is_valid_and_complete(self):
        g = chunked_expert_graph(1.0, 0.5, 3, prefix="x")
        tl = list_schedule(g)
        tl.validate(g)
        assert len(tl.ops) == 3 * 3
        # send of chunk 1 runs while chunk 0's FEC computes
        assert tl.span("xa2a1_c1").start < tl.span("xfec_c0").end


class TestGraph:
    def test_cycle_detection(self):
        from repro.core.scheduler import Op
        with pytest.raises(ValueError):
            list_schedule([Op("a", "comp", 1, ["b"]),
                           Op("b", "comp", 1, ["a"])])

    @given(costs_strategy(), st.integers(1, 4))
    @settings(max_examples=15, deadline=None)
    def test_all_ops_scheduled_once(self, c, nb):
        for strategy in ("sequential", "operator", "blockwise"):
            g = build_graph(nb, c, strategy)
            tl = list_schedule(g)
            names = [o.name for o in tl.ops]
            assert len(names) == len(set(names)) == len(g)
