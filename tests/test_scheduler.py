"""Scheduler tests (paper §V): dependency-correct timelines, strategy
ordering, sub-operator splitting."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback shim — see requirements-dev.txt
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import BlockCosts, build_graph, iteration_time, list_schedule, simulate, split_trans

pos = st.floats(0.05, 5.0)


def costs_strategy():
    return st.builds(BlockCosts, a2a=pos, fec=pos, bec=pos, fnec=pos,
                     bnec=pos, trans=pos, agg=pos,
                     plan=st.floats(0.0, 0.5))


class TestTimeline:
    @given(costs_strategy(), st.integers(1, 6),
           st.sampled_from(["sequential", "operator", "blockwise"]))
    @settings(max_examples=40, deadline=None)
    def test_valid_schedule(self, c, nb, strategy):
        tl = simulate(nb, c, strategy)     # validate() runs inside
        assert tl.makespan > 0

    @given(costs_strategy(), st.integers(1, 6))
    @settings(max_examples=40, deadline=None)
    def test_strategy_ordering(self, c, nb):
        """Pro-Prophet's blockwise ≤ operator ≤ sequential (the paper's
        claim that finer scheduling only helps)."""
        t_seq = iteration_time(nb, c, "sequential")
        t_op = iteration_time(nb, c, "operator")
        t_bw = iteration_time(nb, c, "blockwise")
        assert t_bw <= t_op + 1e-9
        assert t_op <= t_seq + 1e-9

    @given(costs_strategy(), st.integers(2, 6))
    @settings(max_examples=20, deadline=None)
    def test_comm_lower_bound(self, c, nb):
        """No schedule can beat the pure computation critical path."""
        comp = nb * (c.fec + c.fnec + c.bec + c.bnec)
        for s in ("operator", "blockwise"):
            assert iteration_time(nb, c, s) >= comp - 1e-9

    def test_sequential_is_exact_sum(self):
        c = BlockCosts(a2a=1, fec=2, bec=4, fnec=1, bnec=2, trans=3, agg=3,
                       plan=0.5)
        nb = 3
        per_block = (0.5 + 3 + 1 + 2 + 1 + 1) + (2 + 1 + 4 + 1 + 3)
        assert iteration_time(nb, c, "sequential") == pytest.approx(
            nb * per_block)

    def test_blockwise_hides_trans_fully(self):
        # Trans smaller than the FEC window ⇒ fully hidden for blocks ≥ 1.
        c = BlockCosts(a2a=0.1, fec=5, bec=10, fnec=5, bnec=5, trans=1,
                       agg=1, plan=0.0)
        t_bw = iteration_time(4, c, "blockwise")
        t_seq = iteration_time(4, c, "sequential")
        # compute critical path + all comm that can't overlap itself
        comp = 4 * (c.fec + c.fnec + c.bec + c.bnec) + 16 * c.a2a
        # nearly all Trans/Agg hidden: within 2 un-hidden transfers of the
        # compute bound, and strictly better than blocked execution.
        assert t_bw <= comp + 2 * (c.trans + c.agg) + 1e-9
        assert t_bw < t_seq

    def test_plan_overlaps_a2a(self):
        c = BlockCosts(a2a=2, fec=1, bec=2, fnec=1, bnec=1, trans=0.0,
                       agg=0.0, plan=1.5)
        tl = simulate(2, c, "blockwise")
        p0 = tl.span("plan0")
        a0 = tl.span("a2a1_0")
        assert p0.start == pytest.approx(a0.start)   # runs under the a2a

    def test_split_trans(self):
        assert split_trans(3.0, 5.0, 1.0) == (3.0, 0.0)
        assert split_trans(7.0, 5.0, 1.0) == (5.0, 2.0)


class TestGraph:
    def test_cycle_detection(self):
        from repro.core.scheduler import Op
        with pytest.raises(ValueError):
            list_schedule([Op("a", "comp", 1, ["b"]),
                           Op("b", "comp", 1, ["a"])])

    @given(costs_strategy(), st.integers(1, 4))
    @settings(max_examples=15, deadline=None)
    def test_all_ops_scheduled_once(self, c, nb):
        for strategy in ("sequential", "operator", "blockwise"):
            g = build_graph(nb, c, strategy)
            tl = list_schedule(g)
            names = [o.name for o in tl.ops]
            assert len(names) == len(set(names)) == len(g)
