"""Minimal deterministic stand-in for ``hypothesis``.

The property tests import this as a fallback when hypothesis is not
installed (see requirements-dev.txt), so the suite still collects and
exercises many pseudo-random examples per test — it just loses real
hypothesis features (shrinking, example database, edge-case heuristics).

Only the surface this suite uses is implemented: ``@given``/``@settings``
and the ``integers`` / ``floats`` / ``sampled_from`` / ``builds``
strategies.  Draws come from a fixed-seed ``random.Random`` so failures
reproduce across runs.
"""
import functools
import inspect
import random
import types

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def _builds(target, *args, **kwargs):
    def draw(rng):
        pa = [a.example_from(rng) if isinstance(a, _Strategy) else a
              for a in args]
        pk = {k: (v.example_from(rng) if isinstance(v, _Strategy) else v)
              for k, v in kwargs.items()}
        return target(*pa, **pk)
    return _Strategy(draw)


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, sampled_from=_sampled_from,
    builds=_builds)


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        n = getattr(fn, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(0)
            for _ in range(n):
                fn(*args, *[s.example_from(rng) for s in strats], **kwargs)

        # Hide the generated parameters from pytest's fixture resolution
        # (like hypothesis does), leaving only e.g. ``self``.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        wrapper.__signature__ = sig.replace(
            parameters=params[:len(params) - len(strats)])
        return wrapper
    return deco
