"""Ragged grouped-matmul + fused-SwiGLU kernel sweeps vs the ref.py
oracles (interpret=True on CPU), custom-VJP vs autodiff-of-reference
checks, and REPRO_MOE_PALLAS on/off equivalence through moe_apply."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ragged_gmm import active_row_tiles, modeled_flops

KEY = jax.random.PRNGKey(0)

# (G, S, seg_len, D, F, group_sizes rows) — zero-token experts, full
# segments, skew, and non-tile-multiple shapes all represented.
CASES = [
    (2, 1, 16, 8, 8, [[0], [16]]),
    (3, 1, 40, 24, 56, [[5], [0], [33]]),
    (2, 2, 32, 16, 24, [[32, 0], [7, 19]]),
    (3, 4, 8, 33, 65, [[8, 8, 8, 8], [0, 0, 0, 0], [1, 0, 7, 3]]),
    (1, 2, 130, 128, 128, [[130, 1]]),
]


def _case_arrays(case, dtype):
    g, s, seg, d, f, gs_rows = case
    t = s * seg
    x = jax.random.normal(KEY, (g, t, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (g, d, f), dtype)
    w2 = jax.random.normal(jax.random.PRNGKey(2), (g, d, f), dtype)
    gs = jnp.asarray(gs_rows, jnp.int32)
    return x, w, w2, gs, seg


class TestRaggedGMM:
    @pytest.mark.parametrize("case", CASES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, case, dtype):
        x, w, _, gs, seg = _case_arrays(case, dtype)
        got = ops.ragged_gmm(x, w, gs, seg_len=seg, bt=32, bf=32, bd=32)
        want = ref.ragged_gmm_ref(x, w, gs, seg)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_rows_past_count_are_zero_even_for_garbage(self):
        """The op's contract: unoccupied rows produce zeros regardless of
        what the padded capacity slots hold."""
        g, t, d, f = 2, 32, 16, 16
        x = jnp.full((g, t, d), 7.5)          # garbage everywhere
        w = jax.random.normal(KEY, (g, d, f))
        gs = jnp.array([3, 0], jnp.int32)
        out = np.asarray(ops.ragged_gmm(x, w, gs, bt=16, bf=16, bd=16))
        assert np.abs(out[0, 3:]).max() == 0.0
        assert np.abs(out[1]).max() == 0.0
        assert np.abs(out[0, :3]).max() > 0.0

    def test_block_shape_invariance(self):
        x, w, _, gs, seg = _case_arrays(CASES[1], jnp.float32)
        y1 = ops.ragged_gmm(x, w, gs, seg_len=seg, bt=32, bf=32, bd=32)
        y2 = ops.ragged_gmm(x, w, gs, seg_len=seg, bt=128, bf=64, bd=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)

    def test_full_occupancy_matches_dense_gmm(self):
        x = jax.random.normal(KEY, (2, 64, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 48))
        gs = jnp.array([64, 64], jnp.int32)
        got = ops.ragged_gmm(x, w, gs, bt=32, bf=32, bd=32)
        want = ops.gmm(x, w, bt=32, bf=32, bd=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestGmmSwiglu:
    @pytest.mark.parametrize("case", CASES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, case, dtype):
        x, wg, wi, gs, seg = _case_arrays(case, dtype)
        got = ops.gmm_swiglu(x, wg, wi, gs, seg_len=seg, bt=32, bf=32, bd=32)
        want = ref.gmm_swiglu_ref(x, wg, wi, gs, seg)
        # f32 tolerance is loose-ish: the product of two D-wide f32
        # accumulations amplifies summation-order noise at large D.
        tol = 2e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_fused_equals_unfused(self):
        """Epilogue fusion is a pure layout optimization."""
        x, wg, wi, gs, seg = _case_arrays(CASES[2], jnp.float32)
        fused = ops.gmm_swiglu(x, wg, wi, gs, seg_len=seg, bt=32, bf=32,
                               bd=32)
        a = ops.ragged_gmm(x, wg, gs, seg_len=seg, bt=32, bf=32, bd=32)
        b = ops.ragged_gmm(x, wi, gs, seg_len=seg, bt=32, bf=32, bd=32)
        np.testing.assert_allclose(np.asarray(fused),
                                   np.asarray(jax.nn.silu(a) * b),
                                   rtol=1e-5, atol=1e-5)


class TestCustomVJP:
    """The hand-written ragged backward must match autodiff of the
    reference (cotangents restricted to the defined output rows)."""

    @pytest.mark.parametrize("case", [CASES[1], CASES[2], CASES[3]])
    def test_ragged_gmm_grads(self, case):
        x, w, _, gs, seg = _case_arrays(case, jnp.float32)
        ct = jax.random.normal(jax.random.PRNGKey(3),
                               (x.shape[0], x.shape[1], w.shape[2]))

        def f_kernel(x, w):
            return jnp.sum(ops.ragged_gmm(x, w, gs, seg_len=seg, bt=32,
                                          bf=32, bd=32) * ct)

        def f_ref(x, w):
            return jnp.sum(ref.ragged_gmm_ref(x, w, gs, seg) * ct)

        gk = jax.grad(f_kernel, (0, 1))(x, w)
        gr = jax.grad(f_ref, (0, 1))(x, w)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("case", [CASES[1], CASES[2], CASES[3]])
    def test_gmm_swiglu_grads(self, case):
        x, wg, wi, gs, seg = _case_arrays(case, jnp.float32)
        ct = jax.random.normal(jax.random.PRNGKey(3),
                               (x.shape[0], x.shape[1], wg.shape[2]))

        def f_kernel(x, wg, wi):
            return jnp.sum(ops.gmm_swiglu(x, wg, wi, gs, seg_len=seg, bt=32,
                                          bf=32, bd=32) * ct)

        def f_ref(x, wg, wi):
            return jnp.sum(ref.gmm_swiglu_ref(x, wg, wi, gs, seg) * ct)

        gk = jax.grad(f_kernel, (0, 1, 2))(x, wg, wi)
        gr = jax.grad(f_ref, (0, 1, 2))(x, wg, wi)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)

    def test_grad_through_chained_ffn(self):
        """gmm_swiglu → ragged_gmm chained on the same counts (the MoE
        expert FFN) differentiates end to end."""
        g, t, d, f = 2, 32, 16, 24
        x = jax.random.normal(KEY, (g, t, d))
        wg = jax.random.normal(jax.random.PRNGKey(1), (g, d, f))
        wi = jax.random.normal(jax.random.PRNGKey(2), (g, d, f))
        wo = jax.random.normal(jax.random.PRNGKey(3), (g, f, d))
        gs = jnp.array([13, 0], jnp.int32)

        def loss(wg, wi, wo):
            h = ops.gmm_swiglu(x, wg, wi, gs, bt=16, bf=16, bd=16)
            y = ops.ragged_gmm(h, wo, gs, bt=16, bf=16, bd=16)
            return jnp.sum(y ** 2)

        def loss_ref(wg, wi, wo):
            h = ref.gmm_swiglu_ref(x, wg, wi, gs)
            y = ref.ragged_gmm_ref(h, wo, gs)
            return jnp.sum(y ** 2)

        gk = jax.grad(loss, (0, 1, 2))(wg, wi, wo)
        gr = jax.grad(loss_ref, (0, 1, 2))(wg, wi, wo)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)


class TestModeledCost:
    def test_empty_and_full(self):
        assert active_row_tiles(64, [0, 0], bt=32) == (0, 4)
        assert active_row_tiles(64, [64, 64], bt=32) == (4, 4)

    def test_ragged_never_exceeds_dense(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            gs = rng.integers(0, 65, size=(4, 2))
            ragged, dense = modeled_flops(128, 64, 64, gs, 64)
            assert 0 <= ragged <= dense

    def test_skewed_loads_strictly_cheaper_than_dense(self):
        """Whenever any expert runs under capacity, the ragged kernel does
        strictly less modeled work than the dense capacity buffer."""
        ragged, dense = modeled_flops(128, 64, 64, [104, 8, 8, 8], 128,
                                      bt=32)
        assert ragged < dense
        # zero-load experts cost nothing at all
        hot, _ = modeled_flops(128, 64, 64, [128, 0, 0, 0], 128, bt=32)
        assert hot == dense // 4


class TestMoEPallasFlag:
    """moe_apply numerics must be identical with REPRO_MOE_PALLAS on/off,
    across skewed routing distributions (single device here; the mesh /
    shard_map equivalence runs in test_distributed)."""

    def _apply(self, flag, params, x, placement, **kw):
        os.environ["REPRO_MOE_PALLAS"] = flag
        try:
            from repro.models import moe
            from repro.parallel import local_ctx
            return moe.moe_apply(params, x, placement, local_ctx(), **kw)
        finally:
            del os.environ["REPRO_MOE_PALLAS"]

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("ffn_kind", ["swiglu", "gelu"])
    def test_forward_equivalence(self, seed, ffn_kind):
        from repro.models import moe
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        E, d, f = 4, 16, 32
        params = moe.moe_init(ks[0], d, f, E, ffn_kind=ffn_kind)
        # skew the routing by biasing the router logits
        params["router"]["w"] = (params["router"]["w"]
                                 + 2.0 * jax.random.normal(ks[2], (E,)))
        x = 0.5 * jax.random.normal(ks[1], (2, 16, d))
        kw = dict(num_experts=E, top_k=2, d_expert=f, ffn_kind=ffn_kind,
                  capacity_factor=2.0, shadow_capacity_factor=4.0, s_max=2)
        y0, aux0 = self._apply("0", params, x, None, **kw)
        y1, aux1 = self._apply("1", params, x, None, **kw)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(aux0["counts"]),
                                      np.asarray(aux1["counts"]))

    def test_forward_equivalence_with_shadow_placement(self):
        from repro.models import moe
        ks = jax.random.split(KEY, 2)
        E, d, f = 4, 16, 32
        params = moe.moe_init(ks[0], d, f, E, ffn_kind="swiglu")
        x = 0.5 * jax.random.normal(ks[1], (2, 16, d))
        placement = {
            "shadow_idx": jnp.array([1, 4], jnp.int32),
            "shadow_valid": jnp.array([1.0, 0.0], jnp.float32),
            "shadow_devs": jnp.array([[1.0], [0.0]], jnp.float32),
        }
        kw = dict(num_experts=E, top_k=2, d_expert=f, ffn_kind="swiglu",
                  capacity_factor=4.0, shadow_capacity_factor=4.0, s_max=2)
        y0, _ = self._apply("0", params, x, placement, **kw)
        y1, _ = self._apply("1", params, x, placement, **kw)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-5, atol=1e-6)

    def test_grad_equivalence(self):
        from repro.models import moe
        from repro.parallel import local_ctx
        ks = jax.random.split(KEY, 2)
        E, d, f = 4, 16, 32
        params = moe.moe_init(ks[0], d, f, E, ffn_kind="swiglu")
        x = 0.5 * jax.random.normal(ks[1], (2, 8, d))
        kw = dict(num_experts=E, top_k=2, d_expert=f, ffn_kind="swiglu",
                  capacity_factor=4.0, shadow_capacity_factor=4.0, s_max=2)

        def loss(p):
            y, _ = moe.moe_apply(p, x, None, local_ctx(), **kw)
            return jnp.sum(y ** 2)

        os.environ["REPRO_MOE_PALLAS"] = "0"
        try:
            g0 = jax.grad(loss)(params)
        finally:
            os.environ["REPRO_MOE_PALLAS"] = "1"
        try:
            g1 = jax.grad(loss)(params)
        finally:
            del os.environ["REPRO_MOE_PALLAS"]
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)

    def test_shared_expert_fused_path(self):
        from repro.models import ffn
        p = ffn.ffn_init(KEY, "swiglu", 16, 32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
        y0 = ffn.ffn_apply("swiglu", p, x)
        y1 = ffn.ffn_apply("swiglu", p, x, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-5, atol=1e-5)
