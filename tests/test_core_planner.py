"""Planner/performance-model unit + property tests (paper §IV)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback shim — see requirements-dev.txt
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (GatingTrace, GreedyPlanner, HardwareSpec,
                        LocalityPlanner, PerfModel, balance_degree,
                        distribution_similarity, rb_ratio, traditional)
from repro.core.baselines import fastermoe_plan, topk_policy
from repro.core.placement import ExpertPlacement, default_owner


def hw(d=512, f=1024, bw=25e9, fl=70e12, **kw):
    return HardwareSpec.from_model_dims(d, f, bandwidth=bw, flops_per_s=fl,
                                        **kw)


class TestPerfModel:
    def test_eq1_a2a_straggler(self):
        pm = PerfModel(hw(), 4)
        R = np.array([10, 20, 5, 0])
        # eq.1: max_i R_i * size(input) / B
        expect = 20 * pm.hw.input_bytes / pm.hw.bandwidth
        assert pm.t_a2a(R) == pytest.approx(expect)

    def test_eq2_eq3_compute(self):
        pm = PerfModel(hw(), 4)
        H = np.array([100, 400, 50, 1])
        assert pm.t_fec(H) == pytest.approx(400 / pm.hw.throughput)
        assert pm.t_bec(H) == pytest.approx(2 * pm.t_fec(H))

    def test_ragged_vs_dense_fec(self):
        """Dense capacity-padded FEC is load-independent; utilization is
        straggler load over capacity slots (ragged win = 1/util)."""
        pm = PerfModel(hw(), 4)
        H = np.array([100, 400, 50, 1])
        assert pm.t_fec_dense(512) == pytest.approx(512 / pm.hw.throughput)
        assert pm.fec_utilization(H, 512) == pytest.approx(400 / 512)
        # at full load the ragged kernel has no advantage
        assert pm.fec_utilization(np.full(4, 512), 512) == pytest.approx(1.0)
        assert pm.fec_utilization(H, 0) == 1.0

    def test_eq4_eq5_trans_agg_p2p(self):
        pm = PerfModel(hw(), trans_mode="p2p", num_devices=8)
        s, n = 3, 2
        expect = s * (8 - n) * pm.hw.expert_param_bytes / (8 * pm.hw.bandwidth)
        assert pm.t_trans(s, n) == pytest.approx(expect)
        assert pm.t_agg(s, n) == pytest.approx(expect)

    def test_ring_mode_ignores_n(self):
        pm = PerfModel(hw(), trans_mode="ring", num_devices=8)
        assert pm.t_trans(2, 0) == pytest.approx(pm.t_trans(2, 5))

    def test_eq6_total(self):
        pm = PerfModel(hw(), 4)
        R = np.array([8, 0, 0, 0])
        H = np.array([32, 32, 32, 32])
        t = pm.layer_time(R, H, 1, 1)
        assert t == pytest.approx(4 * pm.t_a2a(R) + 3 * pm.t_fec(H)
                                  + pm.t_trans(1, 1) + pm.t_agg(1, 1))

    def test_eq8_overlap_residual(self):
        h = hw(t_fnec=1.0, t_bnec=1.0)
        pm = PerfModel(h, 4)
        R = np.zeros(4)
        H = np.full(4, 1000.0)
        # Huge fnec/bnec windows ⇒ Trans/Agg fully hidden.
        assert pm.layer_time_scheduled(R, H, 2, 0) == pytest.approx(
            3 * pm.t_fec(H))
        # eq.8 never exceeds eq.6.
        assert pm.layer_time_scheduled(R, H, 2, 0) <= pm.layer_time(R, H, 2, 0)


class TestPlacement:
    def test_owner_layout(self):
        own = default_owner(16, 4)
        assert (own == np.repeat(np.arange(4), 4)).all()

    @given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 3),
           st.integers(1, 500))
    @settings(max_examples=30, deadline=None)
    def test_loads_conserve_tokens(self, d, epd, nshadow, seed):
        e = d * epd
        rng = np.random.default_rng(seed)
        g = rng.integers(0, 50, size=(d, e))
        pl = traditional(e, d)
        for _ in range(nshadow):
            ex = int(rng.integers(0, e))
            devs = frozenset(int(x) for x in
                             rng.choice(d, size=max(1, d // 2), replace=False))
            devs = devs - {int(pl.owner[ex])}
            if devs:
                pl = pl.with_shadow(ex, devs)
        H, R = pl.compute_loads(g)
        assert H.sum() == g.sum()              # every token computed once
        assert (R >= 0).all() and R.sum() <= g.sum()
        # received tokens are a subset of computed tokens on each device
        assert (R <= H + 1e-9).all()

    def test_shadow_moves_load(self):
        g = np.zeros((4, 4), dtype=float)
        g[:, 0] = 100.0                        # everyone routes to expert 0
        pl = traditional(4, 4)
        H0, R0 = pl.compute_loads(g)
        assert H0[0] == 400 and R0[0] == 300
        pl2 = pl.with_shadow(0, frozenset({1, 2, 3}))
        H1, R1 = pl2.compute_loads(g)
        assert (H1 == 100).all() and R1.sum() == 0

    def test_device_arrays_roundtrip(self):
        pl = traditional(8, 4).with_shadow(3, frozenset({0, 2}))
        arrs = pl.to_device_arrays(4)
        assert arrs["shadow_idx"][0] == 3
        assert arrs["shadow_valid"].sum() == 1
        assert (arrs["shadow_devs"][0] == [1, 0, 1, 0]).all()


class TestGreedyPlanner:
    def _planner(self, d, scheduled=False, n=2):
        return GreedyPlanner(PerfModel(hw(), d), n=n, alpha=0.25, s_max=8,
                             scheduled=scheduled)

    @given(st.integers(0, 100))
    @settings(max_examples=25, deadline=None)
    def test_never_worse_than_baseline(self, seed):
        d = 8
        g = GatingTrace(d, d, 512, skew=0.15, drift=0.0, seed=seed).step()
        res = self._planner(d).plan(g)
        assert res.predicted_time <= res.baseline_time + 1e-12
        # placement is well-formed
        for e, devs in res.placement.shadows.items():
            assert int(res.placement.owner[e]) not in devs

    def test_balances_extreme_skew(self):
        d = 8
        g = np.full((d, d), 1, dtype=float)
        g[:, 0] = 1000.0
        res = self._planner(d).plan(g)
        assert res.placement.num_shadowed >= 1
        H0, _ = traditional(d, d).compute_loads(g)
        H1, _ = res.placement.compute_loads(g)
        assert H1.max() < H0.max()
        assert rb_ratio(H0, H1) > 1.5

    def test_scheduled_plans_at_least_as_aggressively(self):
        # eq.8 hides Trans/Agg ⇒ the scheduled planner shadows ≥ as many.
        d = 8
        g = GatingTrace(d, d, 2048, skew=0.1, drift=0.0, seed=3).step()
        r_seq = self._planner(d, scheduled=False).plan(g)
        r_sch = self._planner(d, scheduled=True).plan(g)
        assert (r_sch.placement.num_shadowed
                >= r_seq.placement.num_shadowed)

    def test_respects_s_max(self):
        d = 8
        pm = PerfModel(hw(), d)
        p = GreedyPlanner(pm, n=0, alpha=0.0, s_max=2)
        g = GatingTrace(d, d, 2048, skew=0.05, drift=0.0, seed=0).step()
        assert p.plan(g).placement.num_shadowed <= 2


class TestLocality:
    def test_trace_has_locality(self):
        tr = GatingTrace(8, 16, 1024, skew=0.2, drift=0.03, seed=0)
        gs = tr.take(10)
        sims = [distribution_similarity(a.sum(0), b.sum(0))
                for a, b in zip(gs, gs[1:])]
        assert np.mean(sims) > 0.97            # paper Fig. 4 behaviour

    def test_no_drift_no_change(self):
        tr = GatingTrace(4, 8, 4096, skew=0.3, drift=0.0, seed=1)
        gs = tr.take(5)
        sims = [distribution_similarity(a.sum(0), b.sum(0))
                for a, b in zip(gs, gs[1:])]
        assert np.mean(sims) > 0.999

    def test_locality_planner_cadence(self):
        d = 8
        planner = LocalityPlanner(
            GreedyPlanner(PerfModel(hw(), d), n=2, s_max=4),
            num_devices=d, num_experts=d, replan_interval=5)
        tr = GatingTrace(d, d, 512, skew=0.2, drift=0.02, seed=0)
        plans = [planner.maybe_plan(tr.step()) for _ in range(10)]
        # replans at steps 0 and 5 only ⇒ ≤ 2 distinct placements
        ids = {id(p) for p in plans}
        assert len(ids) <= 2


class TestBaselines:
    def test_topk_policy_shadows_to_all(self):
        g = GatingTrace(4, 8, 256, seed=0).step()
        pl = topk_policy(g, 2)
        assert pl.num_shadowed == 2
        for e, devs in pl.shadows.items():
            assert len(devs) == 3              # all devices minus owner

    def test_fastermoe_improves_under_skew(self):
        d = 8
        g = np.full((d, d), 1, dtype=float)
        g[:, 0] = 2000.0
        res = fastermoe_plan(PerfModel(hw(), d), g)
        assert res.predicted_time < res.baseline_time
        # FasterMoE always replicates to ALL devices
        for e, devs in res.placement.shadows.items():
            assert len(devs) == d - 1
