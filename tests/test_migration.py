"""Dynamic expert migration (owner re-layout): placement, planner,
relocation, and trainer bit-identity — the fast single-device lane.
The (2, 4)-mesh end-to-end run lives in tests/dist/migration_equivalence.py.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (EngineConfig, GatingTrace, GreedyPlanner,
                        HardwareSpec, PerfModel, ProProphetEngine,
                        traditional)
from repro.core.placement import ExpertPlacement, default_owner


def hw(d=512, f=1024, bw=25e9, fl=70e12, **kw):
    return HardwareSpec.from_model_dims(d, f, bandwidth=bw, flops_per_s=fl,
                                        **kw)


# ---------------------------------------------------------------------------
# Placement: owner permutation mechanics
# ---------------------------------------------------------------------------

class TestMigrationPlacement:
    def test_identity_normalizes(self):
        pl = ExpertPlacement(8, 4, {}, tuple(range(8)))
        assert pl.slot_of is None
        assert pl == traditional(8, 4)
        assert pl.num_migrated == 0

    def test_with_migration_rehomes(self):
        pl = traditional(8, 4)
        m = pl.with_migration(0, 3)
        assert int(m.owner[0]) == 3
        # the displaced partner (first expert on device 3) moved to 0
        assert int(m.owner[6]) == 0
        assert m.num_migrated == 2
        # everyone else untouched, slot counts per device static
        np.testing.assert_array_equal(
            np.sort(m.owner), np.sort(default_owner(8, 4)))

    def test_with_migration_noop_and_partner(self):
        pl = traditional(8, 4)
        assert pl.with_migration(0, 0) is pl
        m = pl.with_migration(1, 2, partner=5)
        assert int(m.owner[1]) == 2 and int(m.owner[5]) == 0
        with pytest.raises(AssertionError):
            pl.with_migration(1, 2, partner=0)   # partner not owned by dst

    def test_rejects_bad_permutation(self):
        with pytest.raises(AssertionError):
            ExpertPlacement(4, 2, {}, (0, 0, 1, 2))
        with pytest.raises(AssertionError):
            ExpertPlacement(4, 2, {}, (0, 1, 2))

    def test_migration_prunes_conflicting_shadows(self):
        pl = traditional(8, 4).with_shadow(0, frozenset({2, 3}))
        m = pl.with_migration(0, 3, partner=6)
        # expert 0 now lives on 3 — its shadow there must be gone
        assert 3 not in m.shadows.get(0, frozenset())
        assert 2 in m.shadows[0]

    def test_compute_loads_honor_new_home(self):
        g = np.zeros((4, 8))
        g[:, 0] = 100.0
        pl = traditional(8, 4)
        H0, R0 = pl.compute_loads(g)
        assert H0[0] == 400 and R0[0] == 300
        m = pl.with_migration(0, 2, partner=4)
        H1, R1 = m.compute_loads(g)
        assert H1[2] == 400 and R1[2] == 300 and H1[0] == 0
        assert H1.sum() == g.sum()

    def test_diff_and_relocation_gather(self):
        pl = traditional(8, 4)
        m = pl.with_migration(0, 3, partner=6)
        assert m.diff(pl) == [(0, 0, 3), (6, 3, 0)]
        gather = m.relocation_gather(pl)
        # new slot s holds old slot gather[s]'s weights
        old = np.arange(8)
        new = old[gather]
        np.testing.assert_array_equal(new[m.slots], np.arange(8))
        # chained migrations compose through diff against any base
        m2 = m.with_migration(1, 2, partner=4)
        g2 = m2.relocation_gather(m)
        np.testing.assert_array_equal(old[gather][g2][m2.slots],
                                      np.arange(8))

    def test_device_arrays_carry_slots(self):
        m = traditional(8, 4).with_migration(0, 3, partner=6)
        arrs = m.to_device_arrays(2)
        np.testing.assert_array_equal(arrs["expert_slot"], m.slots)
        assert arrs["expert_slot"].dtype == np.int32


# ---------------------------------------------------------------------------
# Planner: migrate-vs-shadow scoring
# ---------------------------------------------------------------------------

def _persistent_g(d=4, e=8):
    """Device 0 owns two hot experts — re-homing one balances."""
    g = np.full((d, e), 10.0)
    g[:, 0] = 300.0
    g[:, 1] = 250.0
    return g


class TestMigrationPlanner:
    def _planner(self, strategy, window, d=4, **kw):
        return GreedyPlanner(PerfModel(hw(), d), n=0, alpha=0.0, s_max=4,
                             strategy=strategy, migrate_window=window, **kw)

    def test_migrate_wins_for_persistent_skew(self):
        res = self._planner("both", window=500).plan(_persistent_g())
        assert res.num_migrations >= 1
        assert res.placement.num_migrated == res.num_migrations
        assert res.predicted_time <= res.baseline_time

    def test_shadow_wins_for_transient_skew(self):
        """window → 1: the one-time move amortizes over nothing and the
        per-step shadow Trans is cheaper."""
        res = self._planner("both", window=1).plan(_persistent_g())
        assert res.num_migrations == 0
        assert res.placement.num_shadowed >= 1

    def test_migration_reduces_steadystate_trans_bytes(self):
        pm = PerfModel(hw(), 4)
        r_sh = self._planner("shadow", window=500).plan(_persistent_g())
        r_bo = self._planner("both", window=500).plan(_persistent_g())
        t_sh = pm.t_trans(r_sh.placement.num_shadowed, 0)
        t_bo = pm.t_trans(r_bo.placement.num_shadowed, 0)
        assert r_bo.num_migrations >= 1
        assert t_bo < t_sh

    def test_shadow_strategy_bit_identical_to_legacy(self):
        """strategy='shadow' must reproduce the pre-migration planner
        exactly — the disabled path is the paper's Algorithm 1."""
        d = 8
        for seed in range(8):
            g = GatingTrace(d, d * 2, 1024, skew=0.2, drift=0.0,
                            seed=seed).step()
            for scheduled in (False, True):
                a = GreedyPlanner(PerfModel(hw(), d), n=2, alpha=0.1,
                                  s_max=6, scheduled=scheduled).plan(g)
                b = GreedyPlanner(PerfModel(hw(), d), n=2, alpha=0.1,
                                  s_max=6, scheduled=scheduled,
                                  strategy="shadow",
                                  migrate_window=1e9).plan(g)
                assert a.placement == b.placement
                assert a.predicted_time == b.predicted_time
                assert b.num_migrations == 0

    def test_migrate_only_strategy(self):
        res = self._planner("migrate", window=500).plan(_persistent_g())
        assert res.placement.num_shadowed == 0
        assert res.num_migrations >= 1

    def test_migrate_incremental_loads_match_recompute(self):
        """The O(1) swap update of (H, R) inside the greedy loop must
        match a full compute_loads of the migrated placement (both
        experts unshadowed, the loop's invariant)."""
        rng = np.random.default_rng(0)
        for _ in range(20):
            D, E = 4, 12
            g = rng.integers(0, 200, size=(D, E)).astype(np.float64)
            cur = traditional(E, D).with_shadow(
                3, frozenset({1, 2}))          # unrelated shadow present
            H, R = cur.compute_loads(g)
            e, dst = 0, int(rng.integers(1, D))
            # the greedy loop never swaps shadowed experts — respect it
            partner = int([p for p in np.where(cur.owner == dst)[0]
                           if p not in cur.shadows][0])
            tot_e, tot_p = float(g[:, e].sum()), float(g[:, partner].sum())
            src = int(cur.owner[e])
            H_mg, R_mg = H.copy(), R.copy()
            H_mg[src] += tot_p - tot_e
            H_mg[dst] += tot_e - tot_p
            R_mg[src] += (tot_p - g[src, partner]) - (tot_e - g[src, e])
            R_mg[dst] += (tot_e - g[dst, e]) - (tot_p - g[dst, partner])
            H_full, R_full = cur.with_migration(
                e, dst, partner).compute_loads(g)
            np.testing.assert_allclose(H_mg, H_full)
            np.testing.assert_allclose(R_mg, R_full)

    def test_relocation_skips_untouched_layers(self):
        """active_gathers drops identity layers so the exchange only
        touches what moved."""
        from repro.configs import get_config, reduced
        from repro.train import relocate
        cfg = reduced(get_config("moe-gpt-s"))
        E, L = cfg.moe.num_experts, cfg.num_moe_layers
        gather = np.tile(np.arange(E, dtype=np.int32), (L, 1))
        assert all(p is None
                   for p in relocate.active_gathers(cfg, gather))
        gather[1, :2] = [1, 0]                 # swap in layer 1 only
        live = relocate.active_gathers(cfg, gather)
        assert sum(p is not None for p in live) == 1
        (stage,) = [p for p in live if p is not None]
        assert len(stage) == 1                 # one macro position live
        # the stacked rows carry the per-repeat gathers for that position
        rows = np.asarray(next(iter(stage.values())))
        assert rows.shape[-1] == E

    def test_t_migrate_amortization(self):
        pm = PerfModel(hw(), 4)
        assert pm.t_migrate(0, window=10) == 0.0
        assert pm.t_migrate(1, window=100) == pytest.approx(
            pm.t_migrate(1, window=10) / 10)
        assert pm.t_migrate(2, window=10) == pytest.approx(
            2 * pm.t_migrate(1, window=10))


# ---------------------------------------------------------------------------
# Engine: relocation schedule
# ---------------------------------------------------------------------------

def _mig_engine(layers=2, d=4, e=8, enabled=True):
    """Comm-bound profile: per-step Trans expensive, migration wins."""
    ec = EngineConfig(num_experts=e, num_devices=d, num_moe_layers=layers,
                      s_max=4, alpha=0.0, scheduled=False,
                      enable_migration=enabled, migrate_window=500.0)
    return ProProphetEngine(ec, hw(bw=1e9, fl=200e12))


class TestEngineRelocation:
    def test_relocation_lifecycle(self):
        eng = _mig_engine()
        g = _persistent_g()
        eng.observe([g, g])
        assert any(p.num_migrated for p in eng.placements)
        gather = eng.pending_relocation()
        assert gather is not None and gather.shape == (2, 8)
        relocs = eng.relocations()
        assert relocs and all(len(r) == 4 for r in relocs)
        arrs = eng.step_arrays()
        np.testing.assert_array_equal(arrs["expert_slot"][0],
                                      eng.placements[0].slots)
        eng.mark_relocated()
        assert eng.pending_relocation() is None
        assert eng.relocations() == []
        # stable skew ⇒ stable plan ⇒ no churn
        v = eng.placements_version
        eng.observe([g, g])
        assert eng.placements_version == v
        assert eng.pending_relocation() is None

    def test_disabled_engine_never_migrates(self):
        eng = _mig_engine(enabled=False)
        g = _persistent_g()
        eng.observe([g, g])
        assert all(p.num_migrated == 0 for p in eng.placements)
        assert eng.pending_relocation() is None
        np.testing.assert_array_equal(
            eng.step_arrays()["expert_slot"],
            np.tile(np.arange(8), (2, 1)))

    def test_flag_overrides_config(self, monkeypatch):
        monkeypatch.setenv("REPRO_MIGRATION", "0")
        assert _mig_engine(enabled=True).migration_enabled is False
        monkeypatch.setenv("REPRO_MIGRATION", "1")
        assert _mig_engine(enabled=False).migration_enabled is True


# ---------------------------------------------------------------------------
# Device path: identity relocation ≡ current path (single-device fast lane)
# ---------------------------------------------------------------------------

class TestRelocationDevicePath:
    def _setup(self, E=8, d=16, f=32):
        from repro.models import moe
        from repro.parallel import local_ctx
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        params = moe.moe_init(ks[0], d, f, E, ffn_kind="swiglu")
        x = 0.5 * jax.random.normal(ks[1], (2, 16, d))
        kw = dict(num_experts=E, top_k=2, d_expert=f, ffn_kind="swiglu",
                  capacity_factor=4.0, shadow_capacity_factor=4.0, s_max=2)
        return moe, local_ctx(), params, x, kw

    def test_identity_expert_slot_bit_identical(self):
        moe, ctx, params, x, kw = self._setup()
        E = kw["num_experts"]
        y0, aux0 = moe.moe_apply(params, x, None, ctx, **kw)
        ident = {"shadow_idx": jnp.full((2,), E, jnp.int32),
                 "shadow_valid": jnp.zeros((2,), jnp.float32),
                 "shadow_devs": jnp.zeros((2, 1), jnp.float32),
                 "expert_slot": jnp.arange(E, dtype=jnp.int32)}
        y1, aux1 = moe.moe_apply(params, x, ident, ctx, **kw)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
        np.testing.assert_array_equal(np.asarray(aux0["counts"]),
                                      np.asarray(aux1["counts"]))
        # pre-migration placement dicts (no expert_slot key) still work
        y2, _ = moe.moe_apply(params, x,
                              {k: v for k, v in ident.items()
                               if k != "expert_slot"}, ctx, **kw)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y2))

    def test_permuted_slots_with_relocated_weights_bit_identical(self):
        """A migrated layout (slot permutation + physically permuted
        weights) computes the same outputs and (row-permuted) grads."""
        moe, ctx, params, x, kw = self._setup()
        E = kw["num_experts"]
        rng = np.random.default_rng(3)
        slot_of = rng.permutation(E)
        inv = np.empty(E, int)
        inv[slot_of] = np.arange(E)
        p2 = dict(params)
        for nm in ("wi", "wg", "wo"):
            p2[nm] = params[nm][inv]
        pl = {"shadow_idx": jnp.full((2,), E, jnp.int32),
              "shadow_valid": jnp.zeros((2,), jnp.float32),
              "shadow_devs": jnp.zeros((2, 1), jnp.float32),
              "expert_slot": jnp.asarray(slot_of, jnp.int32)}

        def loss(p, pp):
            yy, _ = moe.moe_apply(p, x, pp, ctx, **kw)
            return jnp.sum(yy ** 2)

        y0, aux0 = moe.moe_apply(params, x, None, ctx, **kw)
        y2, aux2 = moe.moe_apply(p2, x, pl, ctx, **kw)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y2))
        assert float(aux0["dropped"]) == float(aux2["dropped"])
        g0 = jax.grad(loss)(params, None)
        g2 = jax.grad(loss)(p2, pl)
        for nm in ("wi", "wg", "wo"):
            np.testing.assert_array_equal(np.asarray(g0[nm]),
                                          np.asarray(g2[nm])[slot_of])

    def test_apply_relocation_identity_is_noop(self):
        from repro.configs import get_config, reduced
        from repro.optim import adamw
        from repro.parallel import local_ctx
        from repro.train import Trainer, relocate
        cfg = reduced(get_config("moe-gpt-s"))
        tr = Trainer(cfg, local_ctx(), adamw(1e-3), attn_impl="naive",
                     remat=False)
        state = tr.init_state(jax.random.PRNGKey(0))
        E = cfg.moe.num_experts
        gather = np.tile(np.arange(E, dtype=np.int32),
                         (cfg.num_moe_layers, 1))
        # snapshot first: apply_relocation donates (and deletes) its input
        before = [np.asarray(a) for a in jax.tree.leaves(state)]
        new = relocate.apply_relocation(state, cfg, gather)
        for a, b in zip(before, jax.tree.leaves(new)):
            np.testing.assert_array_equal(a, np.asarray(b))

    def test_restore_home_layout_roundtrip(self):
        """Relocate → restore_home_layout returns the state to the
        identity slot order bitwise (checkpoints are always saved in
        home order — a restored run binds a fresh engine)."""
        from repro.configs import get_config, reduced
        from repro.optim import adamw
        from repro.parallel import local_ctx
        from repro.train import Trainer, relocate
        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        eng = _mig_engine(layers=cfg.num_moe_layers, d=1,
                          e=cfg.moe.num_experts)
        tr = Trainer(cfg, ctx, adamw(1e-3), attn_impl="naive", remat=False,
                     engine=eng)
        state = tr.init_state(jax.random.PRNGKey(0))
        before = [np.asarray(a) for a in jax.tree.leaves(state)]
        E, L = cfg.moe.num_experts, cfg.num_moe_layers
        # pretend the engine executed a swap relocation earlier
        slot_of = np.arange(E)
        slot_of[0], slot_of[1] = slot_of[1], slot_of[0]
        gather = np.tile(np.argsort(slot_of).astype(np.int32), (L, 1))
        state = relocate.apply_relocation(state, cfg, gather)
        eng._device_slots = [slot_of.copy() for _ in range(L)]
        state = tr.restore_home_layout(state)
        assert eng.reset_layout() is None       # device back home
        for a, b in zip(before, jax.tree.leaves(state)):
            np.testing.assert_array_equal(a, np.asarray(b))

    def test_mid_run_relocation_loss_bit_identity(self):
        """Sync-runtime contract at the step level: permuting state with
        apply_relocation and dispatching with the matching expert_slot
        arrays mid-run leaves the loss trajectory bit-identical (no grad
        clipping: the step is exactly permutation-equivariant)."""
        from repro.configs import get_config, reduced
        from repro.data import SyntheticLM
        from repro.optim import adamw, cosine
        from repro.parallel import local_ctx
        from repro.train import Trainer, relocate
        from repro.train.trainer import make_train_step

        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        E, L = cfg.moe.num_experts, cfg.num_moe_layers
        opt = adamw(cosine(3e-3, 2, 6), clip_norm=None)
        tr = Trainer(cfg, ctx, opt, attn_impl="naive", remat=False)
        step_fn = make_train_step(cfg, ctx, opt, attn_impl="naive",
                                  remat=False, donate=False)
        import itertools
        data = list(itertools.islice(iter(SyntheticLM(cfg, batch=2, seq=16)),
                                     6))

        def arrays(slot_of):
            s_max = cfg.moe.s_max
            return {
                "shadow_idx": jnp.full((L, s_max), E, jnp.int32),
                "shadow_valid": jnp.zeros((L, s_max), jnp.float32),
                "shadow_devs": jnp.zeros((L, s_max, 1), jnp.float32),
                "expert_slot": jnp.tile(jnp.asarray(slot_of, jnp.int32),
                                        (L, 1)),
            }

        def batches():
            for b in data:
                yield {k: jnp.asarray(v) for k, v in b.items()}

        # baseline: identity layout throughout
        state = tr.init_state(jax.random.PRNGKey(0))
        base = []
        pl = arrays(np.arange(E))
        for b in batches():
            state, m = step_fn(state, b, pl)
            base.append(float(m["loss"]))

        # migrated: swap two experts after step 3 (state + dispatch form)
        slot_of = np.arange(E)
        slot_of[0], slot_of[-1] = slot_of[-1], slot_of[0]
        # device was at identity: gather[s] = expert occupying new slot s
        gather = np.tile(np.argsort(slot_of).astype(np.int32), (L, 1))
        state = tr.init_state(jax.random.PRNGKey(0))
        got = []
        for i, b in enumerate(batches()):
            if i == 3:
                state = relocate.apply_relocation(state, cfg, gather)
                pl = arrays(slot_of)
            state, m = step_fn(state, b, pl)
            got.append(float(m["loss"]))
        assert got == base

    def test_checkpoint_restore_onto_migrated_run_bit_identity(self,
                                                               tmp_path):
        """Checkpoint taken mid-run while experts are migrated: the save
        is in home order, a fresh run restoring it (with an
        identity-assuming engine) continues the loss trajectory
        bit-identically — and so does the original migrated run, i.e.
        checkpointing is numerically side-effect-free."""
        from repro.checkpoint import restore_latest, save_checkpoint
        from repro.configs import get_config, reduced
        from repro.data import SyntheticLM
        from repro.optim import adamw, cosine
        from repro.parallel import local_ctx
        from repro.train import relocate
        from repro.train.trainer import make_train_step

        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        E, L = cfg.moe.num_experts, cfg.num_moe_layers
        opt = adamw(cosine(3e-3, 2, 6), clip_norm=None)
        step_fn = make_train_step(cfg, ctx, opt, attn_impl="naive",
                                  remat=False, donate=False)
        rfn = relocate.make_relocate_fn(cfg, donate=False)
        import itertools
        data = list(itertools.islice(iter(SyntheticLM(cfg, batch=2,
                                                      seq=16)), 6))

        def arrays(slot_of):
            s_max = cfg.moe.s_max
            return {
                "shadow_idx": jnp.full((L, s_max), E, jnp.int32),
                "shadow_valid": jnp.zeros((L, s_max), jnp.float32),
                "shadow_devs": jnp.zeros((L, s_max, 1), jnp.float32),
                "expert_slot": jnp.tile(jnp.asarray(slot_of, jnp.int32),
                                        (L, 1)),
            }

        def init():
            from repro.train import Trainer
            return Trainer(cfg, ctx, opt, attn_impl="naive",
                           remat=False).init_state(jax.random.PRNGKey(0))

        # baseline: identity layout throughout
        state, base = init(), []
        for b in data:
            state, m = step_fn(state, b, arrays(np.arange(E)))
            base.append(float(m["loss"]))

        # migrated run: swap at step 3, checkpoint (home order) after 4
        slot_of = np.arange(E)
        slot_of[0], slot_of[-1] = slot_of[-1], slot_of[0]
        gather = np.tile(np.argsort(slot_of).astype(np.int32), (L, 1))
        gather_home = np.tile(slot_of.astype(np.int32), (L, 1))
        state, got = init(), []
        root = str(tmp_path / "ckpts")
        for i, b in enumerate(data[:4]):
            if i == 3:
                state = relocate.apply_relocation(state, cfg, gather,
                                                  relocate_fn=rfn)
            state, m = step_fn(state, b, arrays(slot_of if i >= 3
                                                else np.arange(E)))
            got.append(float(m["loss"]))
        home = relocate.apply_relocation(state, cfg, gather_home,
                                         relocate_fn=rfn)
        save_checkpoint(home, root, step=4,
                        extra={"expert_layout": "home"})
        # original run continues, still migrated
        for b in data[4:]:
            state, m = step_fn(state, b, arrays(slot_of))
            got.append(float(m["loss"]))
        assert got == base

        # fresh run restores the checkpoint and continues at home layout
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.asarray(x).shape,
                                           np.asarray(x).dtype), home)
        restored, meta, _ = restore_latest(like, root)
        assert meta["step"] == 4 and meta["expert_layout"] == "home"
        resumed = []
        for b in data[4:]:
            restored, m = step_fn(restored, b, arrays(np.arange(E)))
            resumed.append(float(m["loss"]))
        assert resumed == base[4:]


# ---------------------------------------------------------------------------
# Fast-lane CI guard: migration-disabled trainer ≡ pre-migration numerics
# ---------------------------------------------------------------------------

class TestDisabledPathGuard:
    def test_disabled_trainer_matches_slotless_arrays(self):
        """With migration off, the dispatched expert_slot arrays are
        identity — stripping the key entirely (the pre-migration array
        set) must be bit-identical in losses.  Guards the --fast lane
        against numeric drift from the owner threading without the
        subprocess tests."""
        from repro.configs import get_config, reduced
        from repro.data import SyntheticLM
        from repro.optim import adamw, cosine
        from repro.parallel import local_ctx
        from repro.train import Trainer
        from repro.train.trainer import make_engine_for

        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        steps = 6

        def run(strip_slots):
            eng = make_engine_for(cfg, ctx)
            assert eng.migration_enabled is False
            if strip_slots:
                orig = eng.step_arrays

                def slotless():
                    arrs = orig()
                    arrs.pop("expert_slot")
                    return arrs
                eng.step_arrays = slotless
            tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 2, steps)),
                         attn_impl="naive", remat=False, engine=eng,
                         async_plan=False)
            state = tr.init_state(jax.random.PRNGKey(0))
            data = SyntheticLM(cfg, batch=2, seq=16)
            sink = []
            _, hist = tr.run(state, data, num_steps=steps, log_every=0,
                             stats_sink=sink)
            assert all(s.relocations == 0 for s in sink)
            return hist

        assert run(False) == run(True)


# ---------------------------------------------------------------------------
# Aux loss regression (satellite): top-k dispatch fractions
# ---------------------------------------------------------------------------

class TestLoadBalanceLossTopK:
    def test_hand_computed_top2(self):
        """3 tokens, 4 experts, k=2: dispatch fractions must count BOTH
        choices, each normalized by k·N = 6."""
        from repro.models.moe import load_balance_loss
        probs = jnp.array([[0.4, 0.3, 0.2, 0.1],
                           [0.1, 0.4, 0.3, 0.2],
                           [0.25, 0.25, 0.25, 0.25]])
        idx = jnp.array([[0, 1], [1, 2], [1, 3]], jnp.int32)
        me = np.asarray(probs).mean(0)
        ce = np.array([1, 3, 1, 1]) / 6.0       # selections per expert / kN
        expect = 4 * float(np.sum(me * ce))
        got = float(load_balance_loss(probs, idx, 4))
        assert got == pytest.approx(expect, rel=1e-6)
        # the old idx[..., 0]-only version would see ce = [1,2,0,0]/3
        wrong = 4 * float(np.sum(me * np.array([1, 2, 0, 0]) / 3.0))
        assert got != pytest.approx(wrong, rel=1e-3)

    def test_top1_unchanged(self):
        """k=1 must reproduce the original first-choice-only math."""
        from repro.models.moe import load_balance_loss
        key = jax.random.PRNGKey(0)
        probs = jax.nn.softmax(jax.random.normal(key, (2, 5, 4)), -1)
        idx = jnp.argmax(probs, -1, keepdims=True).astype(jnp.int32)
        got = float(load_balance_loss(probs, idx, 4))
        onehot = jax.nn.one_hot(idx[..., 0], 4)
        ce = onehot.mean(axis=(0, 1))
        me = probs.mean(axis=(0, 1))
        assert got == pytest.approx(float(4 * jnp.sum(me * ce)), rel=1e-6)
