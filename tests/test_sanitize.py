"""Runtime sanitizer (REPRO_SANITIZE=1): transfer guard, debug lanes,
torn-read assertions, and the sanitized trainer smoke run.

The sanitizer is the *dynamic* twin of prophetlint (tests in
test_prophetlint.py): the static rules prove the source holds the
hot-path invariants; this lane proves a real training run does —
no implicit host transfer inside the dispatch guard, no NaN/inf
slipping through the debug lanes, no torn placement read.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import SyntheticLM
from repro.optim import adamw, cosine
from repro.parallel import local_ctx
from repro.train import Trainer, sanitize
from repro.train.runtime import PlacementCache
from repro.train.sanitize import TornReadError
from repro.train.trainer import make_engine_for


@pytest.fixture
def sanitized(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    yield
    # arm() flips process-level jax debug config; put it back so later
    # tests don't pay the debug-lane overhead
    jax.config.update("jax_debug_nans", False)
    jax.config.update("jax_debug_infs", False)


# ---------------------------------------------------------------------------
# dispatch_guard / arm
# ---------------------------------------------------------------------------

class TestGuards:
    def test_off_by_default(self, monkeypatch):
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        assert sanitize.arm() is False
        with sanitize.dispatch_guard():
            # implicit host→device transfer is fine when not sanitizing
            jnp.sin(np.arange(4.0)).block_until_ready()

    def test_guard_blocks_implicit_transfer(self, sanitized):
        assert sanitize.arm() is True
        with pytest.raises(Exception, match="[Dd]isallow"):
            with sanitize.dispatch_guard():
                jnp.sin(np.arange(4.0)).block_until_ready()

    def test_guard_scoped_to_context(self, sanitized):
        with sanitize.dispatch_guard():
            pass
        # outside the guard the same transfer is fine again
        jnp.sin(np.arange(4.0)).block_until_ready()

    def test_debug_lanes_armed(self, sanitized):
        sanitize.arm()
        assert jax.config.jax_debug_nans
        assert jax.config.jax_debug_infs


# ---------------------------------------------------------------------------
# PlacementCache torn-read assertions
# ---------------------------------------------------------------------------

class _RacyEngine:
    """Fake engine whose placements_version moves *during* step_arrays —
    the torn re-pack the submit→wait contract is supposed to prevent."""

    def __init__(self):
        self._v = 0

    @property
    def placements_version(self):
        return self._v

    def step_arrays(self):
        self._v += 1            # concurrent planner bump, mid-pack
        return {"expert_devs": np.zeros((2, 4), np.int32)}


class _StableEngine:
    placements_version = 7

    def step_arrays(self):
        return {"expert_devs": np.zeros((2, 4), np.int32)}


class TestTornRead:
    def test_mid_pack_version_bump_raises(self, sanitized):
        cache = PlacementCache(_RacyEngine())
        with pytest.raises(TornReadError, match="during the placement"):
            cache.arrays_for_dispatch()

    def test_cross_thread_consumption_raises(self, sanitized):
        cache = PlacementCache(_StableEngine())
        cache.arrays_for_dispatch()          # binds the dispatch thread
        errs = []

        def consume():
            try:
                cache.arrays_for_dispatch()
            except TornReadError as e:
                errs.append(e)

        t = threading.Thread(target=consume)
        t.start()
        t.join()
        assert len(errs) == 1
        assert "thread" in str(errs[0])

    def test_clean_usage_passes(self, sanitized):
        cache = PlacementCache(_StableEngine())
        a = cache.arrays_for_dispatch()
        b = cache.arrays_for_dispatch()      # cached path, same thread
        assert a is b

    def test_not_armed_without_flag(self, monkeypatch):
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        cache = PlacementCache(_RacyEngine())
        cache.arrays_for_dispatch()          # racy, but not asserted


# ---------------------------------------------------------------------------
# Sanitized trainer smoke (the acceptance lane)
# ---------------------------------------------------------------------------

class TestSanitizedTrainer:
    @pytest.mark.parametrize("async_mode", [False, True])
    def test_smoke_run_clean(self, sanitized, async_mode):
        """A short Pro-Prophet run on the fast sim config with the full
        sanitizer armed: any disallowed host transfer on the dispatch
        path, NaN/inf in the step, or torn placement read faults the
        run."""
        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        steps = 6
        tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 2, steps)),
                     attn_impl="naive", remat=False,
                     engine=make_engine_for(cfg, ctx),
                     async_plan=async_mode)
        state = tr.init_state(jax.random.PRNGKey(0))
        data = SyntheticLM(cfg, batch=2, seq=16)
        state, hist = tr.run(state, data, num_steps=steps, log_every=0)
        assert len(hist) == steps
        assert all(np.isfinite(h) for h in hist)
