"""Predictive load planning (scripts/ci.sh --forecast).

Pins the forecast-driven runtime end to end:

* :class:`repro.core.forecast.LoadForecaster` property tests — constant
  loads are a *bitwise* EMA fixed point with drift exactly 0.0; a step
  change re-flags the layer ``fluctuating`` within one update and resets
  the calm counter;
* the engine's plan-cadence backoff — stable layers skip the Plan
  primitive (exponential backoff bounded by ``plan_cadence_max``),
  drift resets the interval, snapshot/restore round-trips the forecast
  state for watchdog rollback;
* the :func:`benchmarks.simlib.forecast_sweep` acceptance ratios from
  ROADMAP.md (≥2× fewer plans, ≥2× fewer relocation-blocked dispatches,
  modeled step time no worse) plus the cadence-aware accounting that
  makes the ``host_overlap`` forecast rows comparable;
* the trainer acceptance run — async runtime + forecast backoff +
  prefetched relocation produces a loss history *bit-identical* to the
  fully-synchronous per-step-planning baseline (placements and
  relocation timing only move compute).
"""
import dataclasses
import os
import sys

import numpy as np
import pytest

from repro.core import HardwareSpec, ProProphetEngine, guard
from repro.core.engine import EngineConfig
from repro.core.forecast import PHASES, LoadForecaster

# benchmarks/ lives at the repo root (outside src/) — mirror the
# `python -m pytest` cwd insertion for bare `pytest` invocations.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def _hw():
    return HardwareSpec.from_model_dims(512, 1024, bandwidth=25e9,
                                        flops_per_s=70e12)


def _engine(layers=2, d=4, e=8, **over):
    kw = dict(num_experts=e, num_devices=d, num_moe_layers=layers,
              s_max=4, replan_interval=1, policy="pro_prophet",
              enable_forecast=True, plan_cadence_max=8)
    kw.update(over)
    return ProProphetEngine(EngineConfig(**kw), _hw())


def _loads(d=4, e=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, 100, size=(d, e)).astype(np.float64)


# ---------------------------------------------------------------------------
# Forecaster property tests
# ---------------------------------------------------------------------------

class TestForecasterProperties:
    @pytest.mark.parametrize("decay", [0.0, 0.3, 0.5, 0.9])
    def test_constant_loads_zero_drift_bitwise_fixed_point(self, decay):
        """Constant loads ⇒ drift exactly 0.0 and the EMA bitwise equal
        to the observation for ANY decay (the ``ema + (1-decay)*(g-ema)``
        update has a correction term of exactly zero at the fixed
        point), reaching ``stable`` after ``patience`` calm updates."""
        fc = LoadForecaster(4, 8, decay=decay, patience=3)
        g = _loads()
        assert fc.update(g) == "fluctuating"          # cold start
        assert (fc.predict() == g).all()
        phases = [fc.update(g) for _ in range(5)]
        assert fc.drift == 0.0                        # exactly, not approx
        assert (fc.predict() == g).all()              # bitwise fixed point
        assert phases == ["drifting", "drifting", "stable", "stable",
                          "stable"]

    def test_step_change_flags_fluctuating_within_one_update(self):
        fc = LoadForecaster(2, 4, patience=2)
        g = np.full((2, 4), 25.0)
        for _ in range(4):
            fc.update(g)
        assert fc.phase == "stable"
        shifted = np.zeros((2, 4))
        shifted[:, 0] = 100.0                         # all mass moves
        assert fc.update(shifted) == "fluctuating"
        assert fc.drift > fc.drift_threshold
        # calm counter reset: stability must be re-earned over the full
        # patience window, not resumed
        calm_again = [fc.update(fc.predict()) for _ in range(fc.patience)]
        assert calm_again[-1] != "stable" or len(calm_again) >= fc.patience

    def test_zero_decay_is_last_value_predictor(self):
        fc = LoadForecaster(2, 4, decay=0.0)
        g1, g2 = _loads(2, 4, seed=1), _loads(2, 4, seed=2)
        fc.update(g1)
        fc.update(g2)
        assert (fc.predict() == g2).all()

    def test_predict_none_before_observation_and_returns_copy(self):
        fc = LoadForecaster(2, 4)
        assert fc.predict() is None
        g = _loads(2, 4)
        fc.update(g)
        p = fc.predict()
        p[:] = -1.0
        assert (fc.predict() == g).all()              # internal EMA intact

    def test_snapshot_restore_roundtrip(self):
        fc = LoadForecaster(2, 4, patience=1)
        for s in (1, 1, 1):
            fc.update(_loads(2, 4, seed=s))
        snap = fc.snapshot()
        ema0, phase0, drift0 = fc.predict(), fc.phase, fc.drift
        fc.update(_loads(2, 4, seed=9) * 100.0)       # perturb
        assert fc.phase != phase0 or fc.drift != drift0 \
            or not (fc.predict() == ema0).all()
        fc.restore(snap)
        assert (fc.predict() == ema0).all()
        assert fc.phase == phase0 and fc.drift == drift0
        assert fc.phase in PHASES

    def test_parameter_validation(self):
        with pytest.raises(AssertionError):
            LoadForecaster(2, 4, decay=1.0)           # frozen EMA
        with pytest.raises(AssertionError):
            LoadForecaster(2, 4, stable_threshold=0.5,
                           drift_threshold=0.4)       # inverted bands


# ---------------------------------------------------------------------------
# Engine cadence backoff
# ---------------------------------------------------------------------------

class TestEngineCadenceBackoff:
    def test_stable_trace_backs_off_and_is_bounded(self):
        eng = _engine(layers=2, plan_cadence_max=8)
        gs = [_loads(seed=0), _loads(seed=1)]
        iters = 40
        for _ in range(iters):
            eng.observe(gs)
        total = iters * 2
        assert eng.plans_executed + eng.plans_skipped == total
        # constant loads go stable fast; backoff must cut plans well
        # below the per-step count (acceptance shape: ≥2× fewer; the
        # exact count follows the doubling schedule)
        assert eng.plans_executed <= total // 4
        assert all(1 <= iv <= 8 for iv in eng._plan_interval)
        assert eng.last_plan_info["stable"] == 2
        guard.validate_engine(eng)

    def test_drift_resets_cadence_and_replans_immediately(self):
        eng = _engine(layers=1, plan_cadence_max=8)
        g = _loads()
        for _ in range(20):
            eng.observe([g])
        assert eng._plan_interval[0] > 1               # backed off
        shifted = np.roll(g, 3, axis=1) * 4.0          # big step change
        eng.observe([shifted])
        assert eng.forecasters[0].phase == "fluctuating"
        assert eng._plan_interval[0] == 1              # reset to base
        assert eng.last_plan_info["planned"] == 1      # replanned now

    def test_snapshot_restore_roundtrips_forecast_state(self):
        eng = _engine(layers=2)
        g = _loads()
        for _ in range(10):
            eng.observe([g, g * 2.0])
        snap = eng.snapshot()
        intervals = list(eng._plan_interval)
        counters = (eng.plans_executed, eng.plans_skipped)
        phases = [fc.phase for fc in eng.forecasters]
        emas = [fc.predict() for fc in eng.forecasters]
        for s in (5, 6, 7):                            # churn everything
            eng.observe([_loads(seed=s) * 50, _loads(seed=s + 1) * 50])
        eng.restore(snap)
        assert list(eng._plan_interval) == intervals
        assert (eng.plans_executed, eng.plans_skipped) == counters
        assert [fc.phase for fc in eng.forecasters] == phases
        for fc, ema in zip(eng.forecasters, emas):
            assert (fc.predict() == ema).all()
        guard.validate_engine(eng)

    def test_disabled_path_leaves_forecasters_cold(self):
        """enable_forecast=False must be bit-identical to the last-value
        planner: the forecasters never ingest anything and every
        observation plans at the base cadence."""
        eng = _engine(layers=2, enable_forecast=False)
        for _ in range(5):
            eng.observe([_loads(seed=0), _loads(seed=1)])
        assert all(fc.predict() is None for fc in eng.forecasters)
        assert all(fc.phase == "fluctuating" for fc in eng.forecasters)
        assert eng.plans_executed == 10                # replan_interval=1


# ---------------------------------------------------------------------------
# Simulated acceptance: forecast_sweep ratios + cadence accounting
# ---------------------------------------------------------------------------

class TestForecastSweepAcceptance:
    def test_acceptance_ratios_on_stabilizing_trace(self):
        """ROADMAP acceptance: on the fluctuating→stabilizing trace the
        forecast variant executes ≥2× fewer Plan primitives AND suffers
        ≥2× fewer relocation-blocked dispatches than fixed-cadence
        per-step planning, with modeled step time no worse."""
        from benchmarks.forecast import SWEEP
        from benchmarks.simlib import SimConfig, forecast_sweep
        out = forecast_sweep(SimConfig(iters=30), **SWEEP)
        f, o = out["fixed"], out["forecast"]
        assert f["plans"] >= 2.0 * o["plans"]
        assert f["reloc_blocked"] >= 2.0               # baseline pays
        assert f["reloc_blocked"] >= 2.0 * o["reloc_blocked"]
        assert o["step_s"] <= f["step_s"] * 1.05       # no slower
        acc = out["accuracy"]
        # EMA forecast is no worse than last-value on stabilizing loads
        assert acc["ema"] <= acc["last"] * 1.05
        assert np.isfinite(acc["ema"]) and acc["ema"] >= 0.0

    def test_host_overlap_cadence_accounting_comparable(self):
        """Satellite: host_overlap's forecast rows report plans at the
        same per-iteration granularity as the fixed-cadence baseline, so
        the backoff rows in benchmarks/cadence.py actually compare."""
        from benchmarks.simlib import SimConfig, host_overlap
        sim = SimConfig(iters=6)
        ov = host_overlap(sim, 2e-3, iters=6)
        ovf = host_overlap(sim, 2e-3, iters=6, forecast=True)
        for d in (ov, ovf):
            assert "plans_per_iter" in d and "uploads" in d
            assert d["plans_per_iter"] >= 0.0
        assert ovf["plans_per_iter"] <= ov["plans_per_iter"]


# ---------------------------------------------------------------------------
# Trainer acceptance: forecast + prefetch ≡ per-step sync, bit-identical
# ---------------------------------------------------------------------------

class TestTrainerForecastBitIdentity:
    def test_forecast_prefetch_loss_bit_identical_to_sync(self):
        """Async runtime + forecast cadence backoff + prefetched
        relocation vs the fully-synchronous per-step-planning baseline:
        identical seeds/batches ⇒ bit-identical loss histories.
        Placements and relocation *timing* only decide where compute
        happens (no grad clipping: the step is exactly
        permutation-equivariant), so skipping plans and staging
        exchanges ahead must not move a single bit of the loss."""
        import jax

        from repro.configs import get_config, reduced
        from repro.data import SyntheticLM
        from repro.optim import adamw, cosine
        from repro.parallel import local_ctx
        from repro.train import Trainer
        from repro.train.runtime import OverlapTelemetry
        from repro.train.trainer import make_engine_for

        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()
        steps = 14
        opt = adamw(cosine(3e-3, 4, steps), clip_norm=None)
        tr = Trainer(cfg, ctx, opt, attn_impl="naive", remat=False,
                     engine=make_engine_for(cfg, ctx, migration=True))

        def run(engine, async_mode, prefetch):
            # same compiled step, fresh engine + runtime state per mode
            tr.engine = engine
            tr.async_plan = async_mode
            tr.reloc_prefetch = prefetch
            tr._prefetch = prefetch
            tr._staged = tr._want_stage = None
            tr._reloc_hold = False
            tr._reloc_attempts = 0
            state = tr.init_state(jax.random.PRNGKey(0))
            data = SyntheticLM(cfg, batch=4, seq=32)
            sink, tel = [], OverlapTelemetry()
            state, hist = tr.run(state, data, num_steps=steps, log_every=0,
                                 stats_sink=sink, telemetry=tel)
            return hist, sink, tel

        sync_eng = make_engine_for(cfg, ctx, migration=True)
        hist_s, sink_s, _ = run(sync_eng, False, False)

        # generous thresholds + patience 1 so real (noisy) routing still
        # goes stable and the backoff demonstrably engages
        fc_cfg = dataclasses.replace(
            sync_eng.cfg, enable_forecast=True, forecast_patience=1,
            forecast_stable_threshold=0.9, forecast_drift_threshold=0.95,
            plan_cadence_max=4)
        fore_eng = ProProphetEngine(fc_cfg, sync_eng.perf.hw)
        hist_f, sink_f, tel = run(fore_eng, True, True)

        assert hist_s == hist_f                        # bit-identical
        assert len(sink_s) == len(sink_f) == steps
        s = tel.summary()
        assert s["plans_skipped"] > 0                  # backoff engaged
        assert s["relocation_persistent"] == 0
        assert fore_eng.plans_executed < sync_eng.plans_executed
        guard.validate_engine(fore_eng)
