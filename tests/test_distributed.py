"""Multi-device integration tests: spawn subprocesses with 8 host devices
(XLA device count must be set before jax initializes, hence subprocess)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))


def run_dist_script(name: str, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist", name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"--- stdout ---\n{proc.stdout[-3000:]}\n"
        f"--- stderr ---\n{proc.stderr[-3000:]}")
    return proc.stdout


@pytest.mark.slow
def test_ep_equivalence_and_training_parity():
    out = run_dist_script("ep_equivalence.py")
    assert "EP_EQUIVALENCE_PASS" in out
    assert "TRAINING_PARITY_PASS" in out


@pytest.mark.slow
def test_async_runtime_mesh_equivalence():
    """Async pipelined runtime ≡ serial baseline on a (2, 4) mesh:
    identical loss history and per-step placement arrays."""
    out = run_dist_script("async_equivalence.py")
    assert "ASYNC_EQUIVALENCE_MESH_PASS" in out


@pytest.mark.slow
def test_moe_pallas_mesh_equivalence():
    """REPRO_MOE_PALLAS on/off parity through shard_map over skewed
    routing (the ragged Pallas FEC/BEC vs the dense einsum)."""
    out = run_dist_script("moe_pallas_equivalence.py")
    assert "MOE_PALLAS_MESH_EQUIVALENCE_PASS" in out


@pytest.mark.slow
def test_dispatch_pallas_mesh_equivalence():
    """REPRO_DISPATCH_PALLAS on/off parity through shard_map over skewed
    routing and a live shadow placement (the Pallas token-permutation
    dispatch/combine vs the jnp scatter/gather), serial and K=2 chunked,
    forward and backward."""
    out = run_dist_script("dispatch_equivalence.py", timeout=900)
    assert "DISPATCH_MESH_EQUIVALENCE_PASS" in out


@pytest.mark.slow
def test_migration_mesh_equivalence():
    """Dynamic expert migration on a (2, 4) mesh: migrated layouts are
    bit-identical at the layer level, and a persistent-skew trainer run
    selects ≥1 migration, executes the EP-axis relocation, and keeps the
    loss history bit-identical to the migration-disabled run."""
    out = run_dist_script("migration_equivalence.py", timeout=900)
    assert "MIGRATION_LAYER_EQUIVALENCE_PASS" in out
    assert "MIGRATION_TRAINER_EQUIVALENCE_PASS" in out


@pytest.mark.slow
def test_chunked_a2a_mesh_equivalence():
    """Chunked a2a↔FEC pipeline on a (2, 4) mesh: K>1 bit-identical
    forward / round-off-equal backward at the layer level, K=1 trainer
    runs bit-identical to the engine-driven default over 8 steps, K=2
    showing modeled hidden comm and a lower chunked timeline makespan."""
    out = run_dist_script("chunked_equivalence.py", timeout=900)
    assert "CHUNKED_LAYER_EQUIVALENCE_PASS" in out
    assert "CHUNKED_TRAINER_EQUIVALENCE_PASS" in out


@pytest.mark.slow
def test_health_mesh_equivalence():
    """Degraded-mode runtime on a (2, 4) mesh: an injected device_loss
    on EP rank 2 is classified lost after the patience window, every
    expert is evacuated off the rank within one plan cadence (remote
    load exactly zero, no shadow on the lost rank), and the loss
    history — including the final, fully-evacuated step — stays
    bit-identical to the fault-free run."""
    out = run_dist_script("health_equivalence.py", timeout=900)
    assert "HEALTH_EQUIVALENCE_PASS" in out
