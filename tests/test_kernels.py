"""Pallas kernel sweeps vs the ref.py jnp oracles (interpret=True on CPU).

Shapes deliberately include non-multiples of the tile sizes (padding paths)
and both f32 / bf16.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


class TestGMM:
    @pytest.mark.parametrize("shape", [
        (1, 8, 16, 8), (2, 64, 32, 48), (3, 130, 128, 128), (1, 256, 96, 200),
        (4, 17, 33, 65),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        g, t, d, f = shape
        x = jax.random.normal(KEY, (g, t, d), dtype)
        w = jax.random.normal(jax.random.PRNGKey(1), (g, d, f), dtype)
        got = ops.gmm(x, w, bt=64, bf=64, bd=32)
        want = ref.gmm_ref(x, w)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_block_shape_invariance(self):
        x = jax.random.normal(KEY, (2, 100, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 72))
        y1 = ops.gmm(x, w, bt=32, bf=32, bd=32)
        y2 = ops.gmm(x, w, bt=128, bf=128, bd=64)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)

    def test_zero_group(self):
        x = jnp.zeros((2, 16, 8))
        w = jax.random.normal(KEY, (2, 8, 8))
        assert float(jnp.abs(ops.gmm(x, w)).max()) == 0.0


class TestFlashAttention:
    @pytest.mark.parametrize("shape", [(2, 128, 64), (3, 200, 32),
                                       (1, 64, 128)])
    @pytest.mark.parametrize("window", [None, 64, 17])
    def test_matches_ref(self, shape, window):
        bh, s, dh = shape
        q = 0.3 * jax.random.normal(KEY, (bh, s, dh))
        k = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (bh, s, dh))
        v = jax.random.normal(jax.random.PRNGKey(3), (bh, s, dh))
        got = ops.flash_attention(q, k, v, causal=True, window=window,
                                  bq=64, bk=64)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)

    def test_noncausal(self):
        q = 0.3 * jax.random.normal(KEY, (2, 96, 32))
        k = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (2, 96, 32))
        v = jax.random.normal(jax.random.PRNGKey(3), (2, 96, 32))
        got = ops.flash_attention(q, k, v, causal=False, bq=32, bk=32)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)

    def test_grouped_head_contract(self):
        """5-D (B,S,K,G,dh) wrapper vs per-head reference."""
        B, S, K, G, dh = 1, 64, 2, 2, 32
        q = 0.3 * jax.random.normal(KEY, (B, S, K, G, dh))
        k = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (B, S, K, dh))
        v = jax.random.normal(jax.random.PRNGKey(3), (B, S, K, dh))
        got = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
        assert got.shape == (B, S, K, G, dh)
        from repro.models.attention import _naive
        want = _naive(q, k, v, causal=True, window=None, scale=dh ** -0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)

    def test_bf16(self):
        q = (0.3 * jax.random.normal(KEY, (2, 128, 64))).astype(jnp.bfloat16)
        k = (0.3 * jax.random.normal(jax.random.PRNGKey(2),
                                     (2, 128, 64))).astype(jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(3),
                              (2, 128, 64)).astype(jnp.bfloat16)
        got = ops.flash_attention(q, k, v, bq=64, bk=64)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)
