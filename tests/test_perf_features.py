"""§Perf levers must be numerically inert: chunked xent, attention-impl
switches; plus the dry-run HLO collective parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import (chunked_unembed_xent, cross_entropy_loss,
                                 embedding_init, unembed)


class TestChunkedXent:
    @pytest.mark.parametrize("chunk", [7, 64, 512, 1000])
    def test_matches_dense(self, chunk):
        V, d, B, S = 300, 32, 2, 9
        key = jax.random.PRNGKey(0)
        emb = embedding_init(key, V, d)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
        labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
        dense = cross_entropy_loss(unembed(emb, x), labels)
        chunked = chunked_unembed_xent(x, emb["table"], labels, chunk)
        np.testing.assert_allclose(float(dense), float(chunked), rtol=1e-5)

    def test_mask(self):
        V, d, B, S = 64, 16, 2, 8
        emb = embedding_init(jax.random.PRNGKey(0), V, d)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
        labels = jnp.zeros((B, S), jnp.int32)
        mask = jnp.zeros((B, S)).at[:, :3].set(1.0)
        dense = cross_entropy_loss(unembed(emb, x), labels, mask)
        chunked = chunked_unembed_xent(x, emb["table"], labels, 16, mask)
        np.testing.assert_allclose(float(dense), float(chunked), rtol=1e-5)

    def test_grads_match(self):
        V, d, B, S = 128, 16, 1, 6
        emb = embedding_init(jax.random.PRNGKey(0), V, d)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
        labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
        g1 = jax.grad(lambda e: cross_entropy_loss(unembed(e, x), labels))(emb)
        g2 = jax.grad(lambda e: chunked_unembed_xent(x, e["table"], labels,
                                                     32))(emb)
        np.testing.assert_allclose(np.asarray(g1["table"]),
                                   np.asarray(g2["table"]),
                                   rtol=2e-4, atol=1e-6)


class TestCollectiveParser:
    def test_parses_kinds_and_bytes(self):
        from repro.launch.dryrun import collective_bytes
        hlo = """
  %all-gather.1 = f32[16,4096,128]{2,1,0} all-gather(%x), dimensions={0}
  %ar = bf16[8,1024]{1,0} all-reduce(%y), to_apply=%add
  ROOT %out = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b)
  %rs = f32[2,2]{1,0} reduce-scatter(%c), dimensions={0}
  %cp.2 = u32[10]{0} collective-permute-start(%d)
  %notacoll = f32[4]{0} add(%e, %f)
"""
        got = collective_bytes(hlo)
        assert got["all-gather"] == 16 * 4096 * 128 * 4
        assert got["all-reduce"] == 8 * 1024 * 2
        assert got["all-to-all"] == 2 * 16 * 4
        assert got["reduce-scatter"] == 4 * 4
        assert got["collective-permute"] == 10 * 4
        assert got["count"] == 5


class TestAttnImplFlag:
    def test_naive_max_env(self):
        from repro.models import attention as attn
        key = jax.random.PRNGKey(0)
        p = attn.attention_init(key, 32, 2, 2, 16)
        x = 0.3 * jax.random.normal(key, (1, 96, 32))
        pos = jnp.broadcast_to(jnp.arange(96), (1, 96))
        kw = dict(num_heads=2, num_kv_heads=2, head_dim=16)
        os.environ["REPRO_ATTN_NAIVE_MAX"] = "64"
        try:
            y_chunk_path = attn.multihead_attention(p, x, pos, impl="auto",
                                                    **kw)
        finally:
            del os.environ["REPRO_ATTN_NAIVE_MAX"]
        y_naive = attn.multihead_attention(p, x, pos, impl="naive", **kw)
        np.testing.assert_allclose(np.asarray(y_chunk_path),
                                   np.asarray(y_naive), rtol=2e-4, atol=2e-5)
