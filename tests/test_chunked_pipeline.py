"""Chunked a2a↔FEC pipelining: engine chunk choice, perfmodel coupling,
trainer dispatch, and telemetry (the §V scheduler realized on-device).

Device-path numerics live in tests/test_moe.py (single device) and
tests/dist/chunked_equivalence.py (mesh subprocess); this module covers
the host-side machinery that picks and reports K.
"""
import jax
import numpy as np
import pytest

from repro import flags
from repro.core import (EngineConfig, HardwareSpec, PerfModel,
                        ProProphetEngine, chunked_makespan)
from repro.train.runtime import OverlapTelemetry, StepStats


def _engine(bandwidth=5e9, flops=100e12, layers=2, d=4, e=8, **kw):
    hw = HardwareSpec.from_model_dims(512, 1024, bandwidth=bandwidth,
                                      flops_per_s=flops, num_ffn_mats=3)
    cfg = EngineConfig(num_experts=e, num_devices=d, num_moe_layers=layers,
                       s_max=4, **kw)
    return ProProphetEngine(cfg, hw)


def _skewed(d=4, e=8, hot=0, tokens=5000.0):
    g = np.full((d, e), 500.0)
    g[:, hot] = tokens
    return g


class TestEngineChunkPlan:
    def test_k1_before_any_stats(self):
        eng = _engine()
        assert eng.chunk_plan() == [1, 1]

    def test_comm_heavy_stats_pick_k_above_one(self):
        eng = _engine(bandwidth=5e9, flops=100e12)
        eng.observe([_skewed(), _skewed(hot=3)])
        plan = eng.chunk_plan()
        assert all(k > 1 for k in plan)
        assert all(k in eng.cfg.a2a_chunk_candidates for k in plan)

    def test_tiny_a2a_keeps_bit_identical_path(self):
        # compute-bound profile: the 2·t_a2a/K saving is below the
        # per-chunk launch overhead, so the chooser stays at K=1
        eng = _engine(bandwidth=1e13, flops=1e12)
        eng.observe([_skewed(), _skewed()])
        assert eng.chunk_plan() == [1, 1]

    def test_flag_override(self, monkeypatch):
        eng = _engine()
        eng.observe([_skewed(), _skewed()])
        monkeypatch.setenv("REPRO_A2A_CHUNKS", "3")
        assert eng.chunk_plan() == [3, 3]
        assert flags.a2a_chunks() == 3

    def test_chunk_stats_surface(self):
        eng = _engine(bandwidth=5e9, flops=100e12)
        # before stats: empty but well-formed
        s0 = eng.chunk_stats()
        assert s0["comm_hidden_frac"] == 0.0 and s0["a2a_gbytes"] == 0.0
        eng.observe([_skewed(), _skewed()])
        s = eng.chunk_stats([2, 2])
        assert s["chunked_s"] < s["serial_s"]
        assert 0.0 < s["comm_hidden_frac"] <= 1.0
        assert s["a2a_gbytes"] > 0.0
        assert s["mean_chunks"] == 2.0
        # K=1 plan models zero hidden comm
        s1 = eng.chunk_stats([1, 1])
        assert s1["comm_hidden_frac"] == 0.0
        assert s1["chunked_s"] == pytest.approx(s1["serial_s"])


class TestPerfModelCoupling:
    def test_k1_reproduces_eq8(self):
        """layer_time_chunked(K=1) must equal layer_time_scheduled — the
        model analog of the device path's K=1 bit-identity."""
        hw = HardwareSpec.from_model_dims(512, 1024, bandwidth=10e9,
                                          flops_per_s=35e12, t_fnec=1e-3,
                                          t_bnec=2e-3)
        pm = PerfModel(hw, 16)
        rng = np.random.default_rng(0)
        for _ in range(10):
            R = rng.uniform(0, 4000, size=16)
            H = rng.uniform(100, 8000, size=16)
            s, n = int(rng.integers(0, 8)), int(rng.integers(0, 4))
            assert pm.layer_time_chunked(R, H, s, n, 1) == pytest.approx(
                pm.layer_time_scheduled(R, H, s, n), rel=1e-12)

    def test_chunking_never_hurts_the_model(self):
        hw = HardwareSpec.from_model_dims(512, 1024, bandwidth=10e9,
                                          flops_per_s=35e12)
        pm = PerfModel(hw, 16)
        R = np.full(16, 4000.0)
        H = np.full(16, 4000.0)
        ts = [pm.layer_time_chunked(R, H, 2, 0, k) for k in (1, 2, 4, 8)]
        assert all(b <= a + 1e-15 for a, b in zip(ts, ts[1:]))
        assert ts[1] < ts[0]          # skewed-load acceptance shape

    def test_closed_form_tracks_timeline_with_bec(self):
        """The backward pipeline term (BEC = 2·FEC per chunk) is the same
        closed form on doubled compute."""
        A, F, K = 2e-3, 3e-3, 4
        assert PerfModel.chunked_path_time(A, 2 * F, K) == pytest.approx(
            chunked_makespan(A, 2 * F, K), rel=1e-12)


class TestTrainerDispatch:
    class _StubEngine:
        def __init__(self, plan, stats=None):
            self._plan = plan
            self._stats = stats or {"comm_hidden_frac": 0.25,
                                    "a2a_gbytes": 1.5}
            self.asked = []

        def chunk_plan(self):
            return list(self._plan)

        def chunk_stats(self, plan=None):
            self.asked.append(plan)
            return dict(self._stats)

    def _chunks(self, plan):
        from repro.train.trainer import Trainer
        tr = Trainer.__new__(Trainer)          # no jit compile needed
        tr.engine = self._StubEngine(plan)
        return tr._chunks_for_dispatch()

    def test_majority_collapse_smallest_on_tie(self):
        assert self._chunks([1, 2, 2])[0] == 2
        assert self._chunks([1, 2])[0] == 1    # tie ⇒ smallest
        assert self._chunks([4, 4, 1, 1, 4])[0] == 4

    def test_stats_follow_dispatched_plan(self):
        from repro.train.trainer import Trainer
        tr = Trainer.__new__(Trainer)
        eng = self._StubEngine([2, 4, 2])
        tr.engine = eng
        k, stats = tr._chunks_for_dispatch()
        assert k == 2
        assert eng.asked == [[2, 2, 2]]        # stats for what ran
        assert stats["comm_hidden_frac"] == 0.25

    def test_no_engine_uses_flag(self, monkeypatch):
        from repro.train.trainer import Trainer
        tr = Trainer.__new__(Trainer)
        tr.engine = None
        assert tr._chunks_for_dispatch() == (1, None)
        monkeypatch.setenv("REPRO_A2A_CHUNKS", "4")
        assert tr._chunks_for_dispatch() == (4, None)


@pytest.mark.slow
class TestTrainerEndToEnd:
    def test_forced_k2_trains_and_reports(self, monkeypatch):
        """REPRO_A2A_CHUNKS=2 end to end: the step dispatches with K=2,
        telemetry carries it, and losses track the K=1 run closely."""
        from repro.configs import get_config, reduced
        from repro.data import SyntheticLM
        from repro.optim import adamw, cosine
        from repro.parallel import local_ctx
        from repro.train import Trainer
        from repro.train.trainer import make_engine_for

        cfg = reduced(get_config("moe-gpt-s"))
        ctx = local_ctx()

        def run(k_env):
            if k_env:
                monkeypatch.setenv("REPRO_A2A_CHUNKS", str(k_env))
            else:
                monkeypatch.delenv("REPRO_A2A_CHUNKS", raising=False)
            tr = Trainer(cfg, ctx, adamw(cosine(3e-3, 2, 4)),
                         attn_impl="naive", remat=False,
                         engine=make_engine_for(cfg, ctx))
            state = tr.init_state(jax.random.PRNGKey(0))
            sink = []
            _, hist = tr.run(state, SyntheticLM(cfg, batch=2, seq=16),
                             num_steps=4, log_every=0, stats_sink=sink)
            monkeypatch.delenv("REPRO_A2A_CHUNKS", raising=False)
            return hist, sink

        h1, s1 = run(1)
        h2, s2 = run(2)
        assert [st.a2a_chunks for st in s1] == [1] * 4
        assert [st.a2a_chunks for st in s2] == [2] * 4
        np.testing.assert_allclose(h1, h2, rtol=5e-2)
        assert [a.placements_fingerprint for a in s1] == \
            [b.placements_fingerprint for b in s2]


class TestTelemetrySurface:
    def test_step_stats_log_line(self):
        st = StepStats(step=1, loss=2.0, step_time=0.5, a2a_chunks=2,
                       a2a_gbytes=3.25, comm_hidden_frac=0.4)
        line = st.log_line(0.5)
        assert "a2a=3.25GB" in line and "chunks=2" in line
        assert "comm_hidden=40%" in line
        # no a2a traffic ⇒ no chunk spam in the log
        assert "chunks" not in StepStats(step=0, loss=1.0,
                                         step_time=0.1).log_line(0.1)

    def test_overlap_telemetry_means(self):
        tel = OverlapTelemetry()
        tel.record(plan=0.1, step=1.0, exposed=0.0, comm_hidden=0.5,
                   a2a_gbytes=2.0)
        tel.record(plan=0.1, step=1.0, exposed=0.0, comm_hidden=0.0,
                   a2a_gbytes=0.0)
        s = tel.summary()
        assert s["comm_hidden_frac"] == pytest.approx(0.25)
        assert s["mean_a2a_gbytes"] == pytest.approx(1.0)

    def test_record_stats_carries_chunk_fields(self):
        tel = OverlapTelemetry()
        tel.record_stats(StepStats(step=0, loss=1.0, step_time=0.2,
                                   comm_hidden_frac=0.3, a2a_gbytes=1.0))
        assert tel.comm_hidden_fracs == [0.3]
        assert tel.a2a_gbytes == [1.0]
