"""Data pipeline, optimizer, schedules, checkpoint round-trips, engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config, reduced
from repro.core import EngineConfig, GatingTrace, HardwareSpec, ProProphetEngine
from repro.data import SyntheticLM, make_batch_specs, synthetic_batch
from repro.optim import adamw, apply_updates, clip_by_global_norm, cosine, wsd
from repro.optim.schedule import linear_warmup


class TestData:
    def test_deterministic(self):
        cfg = reduced(get_config("smollm-360m"))
        b1 = synthetic_batch(cfg, 4, 16, step=3, seed=7)
        b2 = synthetic_batch(cfg, 4, 16, step=3, seed=7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = synthetic_batch(cfg, 4, 16, step=4, seed=7)
        assert (b1["tokens"] != b3["tokens"]).any()

    def test_labels_shifted(self):
        cfg = reduced(get_config("smollm-360m"))
        b = synthetic_batch(cfg, 2, 16, step=0, seed=0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        assert (b["tokens"] < cfg.vocab_size).all()

    def test_specs_match_batch(self):
        for name in ("smollm-360m", "hubert-xlarge", "paligemma-3b"):
            cfg = reduced(get_config(name))
            b = synthetic_batch(cfg, 2, 8, step=0, seed=0)
            specs = make_batch_specs(cfg, 2, 8, jnp.float32)
            assert set(b) == set(specs)
            for k in b:
                assert tuple(b[k].shape) == tuple(specs[k].shape), k

    def test_audio_masking(self):
        cfg = reduced(get_config("hubert-xlarge"))
        b = synthetic_batch(cfg, 2, 32, step=0, seed=0)
        masked = b["loss_mask"] > 0
        assert masked.any()
        # masked frames were zeroed (mask-token stub)
        assert np.abs(b["frame_embeds"][masked]).max() == 0.0


class TestOptim:
    def test_adamw_converges_quadratic(self):
        opt = adamw(0.1, weight_decay=0.0, clip_norm=None)
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_weight_decay_only_matrices(self):
        opt = adamw(0.0, weight_decay=0.5)   # lr 0 ⇒ pure decay term check
        params = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
        state = opt.init(params)
        g = jax.tree.map(jnp.zeros_like, params)
        upd, _ = opt.update(g, state, params)
        # lr = 0 ⇒ all updates zero regardless; use nonzero lr instead
        opt = adamw(0.1, weight_decay=0.5)
        upd, _ = opt.update(g, opt.init(params), params)
        assert float(jnp.abs(upd["w"]).max()) > 0      # decayed
        assert float(jnp.abs(upd["scale"]).max()) == 0  # not decayed

    def test_clip(self):
        g = {"a": jnp.full((10,), 100.0)}
        c = clip_by_global_norm(g, 1.0)
        n = float(jnp.linalg.norm(c["a"]))
        assert n == pytest.approx(1.0, rel=1e-5)

    def test_schedules(self):
        s = jnp.arange(0, 1000)
        w = wsd(1.0, 100, 700, 200)(s)
        assert float(w[0]) == 0.0
        assert float(w[500]) == pytest.approx(1.0)     # stable phase
        assert float(w[999]) < 0.05                    # decayed
        c = cosine(1.0, 10, 1000)(s)
        assert float(c[10]) == pytest.approx(1.0, rel=1e-2)
        assert float(c[999]) == pytest.approx(0.1, rel=0.05)
        lw = linear_warmup(2.0, 50)(s)
        assert float(lw[25]) == pytest.approx(1.0)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)},
                "d": [jnp.zeros((2,)), jnp.full((1,), 7.0)]}
        p = str(tmp_path / "ckpt.npz")
        save_pytree(tree, p)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            tree)
        back = load_pytree(like, p)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


class TestEngine:
    def _engine(self, policy="pro_prophet", scheduled=True):
        hw = HardwareSpec.from_model_dims(512, 1024, bandwidth=25e9,
                                          flops_per_s=70e12)
        return ProProphetEngine(EngineConfig(
            num_experts=8, num_devices=8, num_moe_layers=2, s_max=4,
            scheduled=scheduled, policy=policy), hw)

    def test_step_arrays_shapes(self):
        eng = self._engine()
        tr = GatingTrace(8, 8, 2048, skew=0.1, drift=0.02, seed=0)
        eng.observe([tr.step(), tr.step()])
        arrs = eng.step_arrays()
        assert arrs["shadow_idx"].shape == (2, 4)
        assert arrs["shadow_devs"].shape == (2, 4, 8)
        # padding slots carry the sentinel expert id == num_experts
        invalid = arrs["shadow_valid"] == 0
        assert (arrs["shadow_idx"][invalid] == 8).all()

    def test_policies_differ(self):
        tr = GatingTrace(8, 8, 4096, skew=0.05, drift=0.0, seed=1)
        g = tr.step()
        shadows = {}
        for pol in ("pro_prophet", "fastermoe", "top2", "none"):
            eng = self._engine(pol)
            eng.observe([g, g])
            shadows[pol] = sum(p.num_shadowed for p in eng.placements)
        assert shadows["none"] == 0
        assert shadows["top2"] == 4         # 2 per layer
        assert shadows["pro_prophet"] >= 1

    def test_predicted_speedup_under_skew(self):
        eng = self._engine()
        g = np.full((8, 8), 2.0)
        g[:, 0] = 2000.0
        eng.observe([g, g])
        assert eng.predicted_times()["speedup"] > 1.2
