"""prophetlint self-tests: each rule family catches its seeded fixture
violation, the annotation grammar behaves, and the repo itself is clean.

The fixtures live in tools/prophetlint/fixtures/ and are excluded from
the CLI walk — they are linted here explicitly, forcing hot-path /
env scope as needed (``lint_file(path, hot=True, env_exempt=False)``).
"""
import os
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools.prophetlint import cli                              # noqa: E402
from tools.prophetlint.cli import lint_file, lint_paths        # noqa: E402

FIXTURES = os.path.join(_ROOT, "tools", "prophetlint", "fixtures")


def _fixture(name, **kw):
    return lint_file(os.path.join(FIXTURES, name), **kw)


def _rules(violations):
    return [v.rule for v in violations]


# ---------------------------------------------------------------------------
# R1 host-sync
# ---------------------------------------------------------------------------

class TestHostSync:
    def test_catches_all_seeded_syncs(self):
        vs = _fixture("hot_sync.py", hot=True)
        assert _rules(vs).count("host-sync") == 6
        msgs = " ".join(v.message for v in vs)
        assert ".item()" in msgs
        assert "asarray" in msgs
        assert "device_get" in msgs
        assert "float" in msgs

    def test_allow_annotation_suppresses(self):
        vs = _fixture("hot_sync.py", hot=True)
        # annotated_ok's float(metrics["loss"]) is allowed → not flagged
        assert all(v.line < 19 for v in vs if v.rule == "host-sync")

    def test_not_flagged_outside_hot_path(self):
        assert _fixture("hot_sync.py", hot=False) == []


# ---------------------------------------------------------------------------
# R2 env-read
# ---------------------------------------------------------------------------

class TestEnvDiscipline:
    def test_catches_reads_not_writes(self):
        vs = _fixture("env_read.py", env_exempt=False)
        lines = sorted(v.line for v in vs if v.rule == "env-read")
        assert lines == [5, 6, 7, 8]       # get, getenv, membership, index
        # writes (lines 10-11) and the annotated read are not flagged

    def test_exempt_paths_skip_rule(self):
        assert _fixture("env_read.py", env_exempt=True) == []


# ---------------------------------------------------------------------------
# R3 jit-bounded
# ---------------------------------------------------------------------------

class TestJitBounded:
    def test_fixture_violations(self):
        vs = _fixture("jit_unbounded.py")
        msgs = {v.line: v.message for v in vs if v.rule == "jit-bounded"}
        assert any("no boundedness declaration" in m for m in msgs.values())
        assert any("static_argnums" in m for m in msgs.values())
        assert any("outside its declared candidate set" in m
                   for m in msgs.values())
        assert any("computed value for set-bounded" in m
                   for m in msgs.values())
        assert any("unknown kind" in m for m in msgs.values())
        assert len(msgs) == 5

    def test_in_set_literal_and_documented_call_are_clean(self):
        vs = _fixture("jit_unbounded.py")
        flagged = {v.line for v in vs}
        assert 26 not in flagged           # chunks=4 — in-set literal
        assert 28 not in flagged           # annotated provenance


# ---------------------------------------------------------------------------
# R4 shared-state
# ---------------------------------------------------------------------------

class TestSharedState:
    def test_owner_mode_catches_plan_pipeline_shaped_violation(self):
        """Acceptance: a lockset violation on a PlanPipeline-shared
        field (the fixture mirrors runtime.PlanPipeline's registry)."""
        vs = [v for v in _fixture("lockset_bad.py")
              if v.rule == "shared-state"]
        owner_hits = [v for v in vs if "owner list" in v.message]
        assert len(owner_hits) == 3
        assert any("_future" in v.message for v in owner_hits)
        assert any("_closed" in v.message for v in owner_hits)
        assert any("worker_restarts" in v.message for v in owner_hits)

    def test_lock_mode(self):
        vs = [v for v in _fixture("lockset_bad.py")
              if v.rule == "shared-state"]
        lock_hits = [v for v in vs if "self._lock" in v.message]
        assert len(lock_hits) == 1
        assert "racy_bump" in lock_hits[0].message

    def test_owner_methods_init_and_annotated_access_clean(self):
        vs = _fixture("lockset_bad.py")
        flagged = {v.line for v in vs}
        # __init__, submit/wait/close bodies and the annotated peek
        for line in (17, 18, 22, 25, 28, 41):
            assert line not in flagged


# ---------------------------------------------------------------------------
# R5 pallas contracts
# ---------------------------------------------------------------------------

class TestPallas:
    def test_vmem_budget_overflow(self):
        """Acceptance: the seeded 4096³-tile pallas_call is caught as a
        VMEM budget overflow (not merely 'unresolvable')."""
        vs = [v for v in _fixture("pallas_vmem.py")
              if v.rule == "pallas-vmem"]
        over = [v for v in vs if "exceeds" in v.message]
        assert len(over) == 1
        assert "MiB" in over[0].message

    def test_vmem_unresolvable_dim(self):
        vs = [v for v in _fixture("pallas_vmem.py")
              if v.rule == "pallas-vmem"]
        assert any("not statically resolvable" in v.message for v in vs)

    def test_tracer_branching(self):
        vs = [v for v in _fixture("pallas_branch.py")
              if v.rule == "pallas-branch"]
        assert len(vs) == 3                # if-on-pid, if-on-ref, while
        assert all("_branchy_kernel" in v.message for v in vs)
        # _clean_kernel (pl.when, static-config if, range loop) is clean

    def test_index_map_purity(self):
        vs = [v for v in _fixture("pallas_impure.py")
              if v.rule == "pallas-purity"]
        assert any("captures 'shift'" in v.message for v in vs)
        assert any("calls a function" in v.message for v in vs)
        # the pure out_specs map is not flagged
        assert all(v.line != 27 for v in vs)


# ---------------------------------------------------------------------------
# Annotation grammar
# ---------------------------------------------------------------------------

class TestAnnotations:
    def test_allow_requires_reason(self, tmp_path):
        p = tmp_path / "x.py"
        p.write_text("# prophetlint: allow(host-sync):\n"
                     "v = m['loss']\n")
        vs = lint_file(str(p), hot=False)
        assert any(v.rule == "annotation" and "mandatory" in v.message
                   for v in vs)

    def test_trailing_comment_covers_statement(self, tmp_path):
        p = tmp_path / "x.py"
        p.write_text(
            "import numpy as np\n"
            "a = np.asarray(x)  # prophetlint: allow(host-sync): host data\n")
        assert lint_file(str(p), hot=True) == []

    def test_block_comment_covers_multiline_statement(self, tmp_path):
        p = tmp_path / "x.py"
        p.write_text(
            "import numpy as np\n"
            "# prophetlint: allow(host-sync): host data,\n"
            "#   explained across two comment lines\n"
            "a = np.asarray(\n"
            "    x)\n")
        assert lint_file(str(p), hot=True) == []

    def test_unknown_directive_reported(self, tmp_path):
        p = tmp_path / "x.py"
        p.write_text("# prophetlint: frobnicate(x): y\n")
        vs = lint_file(str(p))
        assert any(v.rule == "annotation" for v in vs)


# ---------------------------------------------------------------------------
# The repo itself
# ---------------------------------------------------------------------------

class TestRepoClean:
    def test_src_is_clean(self):
        """Every pre-existing violation is fixed or annotated — the CI
        --lint lane gate."""
        vs = lint_paths([os.path.join(_ROOT, "src")])
        assert vs == [], "\n".join(str(v) for v in vs)

    def test_cli_exit_codes(self, capsys):
        assert cli.main([os.path.join(_ROOT, "src")]) == 0
        assert "clean" in capsys.readouterr().out
        assert cli.main([os.path.join(FIXTURES, "pallas_vmem.py")]) == 1
        out = capsys.readouterr().out
        assert "[pallas-vmem]" in out and "violation" in out

    def test_walker_skips_fixtures(self):
        vs = lint_paths([os.path.join(_ROOT, "tools")])
        assert vs == [], "\n".join(str(v) for v in vs)
