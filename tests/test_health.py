"""Elastic degraded mode: device health tracking, heterogeneity-aware
planning, expert evacuation, capacity-aware scoring, and the cooperative
plan deadline.

The degraded-mode invariant mirrors the resilience suite's: health only
decides *where* compute happens (placements, pricing), never the math —
so a fleet that stays healthy must be bit-identical to a run without the
tracker, and every evacuation must still satisfy the placement
invariants the traced step relies on.
"""
import time

import numpy as np
import pytest

from repro.core import (DeviceHealthTracker, EngineConfig, HardwareSpec,
                        ProProphetEngine)
from repro.core import guard
from repro.core.health import FACTOR_FLOOR, HEALTH_STATES
from repro.core.perfmodel import PerfModel
from repro.core.placement import ExpertPlacement, traditional
from repro.core.planner import GreedyPlanner
from repro.testing import Fault, FaultInjector


def _hw(**kw):
    return HardwareSpec.from_model_dims(512, 1024, bandwidth=25e9,
                                        flops_per_s=70e12, **kw)


def _engine(layers=2, d=4, e=8, **kw):
    cfg = EngineConfig(num_experts=e, num_devices=d, num_moe_layers=layers,
                       s_max=4, **kw)
    return ProProphetEngine(cfg, _hw())


def _skewed(d=4, e=8, hot=0, tokens=300.0):
    g = np.full((d, e), 10.0)
    g[:, hot] = tokens
    return g


# ---------------------------------------------------------------------------
# DeviceHealthTracker units
# ---------------------------------------------------------------------------

class TestHealthTracker:
    def test_uniform_times_stay_healthy(self):
        tr = DeviceHealthTracker(4)
        for _ in range(20):
            tr.update(np.full(4, 0.1))
        assert tr.all_healthy
        assert tr.summary() == "healthy"
        np.testing.assert_array_equal(tr.factors(), np.ones(4))

    def test_degraded_after_patience_with_factor(self):
        tr = DeviceHealthTracker(4, patience=3)
        times = np.full(4, 0.1)
        times[1] = 0.2                     # 2× the fleet median
        states = None
        for i in range(10):
            states = tr.update(times)
            if i < 2:                      # patience not yet exhausted
                assert states[1] == "healthy"
        assert states[1] == "degraded"
        assert tr.degraded() == [1] and tr.lost() == []
        # Factor converges toward median/ema = 0.5.
        assert 0.4 <= float(tr.factors()[1]) <= 0.6
        assert tr.summary() == "degraded:1"

    def test_extreme_ratio_is_lost(self):
        tr = DeviceHealthTracker(4, patience=2, lost_threshold=4.0)
        times = np.full(4, 0.1)
        times[2] = 10.0                    # 100× — lost-grade immediately
        for _ in range(6):
            tr.update(times)
        assert tr.state_of(2) == "lost"
        assert float(tr.factors()[2]) == 0.0

    def test_missed_heartbeats_mean_lost(self):
        tr = DeviceHealthTracker(4, patience=3)
        times = np.full(4, 0.1)
        times[3] = np.nan
        s = None
        for i in range(3):
            s = tr.update(times)
            assert s[3] == ("lost" if i >= 2 else "healthy")
        assert tr.lost() == [3]
        assert tr.summary() == "lost:3"

    def test_single_missed_beat_is_forgiven(self):
        tr = DeviceHealthTracker(4, patience=3)
        tr.update(np.array([0.1, 0.1, 0.1, np.nan]))
        tr.update(np.full(4, 0.1))         # heartbeat returns
        for _ in range(5):
            tr.update(np.full(4, 0.1))
        assert tr.all_healthy

    def test_recovery_after_calm_patience(self):
        tr = DeviceHealthTracker(4, patience=2, recovery_patience=3)
        slow = np.full(4, 0.1)
        slow[0] = 0.3
        for _ in range(8):
            tr.update(slow)
        assert tr.state_of(0) == "degraded"
        # EMA needs a few calm steps to decay below threshold, then
        # recovery_patience more to promote.
        for _ in range(20):
            tr.update(np.full(4, 0.1))
        assert tr.state_of(0) == "healthy"
        assert float(tr.factors()[0]) == 1.0

    def test_mark_lost_out_of_band(self):
        tr = DeviceHealthTracker(4)
        tr.mark_lost(2)
        assert tr.state_of(2) == "lost"
        assert tr.lost() == [2]
        assert float(tr.factors()[2]) == 0.0

    def test_snapshot_restore_roundtrip(self):
        tr = DeviceHealthTracker(4, patience=2)
        times = np.full(4, 0.1)
        times[1] = 0.4
        for _ in range(5):
            tr.update(times)
        snap = tr.snapshot()
        before = (tr.states(), tr.factors().copy(), tr.updates)
        for _ in range(5):
            tr.update(np.array([0.1, np.nan, np.nan, 0.1]))
        assert tr.states() != before[0] or tr.updates != before[2]
        tr.restore(snap)
        assert tr.states() == before[0]
        np.testing.assert_array_equal(tr.factors(), before[1])
        assert tr.updates == before[2]

    def test_states_are_known_labels(self):
        tr = DeviceHealthTracker(3)
        tr.update(np.array([0.1, np.nan, 50.0]))
        assert all(s in HEALTH_STATES for s in tr.states())


# ---------------------------------------------------------------------------
# PerfModel heterogeneity
# ---------------------------------------------------------------------------

class TestPerfModelHeterogeneity:
    def test_uniform_factors_bit_identical(self):
        pm = PerfModel(_hw(), 4)
        H = np.array([100.0, 250.0, 70.0, 33.0])
        R = np.array([40.0, 90.0, 10.0, 5.0])
        base = (pm.t_fec(H), pm.t_a2a(R))
        pm.set_device_factors(np.ones(4))
        assert (pm.t_fec(H), pm.t_a2a(R)) == base
        assert not pm.heterogeneous
        pm.set_device_factors(None)
        assert (pm.t_fec(H), pm.t_a2a(R)) == base

    def test_degraded_factor_slows_fec_and_a2a(self):
        pm = PerfModel(_hw(), 4)
        H = np.full(4, 100.0)
        R = np.full(4, 50.0)
        t0, a0 = pm.t_fec(H), pm.t_a2a(R)
        pm.set_device_factors(np.array([1.0, 0.5, 1.0, 1.0]))
        assert pm.heterogeneous
        assert pm.t_fec(H) == pytest.approx(2.0 * t0)
        assert pm.t_a2a(R) == pytest.approx(2.0 * a0)

    def test_lost_device_clamped_to_floor(self):
        pm = PerfModel(_hw(), 4)
        pm.set_device_factors(np.array([1.0, 1.0, 0.0, 1.0]))
        assert pm.lost_devices() == [2]
        speeds = pm.device_speeds()
        assert speeds[2] == pytest.approx(FACTOR_FLOOR * pm.hw.throughput)
        assert np.isfinite(pm.t_fec(np.full(4, 100.0)))

    def test_hardware_throughput_vector(self):
        import dataclasses
        hw = _hw()
        hw = dataclasses.replace(
            hw, device_throughput=(hw.throughput, hw.throughput / 2,
                                   hw.throughput, hw.throughput))
        pm = PerfModel(hw, 4)
        assert pm.heterogeneous
        H = np.full(4, 100.0)
        assert pm.t_fec(H) == pytest.approx(100.0 / (hw.throughput / 2))

    def test_raw_factors_roundtrip(self):
        pm = PerfModel(_hw(), 4)
        assert pm.raw_factors() is None
        f = np.array([1.0, 0.25, 0.0, 1.0])
        pm.set_device_factors(f)
        np.testing.assert_array_equal(pm.raw_factors(), f)
        pm2 = PerfModel(_hw(), 4)
        pm2.set_device_factors(pm.raw_factors())
        assert pm2.lost_devices() == pm.lost_devices()
        np.testing.assert_array_equal(pm2.device_speeds(),
                                      pm.device_speeds())


# ---------------------------------------------------------------------------
# Heterogeneity-aware planning
# ---------------------------------------------------------------------------

class TestHeterogeneousPlanning:
    def _weighted_max(self, pl, g, speeds):
        H, _ = pl.compute_loads(g)
        return float((H / speeds).max())

    def test_hot_expert_drains_off_slow_device(self):
        """A degraded device hosting the hot expert: the plan must cut
        the slowness-weighted bottleneck below the do-nothing baseline."""
        pm = PerfModel(_hw(), 4)
        pm.set_device_factors(np.array([0.4, 1.0, 1.0, 1.0]))
        planner = GreedyPlanner(pm, alpha=0.1, s_max=4, scheduled=False)
        g = _skewed(hot=0)                 # expert 0 lives on device 0
        res = planner.plan(g)
        base = traditional(8, 4)
        speeds = pm.device_speeds()
        assert (self._weighted_max(res.placement, g, speeds)
                < self._weighted_max(base, g, speeds))
        # The hot expert was shadowed or moved — device 0 no longer
        # carries the whole spike alone.
        H, _ = res.placement.compute_loads(g)
        H_base, _ = base.compute_loads(g)
        assert H[0] < H_base[0]

    def test_homogeneous_plan_unchanged_by_unit_factors(self):
        pm_a = PerfModel(_hw(), 4)
        pm_b = PerfModel(_hw(), 4)
        pm_b.set_device_factors(np.ones(4))
        g = _skewed(hot=3)
        res_a = GreedyPlanner(pm_a, s_max=4).plan(g)
        res_b = GreedyPlanner(pm_b, s_max=4).plan(g)
        assert res_a.placement == res_b.placement
        assert res_a.predicted_time == res_b.predicted_time


# ---------------------------------------------------------------------------
# Expert evacuation
# ---------------------------------------------------------------------------

class TestEvacuation:
    def _lost_perf(self, lost, d=4):
        pm = PerfModel(_hw(), d)
        f = np.ones(d)
        for dd in lost:
            f[dd] = 0.0
        pm.set_device_factors(f)
        return pm

    def test_lost_rank_fully_evacuated(self):
        pm = self._lost_perf([2])
        planner = GreedyPlanner(pm, s_max=4)
        g = _skewed()
        res = planner.plan(g)
        assert res.num_evacuated > 0
        H, R = res.placement.compute_loads(g)
        assert R[2] == 0.0                 # nothing routed to the corpse
        guard.validate_placement(res.placement, num_experts=8,
                                 num_devices=4)

    def test_evacuation_property_random_configs(self):
        """Property: over seeded random (D, E, lost, g) configs the
        evacuated placement is always structurally valid, routes nothing
        to the lost rank, and never shadows onto it."""
        rng = np.random.default_rng(42)
        for trial in range(25):
            d = int(rng.integers(2, 6))
            e = d * int(rng.integers(1, 4))
            lost = int(rng.integers(0, d))
            g = rng.integers(1, 200, size=(d, e)).astype(np.float64)
            pm = self._lost_perf([lost], d=d)
            planner = GreedyPlanner(pm, s_max=max(2, e // 2))
            res = planner.plan(g)
            guard.validate_placement(res.placement, num_experts=e,
                                     num_devices=d)
            _, R = res.placement.compute_loads(g)
            assert R[lost] == 0.0, (trial, d, e, lost)
            for exp, devs in res.placement.shadows.items():
                assert lost not in devs, (trial, exp, devs)

    def test_evacuation_disabled_leaves_residents(self):
        pm = self._lost_perf([1])
        planner = GreedyPlanner(pm, s_max=4, evacuate=False)
        res = planner.plan(_skewed())
        assert res.num_evacuated == 0

    def test_all_lost_is_a_noop(self):
        """Nowhere to evacuate to: the planner must not thrash."""
        pm = self._lost_perf([0, 1, 2, 3])
        res = GreedyPlanner(pm, s_max=4).plan(_skewed())
        assert res.num_evacuated == 0
        guard.validate_placement(res.placement, num_experts=8,
                                 num_devices=4)

    def test_migrations_never_target_lost_rank(self):
        pm = self._lost_perf([3])
        planner = GreedyPlanner(pm, s_max=4, strategy="both",
                                migrate_window=500.0, migrate_hysteresis=0.0)
        res = planner.plan(_skewed(hot=1))
        owner = res.placement.owner
        # Experts may sit in device 3's physical slots only if they are
        # stranded cold partners with zero routed traffic.
        _, R = res.placement.compute_loads(_skewed(hot=1))
        assert R[3] == 0.0
        assert owner.shape == (8,)


# ---------------------------------------------------------------------------
# Capacity-aware placement scoring (ROADMAP carry-over)
# ---------------------------------------------------------------------------

class TestCapacityScoring:
    def _oracle(self, pl, g, cap):
        """Independent loop-based dense accounting: route every (source,
        expert) cell to the device that computes it (local holder, else
        the owner), truncate each per-device expert bucket at cap."""
        d, e = g.shape
        holds = pl.placement_matrix().T          # [D, E]
        buckets = np.zeros((d, e))
        for src in range(d):
            for exp in range(e):
                dev = src if holds[src, exp] else int(pl.owner[exp])
                buckets[dev, exp] += g[src, exp]
        capped = np.minimum(buckets, cap)
        return capped.sum(axis=1), (buckets - capped).sum(axis=1)

    def test_capacity_none_bit_identical(self):
        pl = traditional(8, 4).with_shadow(0, (1, 2))
        g = _skewed()
        H0, R0 = pl.compute_loads(g)
        H1, R1, drop = pl.compute_loads(g, return_dropped=True)
        np.testing.assert_array_equal(H0, H1)
        np.testing.assert_array_equal(R0, R1)
        np.testing.assert_array_equal(drop, np.zeros(4))

    def test_capacity_truncation_matches_oracle(self):
        rng = np.random.default_rng(7)
        for _ in range(10):
            pl = traditional(8, 4)
            for e in rng.choice(8, size=2, replace=False):
                devs = [d for d in range(4) if d != int(pl.owner[e])]
                pl = pl.with_shadow(int(e), tuple(devs[:2]))
            g = rng.integers(0, 120, size=(4, 8)).astype(np.float64)
            cap = float(rng.integers(30, 150))
            H, R, drop = pl.compute_loads(g, capacity=cap,
                                          return_dropped=True)
            H_or, drop_or = self._oracle(pl, g, cap)
            np.testing.assert_allclose(H, H_or)
            np.testing.assert_allclose(drop, drop_or)
            # Wire cost is paid before the buffer drops: R untruncated.
            _, R_dense = pl.compute_loads(g)
            np.testing.assert_array_equal(R, R_dense)

    def test_planner_capacity_penalty_prefers_fewer_drops(self):
        pm = PerfModel(_hw(), 4)
        g = _skewed(tokens=600.0)
        dense = GreedyPlanner(pm, s_max=4, scheduled=False).plan(g)
        capped = GreedyPlanner(pm, s_max=4, scheduled=False,
                               capacity_factor=1.25).plan(g)
        # The dense planner never charges drops, so compare both plans
        # under the *same* cap the capacity-aware search optimized for:
        # its plan must not drop more than the capacity-blind one would.
        cap = 1.25 * g.sum() / 8
        _, _, drop_dense = dense.placement.compute_loads(
            g, capacity=cap, return_dropped=True)
        assert dense.dropped_tokens == 0.0
        assert capped.dropped_tokens <= float(drop_dense.sum()) + 1e-9


# ---------------------------------------------------------------------------
# Cooperative plan deadline (ROADMAP carry-over)
# ---------------------------------------------------------------------------

class TestCooperativeDeadline:
    def test_expired_deadline_aborts_search(self):
        pm = PerfModel(_hw(), 4)
        planner = GreedyPlanner(pm, s_max=4)
        with pytest.raises(guard.PlanDeadlineError):
            planner.plan(_skewed(), deadline=time.perf_counter() - 1.0)

    def test_future_deadline_harmless(self):
        pm = PerfModel(_hw(), 4)
        planner = GreedyPlanner(pm, s_max=4)
        res = planner.plan(_skewed(), deadline=time.perf_counter() + 60.0)
        assert res.placement is not None

    def test_run_plan_converts_to_deadline_fallback(self, monkeypatch):
        from repro.train.runtime import run_plan
        monkeypatch.setenv("REPRO_PLAN_DEADLINE_MS", "0.0000001")
        eng = _engine()
        v = eng.placements_version
        ev = run_plan(eng, np.stack([_skewed(hot=5)] * 2))
        assert not ev.ok and ev.failure == "deadline"
        assert eng.placements_version == v   # rolled back

    def test_deadline_env_does_not_break_fast_plans(self, monkeypatch):
        from repro.train.runtime import run_plan
        monkeypatch.setenv("REPRO_PLAN_DEADLINE_MS", "60000")
        eng = _engine()
        ev = run_plan(eng, np.stack([_skewed()] * 2))
        assert ev.ok


# ---------------------------------------------------------------------------
# Engine wiring: observe_timings → replan → evacuation
# ---------------------------------------------------------------------------

class TestEngineHealth:
    def test_disabled_by_default_no_op(self):
        eng = _engine()
        assert not eng.health_enabled
        eng.observe_timings(np.full(4, 0.1))
        assert eng.health.updates == 0
        assert not eng.perf.heterogeneous

    def test_uniform_timings_never_trip(self):
        eng = _engine(enable_health=True)
        eng.observe([_skewed(), _skewed(hot=3)])
        v = eng.placements_version
        for _ in range(10):
            eng.observe_timings(np.full(4, 0.25))
            eng.observe([_skewed(), _skewed(hot=3)])
        assert eng.health_summary() == "healthy"
        assert not eng.perf.heterogeneous
        assert eng.placements_version == v   # nothing replanned differently

    def test_device_loss_evacuates_within_one_observe(self):
        eng = _engine(enable_health=True, replan_interval=4)
        g = [_skewed(), _skewed(hot=3)]
        eng.observe(g)
        lost_at = None
        for step in range(8):
            t = np.full(4, 0.1)
            t[2] = np.nan
            eng.observe_timings(t)
            if eng.health.lost() and lost_at is None:
                lost_at = step
                assert eng._health_dirty
            eng.observe(g)
            if lost_at is not None:
                break
        assert lost_at is not None
        # The very next observe after classification evacuated rank 2,
        # despite the replan_interval=4 cadence.
        assert eng.evacuations > 0
        for li, pl in enumerate(eng.placements):
            _, R = pl.compute_loads(g[li])
            assert R[2] == 0.0, li
        assert eng.last_plan_info["evacuated"] >= 0
        guard.validate_engine(eng)

    def test_straggler_fault_degrades_then_recovers(self):
        inj = FaultInjector([Fault("straggler", 2,
                                   {"device": 1, "factor": 3.0,
                                    "steps": 6})])
        eng = _engine(enable_health=True,
                      health_patience=2, health_recovery_patience=2)
        g = [_skewed(), _skewed(hot=3)]
        eng.observe(g)
        saw_degraded = False
        for _ in range(30):
            times = inj.device_timings(np.full(4, 0.1))
            eng.observe_timings(times)
            eng.observe(g)
            if eng.health.state_of(1) == "degraded":
                saw_degraded = True
                assert eng.perf.heterogeneous
        assert ("straggler", 2) in inj.fired
        assert saw_degraded
        # Episode over: the device recovers and pricing goes homogeneous.
        assert eng.health_summary() == "healthy"
        assert not eng.perf.heterogeneous

    def test_snapshot_restore_covers_health(self):
        eng = _engine(enable_health=True, health_patience=1)
        g = [_skewed(), _skewed(hot=3)]
        eng.observe(g)
        snap = eng.snapshot()
        t = np.full(4, 0.1)
        t[0] = np.nan
        for _ in range(3):
            eng.observe_timings(t)
            eng.observe(g)
        assert eng.health.lost() == [0]
        eng.restore(snap)
        assert eng.health_summary() == "healthy"
        assert not eng.perf.heterogeneous
        assert eng.evacuations == 0

    def test_validate_health_rejects_corrupt_factor(self):
        eng = _engine(enable_health=True)
        eng.observe([_skewed(), _skewed(hot=3)])
        eng.health._factor[1] = np.nan
        with pytest.raises(guard.PlacementInvariantError, match="factor"):
            guard.validate_engine(eng)


# ---------------------------------------------------------------------------
# Fault injector: timing sites
# ---------------------------------------------------------------------------

class TestTimingFaults:
    def test_device_loss_persists_forever(self):
        inj = FaultInjector([Fault("device_loss", 1, {"device": 3})])
        t0 = inj.device_timings(np.full(4, 0.1))
        assert np.isfinite(t0).all()       # occurrence 0: clean
        for _ in range(5):
            t = inj.device_timings(np.full(4, 0.1))
            assert np.isnan(t[3]) and np.isfinite(t[:3]).all()
        assert ("device_loss", 1) in inj.fired

    def test_straggler_episode_bounded(self):
        inj = FaultInjector([Fault("straggler", 0,
                                   {"device": 0, "factor": 2.0,
                                    "steps": 3})])
        inflated = [inj.device_timings(np.full(4, 0.1))[0]
                    for _ in range(6)]
        assert inflated[:3] == [pytest.approx(0.2)] * 3
        assert inflated[3:] == [pytest.approx(0.1)] * 3

    def test_degraded_throughput_persists(self):
        inj = FaultInjector([Fault("degraded_throughput", 0,
                                   {"device": 2, "factor": 1.5})])
        for _ in range(4):
            t = inj.device_timings(np.full(4, 0.1))
            assert t[2] == pytest.approx(0.15)

    def test_sites_advance_together(self):
        inj = FaultInjector([Fault("straggler", 2, {"device": 0}),
                             Fault("device_loss", 2, {"device": 1})])
        for _ in range(3):
            t = inj.device_timings(np.full(4, 0.1))
        assert t[0] == pytest.approx(0.2) and np.isnan(t[1])
